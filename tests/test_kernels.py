"""Pallas kernel validation (interpret mode) vs pure-jnp oracles: shape/dtype
sweeps + seeded deterministic parameter sweeps (the former hypothesis draws,
pinned so the suite needs no extra dependency)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import (decode_attention,
                                                paged_decode_attention,
                                                ragged_paged_attention)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                densify_pool,
                                                paged_decode_attention_ref,
                                                ragged_paged_attention_ref)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref


# ------------------------------------------------------------ flash attention
FLASH_CASES = [
    # (B, S, H, K, D, window, softcap, dtype)
    (2, 128, 4, 2, 64, None, None, jnp.float32),
    (1, 256, 4, 4, 64, 64, None, jnp.float32),
    (2, 100, 8, 2, 32, None, 50.0, jnp.float32),
    (1, 96, 4, 1, 64, 32, 30.0, jnp.float32),
    (1, 64, 2, 2, 128, None, None, jnp.bfloat16),
    (1, 80, 8, 4, 16, 16, None, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,K,D,win,cap,dt", FLASH_CASES)
def test_flash_attention_matches_ref(B, S, H, K, D, win, cap, dt):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dt)
    k = jax.random.normal(ks[1], (B, S, K, D), dt)
    v = jax.random.normal(ks[2], (B, S, K, D), dt)
    out = flash_attention(q, k, v, window=win, softcap=cap, interpret=True,
                          block_q=32, block_k=32)
    ref = attention_ref(q, k, v, window=win, softcap=cap)
    tol = 2e-5 if dt == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


# deterministic draws from the former hypothesis domains:
# s in [2,5], h in {2,4}, g in {1,2} with g|h, win in {None,8,24}, blk in {16,32}
FLASH_SWEEP = [
    (2, 2, 1, None, 16),
    (3, 4, 2, 8, 32),
    (4, 2, 2, 24, 16),
    (5, 4, 1, None, 32),
    (2, 4, 2, 24, 32),
    (5, 2, 1, 8, 16),
    (3, 2, 2, None, 32),
    (4, 4, 1, 24, 16),
]


@pytest.mark.parametrize("s,h,g,win,blk", FLASH_SWEEP)
def test_flash_attention_param_sweep(s, h, g, win, blk):
    B, S, D = 1, s * 16, 32
    K = h // g
    ks = jax.random.split(jax.random.PRNGKey(s * 7 + h), 3)
    q = jax.random.normal(ks[0], (B, S, h, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
    out = flash_attention(q, k, v, window=win, interpret=True,
                          block_q=blk, block_k=blk)
    ref = attention_ref(q, k, v, window=win)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


# ------------------------------------------------------------ decode attention
DECODE_CASES = [
    (2, 256, 8, 2, 64, None, None, 200),
    (1, 128, 4, 4, 32, 64, None, 128),
    (2, 512, 8, 1, 64, None, 50.0, 300),
    (3, 96, 4, 2, 64, 32, 30.0, 50),
]


@pytest.mark.parametrize("B,S,H,K,D,win,cap,fill", DECODE_CASES)
def test_decode_attention_matches_ref(B, S, H, K, D, win, cap, fill):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
    pos = jnp.where(jnp.arange(S)[None, :] < fill, jnp.arange(S)[None, :], -1)
    pos = jnp.broadcast_to(pos, (B, S))
    qpos = jnp.full((B,), fill - 1, jnp.int32)
    out = decode_attention(q, kc, vc, qpos, pos, window=win, softcap=cap,
                           interpret=True, block_k=64)
    ref = decode_attention_ref(q, kc, vc, qpos, pos, window=win, softcap=cap)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_decode_attention_ring_buffer_semantics():
    """Ring cache: slot positions arbitrary; only in-window slots count."""
    B, S, H, K, D = 1, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
    # ring: slot s holds position 100 - (s % 7) scattered arbitrarily
    pos = (100 - (jnp.arange(S) % 7))[None, :]
    qpos = jnp.full((B,), 100, jnp.int32)
    out = decode_attention(q, kc, vc, qpos, pos, window=5, interpret=True,
                           block_k=16)
    ref = decode_attention_ref(q, kc, vc, qpos, pos, window=5)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


# ----------------------------------------------------- paged decode attention
# seeded sweep over (heads H, kv heads K, block size bs, cache lengths):
# each case scatters per-request caches into a shared block pool through
# randomized block tables and must match BOTH the paged oracle and the dense
# decode oracle on the densified layout.
PAGED_DECODE_SWEEP = [
    # (B, H, K, D, bs, nb, ctx_lens, window, softcap)
    (2, 4, 2, 32, 8, 4, (25, 9), None, None),
    (1, 8, 8, 64, 16, 4, (64,), None, None),
    (3, 4, 1, 64, 16, 8, (100, 17, 128), None, 30.0),
    (2, 8, 2, 32, 32, 2, (33, 64), None, None),
    (2, 4, 4, 16, 8, 8, (61, 1), 12, None),
    (1, 2, 2, 128, 64, 2, (90,), None, 50.0),
    (3, 8, 4, 32, 16, 4, (31, 32, 48), 20, None),
]


def _random_block_tables(rng, num_blocks, bs, nb, ctx_lens):
    """Distinct random physical blocks per request, -1 trailing pads;
    block 0 is kept free (the engine's reserved null block)."""
    B = len(ctx_lens)
    bt = np.full((B, nb), -1, np.int32)
    perm = rng.permutation(np.arange(1, num_blocks))
    i = 0
    for b, ctx in enumerate(ctx_lens):
        n = -(-ctx // bs)
        bt[b, :n] = perm[i:i + n]
        i += n
    return bt


@pytest.mark.parametrize("B,H,K,D,bs,nb,ctxs,win,cap", PAGED_DECODE_SWEEP)
def test_paged_decode_attention_matches_refs(B, H, K, D, bs, nb, ctxs, win, cap):
    rng = np.random.default_rng(B * 1000 + H * 10 + bs)
    N = 1 + sum(-(-c // bs) for c in ctxs) + 2          # null + used + spare
    ks = jax.random.split(jax.random.PRNGKey(B + H + bs), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (N, bs, K, D), jnp.float32)
    vp = jax.random.normal(ks[2], (N, bs, K, D), jnp.float32)
    bt = jnp.asarray(_random_block_tables(rng, N, bs, nb, ctxs))
    qpos = jnp.asarray([c - 1 for c in ctxs], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, qpos, window=win, softcap=cap,
                                 interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, bt, qpos, window=win,
                                     softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # cross-check vs the DENSE oracle on the densified cache: paging must be
    # a pure layout change, not a numerics change
    kd, vd, pos = densify_pool(kp, vp, bt)
    dense = decode_attention_ref(q, kd, vd, qpos, pos, window=win, softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)


# ----------------------------------------------------- ragged paged attention
# the unified mixed tick's kernel: prefill CHUNKS and decode rows packed into
# one token batch.  Each case lists per-request (ctx_len, chunk_len): the
# last chunk_len positions of the context are packed as that request's
# queries (chunk_len == 1 ≡ a decode row); pad lanes fill the budget tail.
# Sweep axes per the acceptance bar: mixed chunk sizes × decode rows × block
# sizes (plus window/softcap and a shared prefix block).
RAGGED_SWEEP = [
    # (H, K, D, bs, nb, reqs=((ctx, chunk), ...), window, softcap)
    (4, 2, 32, 8, 4, ((25, 5), (9, 1)), None, None),            # chunk + decode
    (4, 4, 16, 16, 3, ((33, 33), (40, 1), (17, 1)), None, None),  # full prefill
    (8, 2, 64, 8, 8, ((61, 13), (64, 1), (30, 7), (8, 8)), None, 30.0),
    (2, 2, 128, 32, 2, ((50, 11), (33, 1)), 12, None),          # windowed
    (8, 8, 32, 16, 4, ((1, 1), (2, 1), (64, 64)), None, None),  # tiny ctxs
    (4, 1, 64, 64, 2, ((100, 36), (128, 1), (90, 2)), 20, 50.0),
]


@pytest.mark.parametrize("H,K,D,bs,nb,reqs,win,cap", RAGGED_SWEEP)
def test_ragged_paged_attention_matches_refs(H, K, D, bs, nb, reqs, win, cap):
    rng = np.random.default_rng(H * 100 + bs + len(reqs))
    ctxs = [c for c, _ in reqs]
    N = 1 + sum(-(-c // bs) for c in ctxs) + 2          # null + used + spare
    ks = jax.random.split(jax.random.PRNGKey(H + bs), 3)
    T = sum(ch for _, ch in reqs) + 3                   # 3 pad lanes
    q = jax.random.normal(ks[0], (T, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (N, bs, K, D), jnp.float32)
    vp = jax.random.normal(ks[2], (N, bs, K, D), jnp.float32)
    bt = jnp.asarray(_random_block_tables(rng, N, bs, nb, ctxs))
    rows = np.full(T, -1, np.int32)
    tpos = np.full(T, -1, np.int32)
    n = 0
    for r, (ctx, chunk) in enumerate(reqs):
        rows[n:n + chunk] = r
        tpos[n:n + chunk] = np.arange(ctx - chunk, ctx)
        n += chunk
    rows, tpos = jnp.asarray(rows), jnp.asarray(tpos)
    out = ragged_paged_attention(q, kp, vp, bt, rows, tpos, window=win,
                                 softcap=cap, interpret=True)
    ref = ragged_paged_attention_ref(q, kp, vp, bt, rows, tpos, window=win,
                                     softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # pad lanes are EXACT zeros (the engine relies on nothing leaking there)
    assert np.all(np.asarray(out)[n:] == 0)
    # cross-check vs the independently-validated single-token paged kernel:
    # packing must be a pure layout change, token by token
    per_tok = paged_decode_attention_ref(
        q[:n], kp, vp, bt[jnp.clip(rows[:n], 0, len(reqs) - 1)], tpos[:n],
        window=win, softcap=cap)
    np.testing.assert_allclose(np.asarray(out)[:n], np.asarray(per_tok),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------- speculative verify rows
# Multi-token VERIFY rows (speculative decoding): a decode row that feeds
# its last `fed` tokens at consecutive tail positions — fed = 1 + k draft
# tokens, k ∈ {1, 2, 4} per the acceptance bar, plus the fed = 1 (k = 0)
# degenerate case that must reproduce today's single-token decode.  Swept
# across block sizes × window/softcap, mixed with plain decode rows and a
# prefill chunk in the same packing.
VERIFY_SWEEP = [
    # (H, K, D, bs, reqs=((ctx, fed), ...), window, softcap)
    (4, 2, 32, 8, ((20, 2), (33, 3), (17, 5), (9, 1)), None, None),
    (4, 4, 16, 16, ((40, 5), (16, 2), (25, 3)), None, 30.0),
    (2, 2, 64, 32, ((50, 3), (33, 5), (9, 2), (64, 1)), 12, None),
    (8, 2, 32, 8, ((25, 5), (63, 3), (7, 2), (5, 1), (30, 12)), 16, 50.0),
    (4, 1, 64, 64, ((100, 5), (128, 2), (90, 3)), None, None),
]


@pytest.mark.parametrize("H,K,D,bs,reqs,win,cap", VERIFY_SWEEP)
def test_ragged_verify_rows_match_refs(H, K, D, bs, reqs, win, cap):
    """Verify rows are kernel-wise identical to prefill chunks of the same
    length: the ragged kernel must match the ragged oracle AND the
    independently-validated per-token paged decode oracle for every fed
    position (the logits the acceptance rule consumes)."""
    rng = np.random.default_rng(H * 31 + bs + len(reqs))
    ctxs = [c for c, _ in reqs]
    N = 1 + sum(-(-c // bs) for c in ctxs) + 2
    ks = jax.random.split(jax.random.PRNGKey(H * 7 + bs), 3)
    T = sum(f for _, f in reqs) + 2                    # 2 pad lanes
    q = jax.random.normal(ks[0], (T, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (N, bs, K, D), jnp.float32)
    vp = jax.random.normal(ks[2], (N, bs, K, D), jnp.float32)
    nb = max(-(-c // bs) for c in ctxs)
    bt = jnp.asarray(_random_block_tables(rng, N, bs, nb, ctxs))
    rows = np.full(T, -1, np.int32)
    tpos = np.full(T, -1, np.int32)
    n = 0
    for r, (ctx, fed) in enumerate(reqs):
        rows[n:n + fed] = r
        tpos[n:n + fed] = np.arange(ctx - fed, ctx)    # verify tail
        n += fed
    rows, tpos = jnp.asarray(rows), jnp.asarray(tpos)
    out = ragged_paged_attention(q, kp, vp, bt, rows, tpos, window=win,
                                 softcap=cap, interpret=True)
    ref = ragged_paged_attention_ref(q, kp, vp, bt, rows, tpos, window=win,
                                     softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    per_tok = paged_decode_attention_ref(
        q[:n], kp, vp, bt[jnp.clip(rows[:n], 0, len(reqs) - 1)], tpos[:n],
        window=win, softcap=cap)
    np.testing.assert_allclose(np.asarray(out)[:n], np.asarray(per_tok),
                               atol=2e-5, rtol=2e-5)
    assert np.all(np.asarray(out)[n:] == 0)            # pads stay exact zeros


def test_verify_row_k0_bitmatches_single_token_decode():
    """The fed = 1 degenerate verify row IS today's decode: packing each
    request as a one-token row (with pad lanes interleaved and rows packed
    out of slot order) must BIT-match the single-token paged decode kernel
    — speculation changes the packing, never the numbers."""
    H, K, D, bs = 4, 2, 32, 8
    nb = 3
    ctxs = (21, 9, 17)
    rng = np.random.default_rng(3)
    N = 1 + sum(-(-c // bs) for c in ctxs) + 2
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    B = len(ctxs)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (N, bs, K, D), jnp.float32)
    vp = jax.random.normal(ks[2], (N, bs, K, D), jnp.float32)
    bt = jnp.asarray(_random_block_tables(rng, N, bs, nb, ctxs))
    qpos = jnp.asarray([c - 1 for c in ctxs], jnp.int32)
    decode = paged_decode_attention(q, kp, vp, bt, qpos, interpret=True)
    # scrambled one-token-per-row packing with pads: lanes [pad, 1, 0, pad, 2]
    lanes = [1, 0, 2]
    T = 5
    qr = jnp.zeros((T, H, D), jnp.float32)
    rows = np.full(T, -1, np.int32)
    tpos = np.full(T, -1, np.int32)
    for lane, b in zip((1, 2, 4), lanes):
        qr = qr.at[lane].set(q[b])
        rows[lane] = b
        tpos[lane] = int(qpos[b])
    out = ragged_paged_attention(qr, kp, vp, bt, jnp.asarray(rows),
                                 jnp.asarray(tpos), interpret=True)
    out = np.asarray(out)
    for lane, b in zip((1, 2, 4), lanes):
        assert np.array_equal(out[lane], np.asarray(decode)[b]), \
            f"lane {lane} diverged from single-token decode of request {b}"


def test_ragged_same_dispatch_shared_prefix_block():
    """Two packed chunks whose tables share a physical prefix block (the
    intra-batch sharing case) read identical prefix KV."""
    H, K, D, bs = 4, 2, 32, 8
    N, nb = 6, 2
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    T = 6
    q = jax.random.normal(ks[0], (T, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (N, bs, K, D), jnp.float32)
    vp = jax.random.normal(ks[2], (N, bs, K, D), jnp.float32)
    bt = jnp.asarray([[3, 1], [3, 2]], jnp.int32)       # block 3 shared
    rows = jnp.asarray([0, 0, 0, 1, 1, 1], jnp.int32)
    tpos = jnp.asarray([10, 11, 12, 13, 14, 15], jnp.int32)
    out = ragged_paged_attention(q, kp, vp, bt, rows, tpos, interpret=True)
    ref = ragged_paged_attention_ref(q, kp, vp, bt, rows, tpos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_shared_prefix_block():
    """Two requests whose tables share a physical block (trie prefix reuse)
    read identical prefix KV: outputs for the shared positions agree with a
    dense cache that duplicates the prefix."""
    B, H, K, D, bs = 2, 4, 2, 32, 8
    N, nb = 6, 2
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (N, bs, K, D), jnp.float32)
    vp = jax.random.normal(ks[2], (N, bs, K, D), jnp.float32)
    bt = jnp.asarray([[3, 1], [3, 2]], jnp.int32)       # block 3 shared
    qpos = jnp.asarray([12, 15], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, qpos, interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, bt, qpos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ----------------------------------------------------------------------- SSD
SSD_CASES = [
    (2, 64, 2, 16, 16, 16),
    (1, 100, 4, 32, 16, 32),    # ragged: S % chunk != 0
    (2, 128, 2, 64, 128, 64),
]


@pytest.mark.parametrize("B,S,H,P,N,Q", SSD_CASES)
def test_ssd_matches_sequential_ref(B, S, H, P, N, Q):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B_ = jax.random.normal(ks[3], (B, S, N)) / np.sqrt(N)
    C_ = jax.random.normal(ks[4], (B, S, N)) / np.sqrt(N)
    D = jnp.ones((H,))
    y_k, h_k = ssd(x, dt, A, B_, C_, D, chunk=Q, interpret=True)
    y_r, h_r = ssd_ref(x, dt, A, B_, C_, D)
    np.testing.assert_allclose(y_k, y_r, atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(h_k, h_r, atol=5e-4, rtol=1e-3)


# deterministic draws from the former hypothesis domains:
# s in [3,8], q in {8,16}, n in {8,16}
SSD_SWEEP = [
    (3, 8, 8),
    (4, 16, 8),
    (5, 8, 16),
    (6, 16, 16),
    (7, 16, 8),
    (8, 8, 16),
]


@pytest.mark.parametrize("s,q,n", SSD_SWEEP)
def test_ssd_param_sweep(s, q, n):
    B, S, H, P = 1, s * 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(s + q), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (B, S, n)) / np.sqrt(n)
    C_ = jax.random.normal(ks[4], (B, S, n)) / np.sqrt(n)
    y_k, h_k = ssd(x, dt, A, B_, C_, chunk=q, interpret=True)
    y_r, h_r = ssd_ref(x, dt, A, B_, C_)
    np.testing.assert_allclose(y_k, y_r, atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(h_k, h_r, atol=5e-4, rtol=1e-3)


def test_model_pallas_backend_matches_xla():
    """The model's attention via the Pallas kernel (interpret) == XLA path."""
    from repro.models import ModelConfig, forward, init_params

    cfg_x = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                        dtype="float32", q_chunk=16, attn_backend="xla")
    cfg_p = cfg_x.replace(attn_backend="pallas_interpret")
    params = init_params(jax.random.PRNGKey(0), cfg_x)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 128)
    pos = jnp.broadcast_to(jnp.arange(32), (2, 32))
    lx, _ = forward(params, toks, pos, cfg_x, mode="score")
    lp, _ = forward(params, toks, pos, cfg_p, mode="score")
    np.testing.assert_allclose(lx, lp, atol=1e-4, rtol=1e-4)


# ----------------------------------------------------- quantized KV pools
# Parity sweep across the kv_dtype ladder × block sizes × window/softcap.
# Two-sided contract: (1) kernel vs the SAME-precision oracle stays at the
# unquantized tolerance (both dequantize identical stored values, so the
# pool dtype must not perturb the kernel's arithmetic); (2) quantized
# output vs the f32-pool truth sits within an EXPLICIT tolerance ladder —
# the accuracy budget README documents per dtype.
QUANT_LADDER = {
    "float32": 2e-5,          # storage == compute: exact
    "bfloat16": 2e-2,         # 8-bit mantissa on K/V values
    "int8": 8e-2,             # symmetric absmax, per-(slot, kv-head) scale
    "fp8_e4m3": 2.5e-1,       # 3-bit mantissa, same scale granularity
}

QUANT_CASES = [
    # (bs, nb, reqs=((ctx, chunk), ...), window, softcap)
    (8, 4, ((25, 5), (9, 1)), None, None),
    (16, 3, ((33, 33), (40, 1), (17, 1)), None, 30.0),
    (32, 2, ((50, 11), (33, 1)), 12, None),
    (64, 2, ((100, 4), (90, 1)), 20, 50.0),
]


def _quantize_pool(kp, vp, kv_dtype):
    """Pool leaves at the target storage dtype (+ scales when quantized)."""
    from repro.kernels.decode_attention.quant import quantize_kv
    if kv_dtype in ("float32", "bfloat16"):
        dt = jnp.dtype(kv_dtype)
        return kp.astype(dt), vp.astype(dt), None, None
    kq, ks = quantize_kv(kp, kv_dtype)
    vq, vs = quantize_kv(vp, kv_dtype)
    return kq, vq, ks, vs


@pytest.mark.parametrize("kv_dtype", ["float32", "bfloat16", "int8",
                                      "fp8_e4m3"])
@pytest.mark.parametrize("bs,nb,reqs,win,cap", QUANT_CASES)
def test_ragged_quantized_pool_parity(kv_dtype, bs, nb, reqs, win, cap):
    from repro.kernels.decode_attention.ops import (
        ragged_paged_attention_quant_ref)
    H, K, D = 4, 2, 64
    rng = np.random.default_rng(bs + len(reqs))
    ctxs = [c for c, _ in reqs]
    N = 1 + sum(-(-c // bs) for c in ctxs) + 2
    ks_ = jax.random.split(jax.random.PRNGKey(bs), 3)
    T = sum(ch for _, ch in reqs) + 2                   # 2 pad lanes
    q = jax.random.normal(ks_[0], (T, H, D), jnp.float32)
    kp = jax.random.normal(ks_[1], (N, bs, K, D), jnp.float32)
    vp = jax.random.normal(ks_[2], (N, bs, K, D), jnp.float32)
    bt = jnp.asarray(_random_block_tables(rng, N, bs, nb, ctxs))
    rows = np.full(T, -1, np.int32)
    tpos = np.full(T, -1, np.int32)
    n = 0
    for r, (ctx, chunk) in enumerate(reqs):
        rows[n:n + chunk] = r
        tpos[n:n + chunk] = np.arange(ctx - chunk, ctx)
        n += chunk
    rows, tpos = jnp.asarray(rows), jnp.asarray(tpos)

    kq, vq, kscale, vscale = _quantize_pool(kp, vp, kv_dtype)
    out = ragged_paged_attention(q, kq, vq, bt, rows, tpos, k_scale=kscale,
                                 v_scale=vscale, window=win, softcap=cap,
                                 interpret=True)
    if kscale is None:
        oracle = ragged_paged_attention_ref(q, kq, vq, bt, rows, tpos,
                                            window=win, softcap=cap)
    else:
        oracle = ragged_paged_attention_quant_ref(
            q, kq, vq, kscale, vscale, bt, rows, tpos, window=win,
            softcap=cap)
    # (1) kernel vs same-precision oracle: the unquantized tolerance
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=2e-5, rtol=2e-5)
    # (2) quantized result vs the f32-pool truth: the documented ladder
    truth = ragged_paged_attention_ref(q, kp, vp, bt, rows, tpos,
                                       window=win, softcap=cap)
    tol = QUANT_LADDER[kv_dtype]
    np.testing.assert_allclose(np.asarray(out)[:n], np.asarray(truth)[:n],
                               atol=tol, rtol=tol)
    # (3) pad lanes are exact zeros at EVERY precision
    assert np.all(np.asarray(out)[n:] == 0)


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8_e4m3"])
def test_paged_decode_quantized_parity(kv_dtype):
    """Single-token decode (the batched engine path) with a quantized pool."""
    from repro.kernels.decode_attention.ops import (
        paged_decode_attention_quant_ref)
    B, H, K, D, bs, nb = 3, 4, 2, 64, 16, 8
    ctxs = (100, 17, 64)
    rng = np.random.default_rng(5)
    N = 1 + sum(-(-c // bs) for c in ctxs) + 2
    ks_ = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks_[0], (B, H, D), jnp.float32)
    kp = jax.random.normal(ks_[1], (N, bs, K, D), jnp.float32)
    vp = jax.random.normal(ks_[2], (N, bs, K, D), jnp.float32)
    bt = jnp.asarray(_random_block_tables(rng, N, bs, nb, ctxs))
    qpos = jnp.asarray([c - 1 for c in ctxs], jnp.int32)
    kq, vq, kscale, vscale = _quantize_pool(kp, vp, kv_dtype)
    out = paged_decode_attention(q, kq, vq, bt, qpos, k_scale=kscale,
                                 v_scale=vscale, interpret=True)
    oracle = paged_decode_attention_quant_ref(q, kq, vq, kscale, vscale,
                                              bt, qpos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=2e-5, rtol=2e-5)
    truth = paged_decode_attention_ref(q, kp, vp, bt, qpos)
    tol = QUANT_LADDER[kv_dtype]
    np.testing.assert_allclose(np.asarray(out), np.asarray(truth),
                               atol=tol, rtol=tol)


def test_quantize_kv_roundtrip_properties():
    """Unit contract of the quantizer: zero vectors round-trip to exact
    zeros with scale 1 (untouched pool blocks stay null), dequantized
    error is bounded by half a quantization step per element, and
    quantization is deterministic (same input → same bits)."""
    from repro.kernels.decode_attention.quant import (dequantize_kv,
                                                      quantize_kv)
    x = np.random.default_rng(0).normal(size=(5, 8, 2, 64)).astype(np.float32)
    x[2] = 0.0                                     # an all-zero block
    for name, step in (("int8", 1 / 127.0), ("fp8_e4m3", 1 / 8.0)):
        q, s = quantize_kv(jnp.asarray(x), name)
        q2, s2 = quantize_kv(jnp.asarray(x), name)
        assert np.array_equal(np.asarray(q), np.asarray(q2))
        assert np.array_equal(np.asarray(s), np.asarray(s2))
        assert np.all(np.asarray(s)[2] == 1.0)
        y = np.asarray(dequantize_kv(q, s))
        assert np.all(y[2] == 0.0)
        # |x - dq| <= (quant step) * amax per (token, head) row
        amax = np.abs(x).max(axis=-1, keepdims=True)
        assert np.all(np.abs(x - y) <= step * amax + 1e-7)


def test_ragged_early_out_padding_invariance():
    """The per-token num_blocks early-out must be EXACT: widening the block
    tables with extra -1 columns (a larger nb grid whose tail every token
    skips) and mixing rows of very different lengths must be bit-identical
    to the tight layout."""
    H, K, D, bs = 4, 2, 64, 8
    ctxs = (60, 3, 17)
    rng = np.random.default_rng(3)
    nb = max(-(-c // bs) for c in ctxs)
    N = 1 + sum(-(-c // bs) for c in ctxs) + 2
    ks_ = jax.random.split(jax.random.PRNGKey(4), 3)
    T = 5
    q = jax.random.normal(ks_[0], (T, H, D), jnp.float32)
    kp = jax.random.normal(ks_[1], (N, bs, K, D), jnp.float32)
    vp = jax.random.normal(ks_[2], (N, bs, K, D), jnp.float32)
    bt = _random_block_tables(rng, N, bs, nb, ctxs)
    rows = jnp.asarray([0, 1, 2, 2, -1], jnp.int32)
    tpos = jnp.asarray([59, 2, 15, 16, -1], jnp.int32)
    tight = ragged_paged_attention(q, kp, vp, jnp.asarray(bt), rows, tpos,
                                   interpret=True)
    wide = np.concatenate([bt, np.full((len(ctxs), 5), -1, np.int32)],
                          axis=1)
    padded = ragged_paged_attention(q, kp, vp, jnp.asarray(wide), rows,
                                    tpos, interpret=True)
    assert np.array_equal(np.asarray(tight), np.asarray(padded))
    # and the tight layout itself still matches the oracle
    ref = ragged_paged_attention_ref(q, kp, vp, jnp.asarray(bt), rows, tpos)
    np.testing.assert_allclose(np.asarray(tight), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_suggest_block_size_monotone():
    """Tuning hook sanity: bigger VMEM budgets never suggest smaller
    blocks, and the suggestion always fits the budget it was given."""
    from repro.kernels.decode_attention.kernel import suggest_block_size
    prev = 0
    for budget in (1 << 14, 1 << 16, 1 << 20, 1 << 24):
        bs = suggest_block_size(128, 8, vmem_budget_bytes=budget)
        assert bs >= prev
        prev = bs
    assert suggest_block_size(128, 8, vmem_budget_bytes=1 << 24) == 512

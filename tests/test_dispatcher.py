"""Dispatcher (paper Fig 2): trie match → queue → upcall; RR vs FIFO."""
import threading
import time

import pytest

from repro.core import (CascadeObject, DispatchPolicy, Dispatcher,
                        LambdaHandle, UpcallThreadPool)


def make(n_threads=4):
    pool = UpcallThreadPool(n_threads)
    return pool, Dispatcher(pool)


def test_dispatch_and_result():
    pool, d = make()
    d.register(LambdaHandle("f", "/p", lambda o, ev: o.payload + b"!"))
    evs = d.dispatch(CascadeObject(key="/p/k", payload=b"hi"))
    assert len(evs) == 1
    evs[0].completion.wait(5)
    assert evs[0].result == b"hi!"
    pool.stop()


def test_multi_prefix_multi_upcall():
    """One object matching several prefixes triggers several lambdas."""
    pool, d = make()
    d.register(LambdaHandle("a", "/p", lambda o, ev: "a"))
    d.register(LambdaHandle("b", "/p/q", lambda o, ev: "b"))
    evs = d.dispatch(CascadeObject(key="/p/q/k", payload=b""))
    assert {ev.handle.name for ev in evs} == {"a", "b"}
    for ev in evs:
        ev.completion.wait(5)
    pool.stop()


def test_fifo_same_key_same_thread_ordered():
    """FIFO dispatch: same-key objects run on one thread, in order."""
    pool, d = make(n_threads=4)
    seen: list[int] = []
    lock = threading.Lock()

    def lam(o, ev):
        with lock:
            seen.append(int(o.payload))
        time.sleep(0.001)

    d.register(LambdaHandle("f", "/cam", lam, dispatch=DispatchPolicy.FIFO))
    evs = []
    for i in range(20):
        evs += d.dispatch(CascadeObject(key="/cam/0/frame", payload=str(i).encode()))
    for ev in evs:
        ev.completion.wait(5)
    assert seen == list(range(20))
    pool.stop()


def test_rr_spreads_across_queues():
    pool, d = make(n_threads=4)
    used = set()
    lock = threading.Lock()

    def lam(o, ev):
        with lock:
            used.add(threading.current_thread().name)

    d.register(LambdaHandle("f", "/p", lam, dispatch=DispatchPolicy.ROUND_ROBIN))
    evs = []
    for i in range(16):
        evs += d.dispatch(CascadeObject(key=f"/p/{i}", payload=b""))
    for ev in evs:
        ev.completion.wait(5)
    assert len(used) == 4  # all upcall threads participated
    pool.stop()


def test_fifo_affinity_queue_hash_groups_sessions():
    """Pool-configurable FIFO pick: with a queue_hash over the session
    prefix, ALL of a session's keys share one upcall thread (mirroring the
    store-level affinity member pick), even though the full-key hash would
    scatter them."""
    import functools

    from repro.core.pools import affinity_shard_hash

    pool, d = make(n_threads=4)
    by_session: dict[str, set[str]] = {}
    lock = threading.Lock()

    def lam(o, ev):
        sess = o.key.split("/")[2]
        with lock:
            by_session.setdefault(sess, set()).add(
                threading.current_thread().name)

    d.register(LambdaHandle(
        "f", "/req", lam, dispatch=DispatchPolicy.FIFO,
        queue_hash=functools.partial(affinity_shard_hash, depth=2)))
    evs = []
    for sess in ("alice", "bob", "carol", "dave"):
        for i in range(6):
            evs += d.dispatch(CascadeObject(key=f"/req/{sess}/r{i}",
                                            payload=b""))
    for ev in evs:
        ev.completion.wait(5)
    assert all(len(threads) == 1 for threads in by_session.values()), \
        by_session
    pool.stop()


def test_queue_depth_buildup_under_blocked_upcall_thread():
    """Per-queue depth introspection: a blocked upcall thread shows its
    backlog build up — the running event PLUS everything queued behind it —
    and the depth falls back to zero once the lambda releases.  This is the
    signal bounded-admission layers watermark against, so it gets its own
    unit test independent of the serving layer that consumes it."""
    pool, d = make(n_threads=2)
    release = threading.Event()
    d.register(LambdaHandle("f", "/p", lambda o, ev: release.wait(5),
                            dispatch=DispatchPolicy.FIFO))
    evs = []
    for i in range(5):
        evs += d.dispatch(CascadeObject(key="/p/k", payload=b""))
    # FIFO same-key → ONE queue: 1 in-flight + 4 queued, other queue empty
    depths = d.queue_depths()
    assert sorted(depths) == [0, 5], depths
    assert d.queue_depth() == 5
    release.set()
    for ev in evs:
        ev.completion.wait(5)
    # completion fires AFTER the depth decrement, so drained means zero
    assert d.queue_depth() == 0
    assert d.queue_depths() == [0, 0]
    pool.stop()


def test_queue_depth_counts_only_the_blocked_queue():
    """Traffic on the healthy thread drains to zero while one FIFO key's
    queue stays backed up — depth is per queue, not a global gauge."""
    import zlib

    pool, d = make(n_threads=2)
    release = threading.Event()
    seen = threading.Event()

    def slow(o, ev):
        seen.set()
        release.wait(5)

    d.register(LambdaHandle("slow", "/cam", slow, dispatch=DispatchPolicy.FIFO))
    d.register(LambdaHandle("fast", "/other", lambda o, ev: None,
                            dispatch=DispatchPolicy.FIFO))
    blocked_qi = zlib.crc32(b"/cam/0") % 2
    # a key whose FIFO hash lands on the OTHER (healthy) queue
    fast_key = next(f"/other/{i}" for i in range(32)
                    if zlib.crc32(f"/other/{i}".encode()) % 2 != blocked_qi)
    blocked = []
    for i in range(3):
        blocked += d.dispatch(CascadeObject(key="/cam/0", payload=b""))
    assert seen.wait(5)
    fast = []
    for i in range(8):
        fast += d.dispatch(CascadeObject(key=fast_key, payload=b""))
    for ev in fast:
        ev.completion.wait(5)
    depths = d.queue_depths()
    assert depths[blocked_qi] == 3       # still wedged
    assert depths[1 - blocked_qi] == 0   # healthy queue drained
    release.set()
    for ev in blocked:
        ev.completion.wait(5)
    assert d.queue_depth() == 0
    pool.stop()


def test_error_surfaces_not_swallowed():
    pool, d = make()

    def boom(o, ev):
        raise ValueError("boom")

    d.register(LambdaHandle("f", "/p", boom))
    [ev] = d.dispatch(CascadeObject(key="/p/k", payload=b""))
    ev.completion.wait(5)
    assert isinstance(ev.error, ValueError)
    pool.stop()


def test_event_timestamps_ordered():
    pool, d = make()
    d.register(LambdaHandle("f", "/p", lambda o, ev: None))
    [ev] = d.dispatch(CascadeObject(key="/p/k", payload=b""))
    ev.completion.wait(5)
    assert ev.enqueued_ns <= ev.dequeued_ns <= ev.done_ns
    pool.stop()


def test_poisoned_lambda_contained_counted_thread_survives():
    """A lambda that ALWAYS raises must be contained per event — the error
    rides on the event, ``stats().upcall_errors`` counts it, and the upcall
    thread keeps serving later events on the same queue."""
    from repro.serving.faults import poisoned_lambda

    pool, d = make(n_threads=1)       # one queue: poison and probe share it
    d.register(LambdaHandle("poison", "/bad",
                            poisoned_lambda(RuntimeError, "injected")))
    d.register(LambdaHandle("ok", "/good", lambda o, ev: "alive"))
    bad = []
    for i in range(5):
        bad += d.dispatch(CascadeObject(key="/bad/k", payload=b""))
    [good] = d.dispatch(CascadeObject(key="/good/k", payload=b""))
    good.completion.wait(5)
    for ev in bad:
        ev.completion.wait(5)
        assert isinstance(ev.error, RuntimeError)
    # the thread survived the poison: the later event still ran
    assert good.result == "alive" and good.error is None
    st = d.stats()
    assert st["upcall_errors"] == 5
    assert st["upcall_errors_per_queue"] == [5]
    assert st["dispatched"] == 6
    pool.stop()

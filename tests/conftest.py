"""Tier-1 wiring for the cascade-lint runtime sanitizers.

For the threaded suites (dispatcher / cluster / devstore / store / log)
every test runs with:

- the **lock-order tracker** installed: all ``threading.Lock``/``RLock``
  created by ``repro.*`` modules during the test are wrapped, the
  acquisition graph is recorded, and any cycle (lock-order inversion that
  could deadlock under another schedule) or blocking self-re-acquire
  fails the test at teardown — even if the deadlocking schedule never
  actually ran;
- the **sync-site sanitizer** installed: a ``jax.device_get`` issued from
  fast-path code (``repro.serving``/``repro.models``) anywhere other
  than ``ServeEngine._to_host`` fails the test.

Other suites are untouched: the patch is per-test and uninstalled in a
finally block.
"""
import pytest

from repro.analysis.sanitizer import LockOrderTracker, SyncSiteSanitizer

SANITIZED_MODULES = {
    "test_dispatcher",
    "test_faults",
    "test_serve_cluster",
    "test_serve_node",
    "test_devstore_retention",
    "test_fastpath_devstore",
    "test_store_core",
    "test_log",
}


@pytest.fixture(autouse=True)
def _cascade_sanitizers(request):
    mod = request.module.__name__.rsplit(".", 1)[-1]
    if mod not in SANITIZED_MODULES:
        yield
        return
    tracker = LockOrderTracker()
    sync = SyncSiteSanitizer()
    tracker.install()
    sync.install()
    try:
        yield
    finally:
        tracker.uninstall()
        sync.uninstall()
    assert not tracker.violations, (
        "lock-order sanitizer: " + "; ".join(tracker.violations))
    assert not sync.violations, (
        "sync-site sanitizer: " + "; ".join(sync.violations))

"""Preemption + resume: bit-parity, block accounting, spill-pool lifecycle.

The acceptance gate for the issue-queue scheduler's preemption: a greedy
stream interrupted by a preemption — KV spilled to the host-side pool and
restored via adopt(), OR lost and replayed from the folded prompt — must be
bit-identical to the uninterrupted run, with the allocator drained to
exactly zero and every spilled block freed exactly once.
"""
import time

import jax
import numpy as np
import pytest

from repro.core.pools import PoolSpec
from repro.core.store import CascadeStore, SpillPool, Worker
from repro.models import ModelConfig, init_params
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import Request, SLO_BATCH, SLO_INTERACTIVE

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab_size=128, dtype="float32",
                  q_chunk=16)
MAX_NEW_BATCH = 8
MAX_NEW_INTER = 3


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _prompts(seed):
    rng = np.random.default_rng(seed)
    return {
        "b0": rng.integers(1, CFG.vocab_size, (8,)).astype(np.int32),
        "b1": rng.integers(1, CFG.vocab_size, (8,)).astype(np.int32),
        "i0": rng.integers(1, CFG.vocab_size, (4,)).astype(np.int32),
    }


def _req(rid, prompt, slo):
    max_new = MAX_NEW_INTER if slo == SLO_INTERACTIVE else MAX_NEW_BATCH
    return Request(request_id=rid, session_key=f"sess-{rid}", prompt=prompt,
                   max_new_tokens=max_new, slo=slo)


def _baseline(params, prompts):
    """Uninterrupted greedy run with slack capacity: no pressure, no
    preemption — the reference streams (greedy depends only on the prompt,
    so slot/tick placement differences cannot change them)."""
    eng = ServeEngine(CFG, params, n_slots=8, max_len=48, temperature=0.0,
                      block_size=4, num_blocks=64, prefix_cache=False)
    done = {}
    eng.on_complete = lambda r: done.setdefault(r.request_id, r)
    for rid in ("b0", "b1", "i0"):
        eng.submit(_req(rid, prompts[rid],
                        SLO_INTERACTIVE if rid == "i0" else SLO_BATCH))
    eng.run_until_drained()
    assert eng.stats.preemptions == 0
    assert eng.stats.host_syncs == eng.stats.ticks
    return {rid: list(r.tokens) for rid, r in done.items()}


def _preempt_run(params, prompts, spill_pool):
    """Tight engine (2 slots, 10 usable blocks): both batch requests fill
    it, then an interactive arrival forces a preemption mid-decode."""
    eng = ServeEngine(CFG, params, n_slots=2, max_len=48, temperature=0.0,
                      block_size=4, num_blocks=11, prefix_cache=False,
                      spill_pool=spill_pool, preempt=True)
    done = {}
    eng.on_complete = lambda r: done.setdefault(r.request_id, r)
    eng.submit(_req("b0", prompts["b0"], SLO_BATCH))
    eng.submit(_req("b1", prompts["b1"], SLO_BATCH))
    stop = time.monotonic() + 30
    while not (len(eng.live) == 2
               and all(r.tokens for r in eng.live.values())):
        eng.tick()
        assert time.monotonic() < stop, "batch requests never went live"
    eng.submit(_req("i0", prompts["i0"], SLO_INTERACTIVE))
    eng.run_until_drained()
    assert {r.error for r in done.values()} == {None}
    return eng, {rid: list(r.tokens) for rid, r in done.items()}


def _assert_drained_exactly(eng):
    """Exact block accounting: the drained pool holds nothing (prefix cache
    off), every slot is free, and the free list holds each block exactly
    once — a double-free on the spilled tail would show up as a duplicate
    (or as blocks_in_use going negative via an over-long free list)."""
    alloc = eng.cm.alloc
    assert alloc.blocks_in_use == 0
    assert all(not s.active for s in eng.cm.slots)
    assert len(alloc.free) == len(set(alloc.free)) == eng.cm.num_blocks - 1
    assert eng.cm.available_for_admission() == alloc.available()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_preempt_resume_via_spill_pool_bit_identical(params, seed):
    prompts = _prompts(seed)
    ref = _baseline(params, prompts)
    pool = SpillPool(capacity_blocks=64)
    eng, got = _preempt_run(params, prompts, pool)
    assert got == ref
    assert eng.stats.preemptions >= 1
    assert eng.stats.resumes >= 1            # restored via adopt, not replay
    assert eng.stats.spilled_blocks >= 1
    assert eng.stats.adopted_sessions == eng.stats.resumes
    # sync discipline: the extra pulls are exactly the preemption spills
    assert eng.stats.spill_syncs == eng.stats.spilled_sessions >= 1
    assert eng.stats.host_syncs == eng.stats.ticks + eng.stats.spill_syncs
    _assert_drained_exactly(eng)
    # spill-pool lifecycle: everything parked was unparked (resume) —
    # nothing leaked, nothing evicted at this capacity
    assert pool.blocks == 0 and pool.evicted == 0
    assert pool.parked == pool.unparked >= 1
    # per-class queue-wait histograms saw both classes
    assert set(eng.stats.queue_wait_s) == {SLO_BATCH, SLO_INTERACTIVE}


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_preempt_resume_via_replay_fallback_bit_identical(params, seed):
    """Capacity-0 pool: every park is refused, so the victim's emissions
    fold into its prompt and the resume replays — still bit-identical."""
    prompts = _prompts(seed)
    ref = _baseline(params, prompts)
    pool = SpillPool(capacity_blocks=0)
    eng, got = _preempt_run(params, prompts, pool)
    assert got == ref
    assert eng.stats.preemptions >= 1
    assert eng.stats.resumes == 0            # no adopt: replay path only
    assert eng.stats.adopted_sessions == 0
    assert pool.parked == 0 and pool.blocks == 0
    # the spill still happened (and was counted) before the park refusal
    assert eng.stats.host_syncs == eng.stats.ticks + eng.stats.spill_syncs
    _assert_drained_exactly(eng)


def test_preempt_without_pool_keeps_strict_sync_invariant(params):
    """No pool at all: the victim is never spilled (no wasted sync) — it
    folds and replays, and host_syncs == ticks stays STRICT."""
    prompts = _prompts(3)
    ref = _baseline(params, prompts)
    eng, got = _preempt_run(params, prompts, None)
    assert got == ref
    assert eng.stats.preemptions >= 1
    assert eng.stats.spill_syncs == 0 and eng.stats.spilled_blocks == 0
    assert eng.stats.host_syncs == eng.stats.ticks
    _assert_drained_exactly(eng)


def test_preempt_requires_paged_path(params):
    with pytest.raises(ValueError, match="preemption"):
        ServeEngine(CFG, params, paged=False, preempt=True)


# ============================================================ SpillPool unit
def test_spill_pool_park_unpark_discard_accounting():
    pool = SpillPool(capacity_blocks=8)
    assert pool.park("a", "kv-a", 3)
    assert pool.park("b", "kv-b", 4)
    assert pool.blocks == 7 and pool.has("a") and pool.has("b")
    assert pool.unpark("a") == "kv-a"
    assert pool.blocks == 4 and not pool.has("a")
    assert pool.unpark("a") is None          # absent reads as None
    pool.discard("b")
    assert pool.blocks == 0
    assert pool.stats() == {"spill_pool_blocks": 0, "spill_pool_parked": 2,
                            "spill_pool_unparked": 1, "spill_pool_evicted": 0}


def test_spill_pool_evicts_oldest_first_and_refuses_oversized():
    pool = SpillPool(capacity_blocks=8)
    assert not pool.park("huge", "kv", 9)    # can never fit: caller replays
    assert pool.park("a", "kv-a", 4)
    assert pool.park("b", "kv-b", 4)
    assert pool.park("c", "kv-c", 4)         # evicts a (oldest) to fit
    assert not pool.has("a") and pool.has("b") and pool.has("c")
    assert pool.evicted == 1 and pool.blocks == 8
    # re-park replaces rather than double-counting
    assert pool.park("c", "kv-c2", 2)
    assert pool.blocks == 6 and pool.unpark("c") == "kv-c2"


def test_spill_pool_store_backed_publishes_and_tombstones():
    w = Worker(0, n_upcall_threads=1)
    store = CascadeStore([w])
    store.create_pool(PoolSpec(path="/spill/m"))
    try:
        pool = SpillPool(capacity_blocks=8, store=store, prefix="/spill/m")
        pool.park("r1", {"kv": 1}, 2)
        obj = store.get("/spill/m/r1")
        assert obj is not None and obj.payload == {"kv": 1}
        assert pool.unpark("r1") == {"kv": 1}
        # no per-key delete on the store: unpark writes a None TOMBSTONE,
        # and a tombstone must read as absent through the pool
        obj = store.get("/spill/m/r1")
        assert obj is not None and obj.payload is None
        assert pool.unpark("r1") is None
        # a SIBLING pool instance resolves a park it never saw via the store
        pool.park("r2", {"kv": 2}, 2)
        sibling = SpillPool(capacity_blocks=8, store=store, prefix="/spill/m")
        assert sibling.unpark("r2") == {"kv": 2}
        assert sibling.unpark("r2") is None  # tombstoned for everyone
    finally:
        store.close()

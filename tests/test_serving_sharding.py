"""Serving engine + sharding rules: continuous batching, FIFO sessions,
logical-axis resolution properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, SHAPES, get_config
from repro.core.pools import DispatchPolicy
from repro.launch.sharding import leaf_spec, make_rules, tree_shardings
from repro.models import ModelConfig, init_params, param_axes
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import Request, Scheduler

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab_size=128, dtype="float32",
                  q_chunk=16)


# ------------------------------------------------------------------ serving
def test_engine_drains_and_counts():
    params = init_params(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(CFG, params, n_slots=3, max_len=32)
    rng = np.random.default_rng(0)
    for i in range(7):  # more requests than slots → queueing + reuse
        eng.submit(Request(request_id=f"r{i}", session_key=f"s{i}",
                           prompt=rng.integers(0, 128, (5,)).astype(np.int32),
                           max_new_tokens=4))
    eng.run_until_drained()
    assert eng.stats.prefills == 7
    assert eng.stats.tokens_out == 7 * 4
    assert eng.cm.n_active == 0


def test_engine_greedy_matches_forward():
    """Engine's first generated token == argmax of a plain forward pass."""
    from repro.models import forward

    params = init_params(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(CFG, params, n_slots=2, max_len=32)
    prompt = np.arange(1, 9, dtype=np.int32)
    eng.submit(Request(request_id="r", session_key="s", prompt=prompt,
                       max_new_tokens=1))
    eng.run_until_drained()
    toks = jnp.asarray(prompt)[None, :]
    pos = jnp.arange(8)[None, :]
    logits, _ = forward(params, toks, pos, CFG, mode="score")
    expected = int(jnp.argmax(logits[0, -1]))
    [req] = [r for r in [*eng.live.values()]] if eng.live else [None]
    # request completed; check recorded token
    assert eng.stats.tokens_out >= 1


def test_scheduler_fifo_pins_sessions():
    s = Scheduler(policy=DispatchPolicy.FIFO, n_replicas=4)
    reps = {s.submit(Request(request_id=f"r{i}", session_key="session-A",
                             prompt=None)) for i in range(8)}
    assert len(reps) == 1  # same session always lands on one replica
    reps_b = {s.submit(Request(request_id=f"q{i}", session_key=f"sess-{i}",
                               prompt=None)) for i in range(16)}
    assert len(reps_b) > 1  # distinct sessions spread


def test_scheduler_rr_balances():
    s = Scheduler(policy=DispatchPolicy.ROUND_ROBIN, n_replicas=3)
    counts = [0, 0, 0]
    for i in range(9):
        counts[s.submit(Request(request_id=f"r{i}", session_key="x",
                                prompt=None))] += 1
    assert counts == [3, 3, 3]


def test_admission_respects_budget():
    s = Scheduler(n_replicas=1, prefill_budget=2)
    for i in range(5):
        s.submit(Request(request_id=f"r{i}", session_key="x", prompt=None))
    first = s.admit(0, free_slots=4)
    assert len(first) == 2  # prefill budget bounds admissions per tick
    assert s.pending(0) == 3


# ----------------------------------------------------------------- sharding
def _mesh(shape=(4, 2), axes=("data", "model")):
    n = shape[0] * shape[1]
    if len(jax.devices()) < n:
        pytest.skip("needs multi-device")
    return jax.make_mesh(shape, axes)


def test_leaf_spec_dedups_mesh_axes():
    rules = {"embed": "model", "ffn": "model", "heads": "model", None: None}
    spec = leaf_spec(("embed", "ffn"), rules)
    # ffn has higher priority → gets model; embed must NOT reuse it
    assert spec == jax.sharding.PartitionSpec(None, "model")


def test_leaf_spec_priority_order():
    rules = {"expert": "data", "embed": "data", "ffn": "model", None: None}
    spec = leaf_spec(("expert", "embed", "ffn"), rules)
    assert spec == jax.sharding.PartitionSpec("data", None, "model")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_rules_respect_divisibility(arch):
    """No rule may assign an axis that does not divide the dimension."""
    cfg = get_config(arch)

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)

    rules = make_rules(cfg, FakeMesh(), batch=256)
    model = 16
    if rules["heads"] == "model":
        assert cfg.n_heads % model == 0
    if rules["kv_heads"] == "model":
        assert cfg.n_kv_heads % model == 0
    if rules["vocab"] == "model":
        assert cfg.vocab_size % model == 0
    if cfg.ssm_state and rules["ssm_heads"] == "model":
        assert cfg.ssm_heads % model == 0


def test_param_axes_cover_all_archs():
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        axes = param_axes(cfg)
        flat_p = jax.tree.leaves(params)
        flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple)
                                 and all(isinstance(e, (str, type(None))) for e in x))
        assert len(flat_p) == len(flat_a)
        for p, a in zip(flat_p, flat_a):
            assert p.ndim == len(a)

"""Serving engine + sharding rules: continuous batching, FIFO sessions,
logical-axis resolution properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, SHAPES, get_config
from repro.core.pools import DispatchPolicy
from repro.launch.sharding import leaf_spec, make_rules, tree_shardings
from repro.models import ModelConfig, init_params, param_axes
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import Request, Scheduler

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab_size=128, dtype="float32",
                  q_chunk=16)


# ------------------------------------------------------------------ serving
def test_engine_drains_and_counts():
    params = init_params(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(CFG, params, n_slots=3, max_len=32)
    rng = np.random.default_rng(0)
    for i in range(7):  # more requests than slots → queueing + reuse
        eng.submit(Request(request_id=f"r{i}", session_key=f"s{i}",
                           prompt=rng.integers(0, 128, (5,)).astype(np.int32),
                           max_new_tokens=4))
    eng.run_until_drained()
    assert eng.stats.prefills == 7
    assert eng.stats.tokens_out == 7 * 4
    assert eng.cm.n_active == 0


def test_engine_greedy_matches_forward():
    """Engine's first generated token == argmax of a plain forward pass."""
    from repro.models import forward

    params = init_params(jax.random.PRNGKey(0), CFG)
    eng = ServeEngine(CFG, params, n_slots=2, max_len=32)
    prompt = np.arange(1, 9, dtype=np.int32)
    req = Request(request_id="r", session_key="s", prompt=prompt,
                  max_new_tokens=1)
    eng.submit(req)
    eng.run_until_drained()
    toks = jnp.asarray(prompt)[None, :]
    pos = jnp.arange(8)[None, :]
    logits, _ = forward(params, toks, pos, CFG, mode="score")
    expected = int(jnp.argmax(logits[0, -1]))
    assert len(req.tokens) == 1
    assert int(req.tokens[0]) == expected
    assert eng.stats.host_syncs == eng.stats.ticks


def test_scheduler_fifo_pins_sessions():
    s = Scheduler(policy=DispatchPolicy.FIFO, n_replicas=4)
    reps = {s.submit(Request(request_id=f"r{i}", session_key="session-A",
                             prompt=None)) for i in range(8)}
    assert len(reps) == 1  # same session always lands on one replica
    reps_b = {s.submit(Request(request_id=f"q{i}", session_key=f"sess-{i}",
                               prompt=None)) for i in range(16)}
    assert len(reps_b) > 1  # distinct sessions spread


def test_scheduler_rr_balances():
    s = Scheduler(policy=DispatchPolicy.ROUND_ROBIN, n_replicas=3)
    counts = [0, 0, 0]
    for i in range(9):
        counts[s.submit(Request(request_id=f"r{i}", session_key="x",
                                prompt=None))] += 1
    assert counts == [3, 3, 3]


def test_admission_respects_budget():
    s = Scheduler(n_replicas=1, prefill_budget=2)
    for i in range(5):
        s.submit(Request(request_id=f"r{i}", session_key="x", prompt=None))
    first = s.admit(0, free_slots=4)
    assert len(first) == 2  # prefill budget bounds admissions per tick
    assert s.pending(0) == 3


# ----------------------------------------------------------------- sharding
def _mesh(shape=(4, 2), axes=("data", "model")):
    n = shape[0] * shape[1]
    if len(jax.devices()) < n:
        pytest.skip("needs multi-device")
    return jax.make_mesh(shape, axes)


def test_leaf_spec_dedups_mesh_axes():
    rules = {"embed": "model", "ffn": "model", "heads": "model", None: None}
    spec = leaf_spec(("embed", "ffn"), rules)
    # ffn has higher priority → gets model; embed must NOT reuse it
    assert spec == jax.sharding.PartitionSpec(None, "model")


def test_leaf_spec_priority_order():
    rules = {"expert": "data", "embed": "data", "ffn": "model", None: None}
    spec = leaf_spec(("expert", "embed", "ffn"), rules)
    assert spec == jax.sharding.PartitionSpec("data", None, "model")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_rules_respect_divisibility(arch):
    """No rule may assign an axis that does not divide the dimension."""
    cfg = get_config(arch)

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)

    rules = make_rules(cfg, FakeMesh(), batch=256)
    model = 16
    if rules["heads"] == "model":
        assert cfg.n_heads % model == 0
    if rules["kv_heads"] == "model":
        assert cfg.n_kv_heads % model == 0
    if rules["vocab"] == "model":
        assert cfg.vocab_size % model == 0
    if cfg.ssm_state and rules["ssm_heads"] == "model":
        assert cfg.ssm_heads % model == 0


def test_param_axes_cover_all_archs():
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        axes = param_axes(cfg)
        flat_p = jax.tree.leaves(params)
        flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple)
                                 and all(isinstance(e, (str, type(None))) for e in x))
        assert len(flat_p) == len(flat_a)
        for p, a in zip(flat_p, flat_a):
            assert p.ndim == len(a)


# ----------------------------------------------------------- mesh slices
# Multi-device tests below need >= 2 fake CPU devices: run them via
# ``make test-sharded`` (XLA_FLAGS=--xla_force_host_platform_device_count=8);
# on a plain single-device session they skip.
def test_make_host_mesh_rejects_non_divisible():
    from repro.launch.mesh import make_host_mesh

    with pytest.raises(ValueError,
                       match=r"model=4 does not divide n_devices=6"):
        make_host_mesh(6, model=4)
    with pytest.raises(ValueError, match=r"strand 2"):
        make_host_mesh(6, model=4)


def test_mesh_slices_are_disjoint_and_bounded():
    from repro.launch.mesh import mesh_slices

    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs multi-device (make test-sharded)")
    slices = mesh_slices(2, 1)
    sets = [set(m.devices.flat) for m in slices]
    assert sets[0].isdisjoint(sets[1])
    with pytest.raises(ValueError, match="available"):
        mesh_slices(n + 1, 1)


def _slice_meshes(n_slices, devices_per_slice):
    from repro.launch.mesh import mesh_slices

    need = n_slices * devices_per_slice
    if len(jax.devices()) < need:
        pytest.skip(f"needs {need} devices (make test-sharded)")
    return mesh_slices(n_slices, devices_per_slice)


def _greedy_stream(eng, prompt, rid, max_new_tokens=8):
    req = Request(request_id=rid, session_key=f"s-{rid}", prompt=prompt,
                  max_new_tokens=max_new_tokens)
    eng.submit(req)
    eng.run_until_drained()
    return req, [int(t) for t in req.tokens]


def test_sharded_engine_greedy_bit_identical():
    """A model=2 sharded replica emits the bit-identical fp32 greedy stream
    of a single-device engine, keeps host_syncs == ticks on its slice, and
    its pool publishes stay zero-copy (donate_misses == 0)."""
    [mesh] = _slice_meshes(1, 2)
    params = init_params(jax.random.PRNGKey(0), CFG)
    prompt = np.arange(1, 9, dtype=np.int32)

    base = ServeEngine(CFG, params, n_slots=2, max_len=32)
    _, expected = _greedy_stream(base, prompt, rid="base")

    eng = ServeEngine(CFG, params, n_slots=2, max_len=32, mesh=mesh)
    _, got = _greedy_stream(eng, prompt, rid="sharded")

    assert got == expected and len(got) == 8
    assert eng.stats.host_syncs == eng.stats.ticks
    assert eng.cm.devstore.donate_misses == 0
    assert eng.cm.devstore.donate_hits >= eng.stats.ticks
    # the pool really is sharded over the slice: kv_heads dim on 'model'
    for leaf, sh in zip(jax.tree.leaves(eng.cm.pools),
                        jax.tree.leaves(eng.cm.pool_shardings)):
        assert len(leaf.sharding.device_set) == 2
        assert leaf.sharding == sh
        assert "model" in tuple(sh.spec)
    # params shard too (at least one leaf split over the slice)
    assert any(len(p.sharding.device_set) == 2 and
               any(ax is not None for ax in tuple(p.sharding.spec))
               for p in jax.tree.leaves(eng.params))


def test_sharded_spill_adopt_roundtrip_across_slices():
    """Spill a live session off a sharded replica and adopt it on a replica
    holding a DIFFERENT slice: every pool leaf (quantized K/V and their f32
    scales) round-trips bit-exactly, and the continued greedy stream is
    bit-identical to an uninterrupted run."""
    mesh_a, mesh_b = _slice_meshes(2, 2)
    params = init_params(jax.random.PRNGKey(0), CFG)
    prompt = np.arange(1, 9, dtype=np.int32)

    ref = ServeEngine(CFG, params, n_slots=2, max_len=32, kv_dtype="int8")
    _, expected = _greedy_stream(ref, prompt, rid="ref")

    eng_a = ServeEngine(CFG, params, n_slots=2, max_len=32, mesh=mesh_a,
                        kv_dtype="int8")
    eng_b = ServeEngine(CFG, params, n_slots=2, max_len=32, mesh=mesh_b,
                        kv_dtype="int8")
    req = Request(request_id="mig", session_key="s-mig", prompt=prompt,
                  max_new_tokens=8)
    eng_a.submit(req)
    while len(req.tokens) < 3:
        eng_a.tick()
    slot_a = req.slot
    spilled = eng_a.spill(slot_a)
    assert spilled is not None and spilled.n_blocks > 0
    # int8 pool spills 4 leaf arrays per layer stack: k, v, k_scale, v_scale
    assert len(jax.tree.leaves(spilled.blocks)) == 4
    eng_a.live.pop(slot_a)
    eng_a.cm.release(slot_a)

    assert eng_b.adopt(req, spilled)
    # round-trip: re-spilling the adopted slot off slice B returns the
    # exact bytes that left slice A, for every leaf including the scales
    back = eng_b.spill(req.slot)
    for a, b in zip(jax.tree.leaves(spilled.blocks),
                    jax.tree.leaves(back.blocks)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    eng_b.run_until_drained()
    assert [int(t) for t in req.tokens] == expected
    assert eng_a.stats.host_syncs == eng_a.stats.ticks + eng_a.stats.spill_syncs
    assert eng_b.stats.host_syncs == eng_b.stats.ticks + eng_b.stats.spill_syncs


def test_deployment_carves_disjoint_slices():
    """devices_per_replica=2 x 2 replicas: each engine owns its own slice
    (no shared devices), serves correctly, and stop() returns the devices
    to the node's pool."""
    from repro.serving.cluster import ServeCluster

    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices (make test-sharded)")
    params = init_params(jax.random.PRNGKey(0), CFG)
    with ServeCluster(CFG, params, n_replicas=2, n_slots=2, max_len=32,
                      devices_per_replica=2) as cluster:
        sets = [set(jax.tree.leaves(e.cm.pools)[0].sharding.device_set)
                for e in cluster.engines]
        assert all(len(s) == 2 for s in sets)
        assert sets[0].isdisjoint(sets[1])
        # sliced replicas cannot share one jitted program (per-slice
        # out_shardings) — each compiles its own
        assert cluster.engines[0]._mixed is not cluster.engines[1]._mixed
        rng = np.random.default_rng(0)
        for i in range(4):
            cluster.submit(f"sess-{i}", f"r{i}",
                           rng.integers(0, 128, (5,)).astype(np.int32),
                           max_new_tokens=4)
        cluster.run_until_drained()
        for i in range(4):
            out = cluster.result(f"r{i}")
            assert out is not None and len(out) == 4
        for e in cluster.engines:
            assert e.stats.host_syncs == e.stats.ticks
        assert cluster.kv_store.donate_misses == 0
        node = cluster.node
        free_before_stop = len(node._free_devices)
        cluster.dep.stop()
        assert len(node._free_devices) == free_before_stop + 4

"""Fault-tolerant fast path: injection, failover with KV migration, and
deadline-aware retry.

Runs under the PR 6 runtime sanitizers (tests/conftest.py): every test here
gets the lock-order tracker and the sync-site sanitizer — spills must pull
through ``ServeEngine._to_host`` like everything else, and no new lock can
introduce an ordering cycle.

The bit-identical tests are the heart of the failover contract: greedy
decoding makes the token stream a pure function of the prompt, so a session
migrated (KV spill + restore) or replayed (emissions folded into the prompt)
onto a sibling must produce EXACTLY the tokens an uninterrupted run does.
"""
import time

import jax
import numpy as np
import pytest

from repro.models import ModelConfig, init_params
from repro.serving.cluster import CascadeGate, CascadeRoute, ServeNode
from repro.serving.faults import (FaultInjector, FaultKind, FaultSpec,
                                  InjectedFault, ReplicaCrashed)
from repro.serving.scheduler import Request, Scheduler

LIGHT = ModelConfig(name="light", family="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                    dtype="float32", q_chunk=16)
HEAVY = ModelConfig(name="heavy", family="ssm", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                    dtype="float32")


@pytest.fixture(scope="module")
def light_params():
    return init_params(jax.random.PRNGKey(0), LIGHT)


@pytest.fixture(scope="module")
def heavy_params():
    return init_params(jax.random.PRNGKey(1), HEAVY)


def _prompts(n, seed=7, lo=4, hi=12):
    rng = np.random.default_rng(seed)
    return {f"r{i}": rng.integers(0, 128, (int(rng.integers(lo, hi)),))
            .astype(np.int32) for i in range(n)}


# =========================================================== injector unit
def test_injector_seeded_schedule_is_deterministic():
    """Negative at_tick draws are seeded: same seed → same schedule."""
    mk = lambda seed: FaultInjector(
        [FaultSpec(FaultKind.CRASH, at_tick=-10),
         FaultSpec(FaultKind.STALL, at_tick=-10)], seed=seed)
    a, b = mk(3), mk(3)
    assert [s.at_tick for s in a.specs] == [s.at_tick for s in b.specs]
    assert all(1 <= s.at_tick <= 10 for s in a.specs)


class _DummyEngine:
    crashed = False
    kv_recoverable = True


def test_injector_crash_fires_once_and_latches_one_replica():
    inj = FaultInjector([FaultSpec(FaultKind.CRASH, at_tick=2)])
    e0, e1 = _DummyEngine(), _DummyEngine()
    s0, s1 = inj.bind("m", 0), inj.bind("m", 1)
    assert s0.on_tick(e0) is None and s1.on_tick(e1) is None
    with pytest.raises(ReplicaCrashed):
        s0.on_tick(e0)                    # m/r0 reaches tick 2 first
    assert e0.crashed and e0.kv_recoverable
    # the wildcard latched onto r0: r1 never crashes
    for _ in range(5):
        assert s1.on_tick(e1) is None
    assert not e1.crashed
    assert inj.fired_log == ["crash:m/r0@tick2"]


def test_injector_submit_errors_fire_count_times_then_clear():
    inj = FaultInjector([FaultSpec(FaultKind.SUBMIT_ERROR, count=2)])
    seam = inj.bind("m", 0)
    for _ in range(2):
        with pytest.raises(InjectedFault):
            seam.on_submit()
    seam.on_submit()                      # budget spent: submits flow again
    assert len(inj.fired_log) == 2


def test_injector_stall_is_permanent_once_armed():
    inj = FaultInjector([FaultSpec(FaultKind.STALL, at_tick=3)])
    e = _DummyEngine()
    seam = inj.bind("m", 0)
    assert [seam.on_tick(e) for _ in range(2)] == [None, None]
    assert all(seam.on_tick(e) == "stall" for _ in range(4))
    assert not e.crashed                  # a wedged replica is not a crash


# ========================================================== scheduler unit
def test_scheduler_pop_expired_keeps_order_and_drain_empties():
    sched = Scheduler(n_replicas=1)
    now = time.monotonic()
    reqs = []
    for i, dl in enumerate([None, 0.001, 100.0, 0.001, None]):
        r = Request(request_id=f"r{i}", session_key="s", prompt=[1],
                    deadline_s=dl)
        r.arrived_s = now - 1.0           # 1s old: tight deadlines expired
        sched.submit(r)
        reqs.append(r)
    expired = sched.pop_expired(0)
    assert [r.request_id for r in expired] == ["r1", "r3"]
    assert [r.request_id for r in sched.waiting[0]] == ["r0", "r2", "r4"]
    assert [r.request_id for r in sched.drain(0)] == ["r0", "r2", "r4"]
    assert sched.pending(0) == 0


# =================================================== failover: bit-identical
def _run_failover(params, mode, n=6, max_new=6):
    """Submit ``n`` requests; in chaos modes, kill replica 0 once it holds
    live (decoding) sessions and let the deployment re-home them.  Returns
    (results, deployment stats, per-engine EngineStats list)."""
    with ServeNode(n_workers=2) as node:
        dep = node.deploy("light", LIGHT, params, n_replicas=2, n_slots=8,
                          max_len=64, block_size=8, num_blocks=64,
                          prefix_cache=False)
        prompts = _prompts(n)
        for i, (rid, p) in enumerate(prompts.items()):
            dep.submit(f"s{i % 3}", rid, p, max_new_tokens=max_new)
        if mode != "baseline":
            eng0 = dep.engines[0]
            stop = time.monotonic() + 30
            # wait (driving the node) until replica 0 is mid-decode with at
            # least one emitted token, so the kill lands on live KV state
            while not any(r.tokens for r in list(eng0.live.values())):
                node.step()
                assert time.monotonic() < stop, "replica 0 never went live"
            if mode == "replay":
                eng0.kv_recoverable = False
            dep.mark_down(0, "test-crash")
        node.run_until_drained()
        results = {rid: np.asarray(dep.result(rid)) for rid in prompts}
        errors = {rid: dep.error(rid) for rid in prompts}
        stats = dep.stats()
        eng_stats = [e.stats for e in dep.engines]
        cms = [e.cm for e in dep.engines]
        assert all(err is None for err in errors.values()), errors
        return results, stats, eng_stats, cms


@pytest.fixture(scope="module")
def baseline_results(light_params):
    results, stats, _, _ = _run_failover(light_params, "baseline")
    assert stats["failovers"] == 0 and stats["rehomed"] == 0
    return results


def test_crash_failover_migrates_kv_bit_identical(light_params,
                                                  baseline_results):
    """Kill a replica mid-decode with recoverable KV: its sessions spill,
    migrate, and resume on the sibling — the client-visible streams are
    bit-identical to the uninterrupted run."""
    results, st, eng_stats, cms = _run_failover(light_params, "migrate")
    for rid, toks in baseline_results.items():
        np.testing.assert_array_equal(results[rid], toks)
    assert st["failovers"] == 1 and st["down"] == {0: "test-crash"}
    assert st["rehomed"] >= 1 and st["migrated"] >= 1
    assert st["failover_failed"] == 0
    # sync discipline: the survivor keeps the strict one-sync-per-tick rule;
    # the dead replica's extra pulls are exactly its spills
    assert eng_stats[1].host_syncs == eng_stats[1].ticks
    assert eng_stats[0].host_syncs == eng_stats[0].ticks \
        + eng_stats[0].spill_syncs
    assert eng_stats[0].spilled_sessions >= st["migrated"]
    assert eng_stats[1].adopted_sessions == st["migrated"]
    # exact block accounting across spill/restore: with the prefix cache off
    # a drained pool holds NOTHING — every spilled, adopted, and evacuated
    # block was returned exactly once
    for cm in cms:
        assert cm.alloc.blocks_in_use == 0
        assert all(not s.active for s in cm.slots)
        assert cm.available_for_admission() == cm.alloc.available()


def test_crash_with_unrecoverable_kv_replays_bit_identical(light_params,
                                                           baseline_results):
    """Same kill, but the dead replica's KV is unrecoverable: sessions fold
    their emissions into the prompt and replay-prefill on the sibling —
    still bit-identical, zero spills."""
    results, st, eng_stats, cms = _run_failover(light_params, "replay")
    for rid, toks in baseline_results.items():
        np.testing.assert_array_equal(results[rid], toks)
    assert st["failovers"] == 1
    assert st["replayed"] >= 1 and st["migrated"] == 0
    assert st["failover_failed"] == 0
    # no spill happened, so BOTH replicas keep the strict invariant
    for es in eng_stats:
        assert es.host_syncs == es.ticks
        assert es.spill_syncs == 0
    for cm in cms:
        assert cm.alloc.blocks_in_use == 0


# ======================================================= injector end-to-end
def test_seeded_chaos_crash_every_request_terminal(light_params):
    """The acceptance gate: under a SEEDED injected crash, every in-flight
    request reaches a terminal state — a migrated/replayed result or a
    structured error — and the drain resolves instead of timing out."""
    inj = FaultInjector([FaultSpec(FaultKind.CRASH, deployment="light",
                                   at_tick=-6, kv_recoverable=True)], seed=11)
    with ServeNode(n_workers=2) as node:
        dep = node.deploy("light", LIGHT, light_params, n_replicas=2,
                          n_slots=4, max_len=64, block_size=8, num_blocks=64)
        node.install_faults(inj)
        prompts = _prompts(8, seed=5)
        for i, (rid, p) in enumerate(prompts.items()):
            dep.submit(f"s{i % 4}", rid, p, max_new_tokens=5)
        node.run_until_drained()
        st = dep.stats()
        assert any(e.startswith("crash:light/") for e in inj.fired_log)
        assert st["failovers"] == 1 and len(st["down"]) == 1
        for rid in prompts:
            res, err = dep.result(rid), dep.error(rid)
            assert res is not None                      # terminal, always
            if err is None:
                assert res.shape == (5,)                # full generation
            else:                                       # structured, never raw
                assert err["error"] in ("replica_failed",
                                        "deadline_exceeded")
        ns = node.stats()
        assert ns["submitted"] == ns["completed"]


def test_stall_watchdog_marks_down_and_drain_resolves(light_params):
    """A wedged replica (busy, zero tick progress) is invisible to crash
    handling — only the progress watchdog can see it.  Its sessions must
    re-home and the drain must RESOLVE, not time out."""
    inj = FaultInjector([FaultSpec(FaultKind.STALL, deployment="light",
                                   at_tick=2)])
    with ServeNode(n_workers=2) as node:
        dep = node.deploy("light", LIGHT, light_params, n_replicas=2,
                          n_slots=4, max_len=64, block_size=8, num_blocks=64,
                          watchdog_s=0.15)
        node.install_faults(inj)
        prompts = _prompts(6, seed=9)
        for i, (rid, p) in enumerate(prompts.items()):
            dep.submit(f"s{i % 3}", rid, p, max_new_tokens=4)
        node.run_until_drained(timeout_s=60.0)
        st = dep.stats()
        assert list(st["down"].values()) == ["stalled"]
        assert st["failovers"] == 1
        for rid in prompts:
            assert dep.result(rid) is not None
            assert dep.error(rid) is None, dep.error(rid)
            assert dep.result(rid).shape == (4,)


def test_watchdog_tolerates_slow_ticks(light_params):
    """SLOW_TICK stretches ticks but progress continues — the watchdog must
    NOT mark the replica down (deadlines, not failover, own slowness)."""
    inj = FaultInjector([FaultSpec(FaultKind.SLOW_TICK, deployment="light",
                                   at_tick=1, count=50, duration_s=0.01)])
    with ServeNode(n_workers=1) as node:
        dep = node.deploy("light", LIGHT, light_params, n_replicas=1,
                          n_slots=2, max_len=64, watchdog_s=1.0)
        node.install_faults(inj)
        dep.submit("s0", "r0", np.arange(5, dtype=np.int32),
                   max_new_tokens=3)
        node.run_until_drained()
        st = dep.stats()
        assert st["down"] == {} and st["failovers"] == 0
        assert dep.result("r0").shape == (3,)


# ================================================================ deadlines
def test_deadline_expired_at_admission_structured_error(light_params):
    with ServeNode(n_workers=1) as node:
        dep = node.deploy("light", LIGHT, light_params, n_replicas=1,
                          n_slots=2, max_len=64)
        dep.submit("s0", "r0", np.arange(5, dtype=np.int32),
                   max_new_tokens=4, deadline_s=0.0)
        node.run_until_drained()
        err = dep.error("r0")
        assert err["error"] == "deadline_exceeded"
        assert err["stage"] == "admission"
        assert err["elapsed_s"] > err["deadline_s"] == 0.0
        assert dep.result("r0").shape == (0,)
        assert dep.stats()["deadline_exceeded"] == 1


def test_deadline_mid_generation_sweeps_with_partial_tokens(light_params):
    """Slow ticks burn a live request's budget: the per-tick sweep expires
    it with a structured stage and keeps the partial tokens — a deadline is
    a latency bound, not a correctness failure."""
    inj = FaultInjector([FaultSpec(FaultKind.SLOW_TICK, deployment="light",
                                   at_tick=1, count=1000, duration_s=0.02)])
    with ServeNode(n_workers=1) as node:
        dep = node.deploy("light", LIGHT, light_params, n_replicas=1,
                          n_slots=2, max_len=64, watchdog_s=5.0)
        node.install_faults(inj)
        dep.submit("s0", "r0", np.arange(6, dtype=np.int32),
                   max_new_tokens=50, deadline_s=0.08)
        node.run_until_drained()
        err = dep.error("r0")
        assert err["error"] == "deadline_exceeded"
        assert err["stage"] in ("queued", "prefill", "decode")
        assert err["elapsed_s"] > 0.08
        assert dep.result("r0") is not None        # partial tokens kept
        assert dep.stats()["down"] == {}           # slow ≠ wedged


# ============================================================ submit retries
def test_transient_submit_error_retries_on_sibling(light_params):
    inj = FaultInjector([FaultSpec(FaultKind.SUBMIT_ERROR,
                                   deployment="light", count=1)])
    with ServeNode(n_workers=2) as node:
        dep = node.deploy("light", LIGHT, light_params, n_replicas=2,
                          n_slots=2, max_len=64)
        node.install_faults(inj)
        dep.submit("s0", "r0", np.arange(5, dtype=np.int32),
                   max_new_tokens=3)
        node.run_until_drained()
        assert dep.result("r0").shape == (3,)
        assert dep.error("r0") is None
        st = dep.stats()
        assert st["submit_retries"] >= 1 and st["failover_failed"] == 0
        assert len(inj.fired_log) == 1


def test_submit_retry_exhaustion_fails_structured(light_params):
    """Every replica refusing the submit must terminate the request with a
    structured replica_failed — counted, completed, never raised back
    through a counted submit (which would hang the drain)."""
    inj = FaultInjector([
        FaultSpec(FaultKind.SUBMIT_ERROR, deployment="light", replica=0,
                  count=100),
        FaultSpec(FaultKind.SUBMIT_ERROR, deployment="light", replica=1,
                  count=100)])
    with ServeNode(n_workers=2) as node:
        dep = node.deploy("light", LIGHT, light_params, n_replicas=2,
                          n_slots=2, max_len=64)
        node.install_faults(inj)
        dep.submit("s0", "r0", np.arange(5, dtype=np.int32),
                   max_new_tokens=3)
        node.run_until_drained()
        err = dep.error("r0")
        assert err["error"] == "replica_failed"
        assert "no healthy replica" in err["reason"]
        assert dep.result("r0").shape == (0,)
        assert dep.stats()["failover_failed"] == 1


def test_store_seam_submit_error_retried_with_backoff(light_params):
    """A transient trigger_put failure (store seam) is retried by the
    deployment's capped-backoff loop; the request still lands and serves."""
    inj = FaultInjector([FaultSpec(FaultKind.SUBMIT_ERROR,
                                   deployment="light", seam="store",
                                   count=1)])
    with ServeNode(n_workers=1) as node:
        dep = node.deploy("light", LIGHT, light_params, n_replicas=1,
                          n_slots=2, max_len=64)
        node.install_faults(inj)
        dep.submit("s0", "r0", np.arange(5, dtype=np.int32),
                   max_new_tokens=3)
        node.run_until_drained()
        assert dep.result("r0").shape == (3,)
        assert dep.error("r0") is None
        assert dep.stats()["submit_retries"] >= 1
        assert inj.fired_log[0].startswith("store_submit_error:")


# ======================================================= cascade resilience
def test_cascade_heavy_crash_after_escalation_resolves(light_params,
                                                       heavy_params):
    """Heavy-tier replica crashing AFTER escalation submits succeeded: the
    escalated requests re-home inside the heavy deployment and every
    ``result()`` resolves — never pends forever."""
    inj = FaultInjector([FaultSpec(FaultKind.CRASH, deployment="heavy",
                                   at_tick=2)])
    with ServeNode(n_workers=2) as node:
        light = node.deploy("light", LIGHT, light_params, n_replicas=2,
                            n_slots=4, max_len=64)
        heavy = node.deploy("heavy", HEAVY, heavy_params, n_replicas=2,
                            n_slots=4, max_len=64)
        node.install_faults(inj)
        # threshold high enough that EVERY light answer escalates
        route = CascadeRoute(light, heavy,
                             gate=CascadeGate(metric="logprob",
                                              threshold=1e9))
        prompts = _prompts(6, seed=3)
        for i, (rid, p) in enumerate(prompts.items()):
            route.submit(f"s{i % 3}", rid, p, max_new_tokens=4)
        node.run_until_drained()
        st = route.stats()
        assert st["escalated"] == 6
        assert heavy.stats()["failovers"] == 1
        for rid in prompts:
            res = route.result(rid)
            assert res is not None
            err = route.error(rid)
            if err is None:
                assert res.shape == (4,)    # re-homed heavy answer
            else:
                assert err["error"] == "replica_failed"


def test_cascade_all_heavy_down_resolves_with_structured_error(light_params,
                                                               heavy_params):
    """No surviving heavy replica: escalated requests complete with a
    structured replica_failed from the heavy deployment — the route still
    resolves every request."""
    inj = FaultInjector([FaultSpec(FaultKind.CRASH, deployment="heavy",
                                   at_tick=1)])
    with ServeNode(n_workers=2) as node:
        light = node.deploy("light", LIGHT, light_params, n_replicas=2,
                            n_slots=4, max_len=64)
        heavy = node.deploy("heavy", HEAVY, heavy_params, n_replicas=1,
                            n_slots=4, max_len=64)
        node.install_faults(inj)
        route = CascadeRoute(light, heavy,
                             gate=CascadeGate(metric="logprob",
                                              threshold=1e9))
        prompts = _prompts(4, seed=13)
        for i, (rid, p) in enumerate(prompts.items()):
            route.submit(f"s{i % 2}", rid, p, max_new_tokens=4)
        node.run_until_drained()
        assert heavy.stats()["down"] != {}
        failed = 0
        for rid in prompts:
            assert route.result(rid) is not None
            err = route.error(rid)
            if err is not None:
                assert err["error"] == "replica_failed"
                failed += 1
        assert failed >= 1                  # the crash really bit someone


def test_cascade_deadline_skips_escalation(light_params, heavy_params):
    """An exhausted end-to-end budget at the cascade boundary skips the
    heavy tier entirely: the light outcome stands, ``deadline_skips`` counts
    the decision, and the heavy deployment never sees the request."""
    with ServeNode(n_workers=2) as node:
        light = node.deploy("light", LIGHT, light_params, n_replicas=1,
                            n_slots=2, max_len=64)
        heavy = node.deploy("heavy", HEAVY, heavy_params, n_replicas=1,
                            n_slots=2, max_len=64)
        route = CascadeRoute(light, heavy,
                             gate=CascadeGate(metric="logprob",
                                              threshold=1e9))
        route.submit("s0", "r0", np.arange(5, dtype=np.int32),
                     max_new_tokens=4, deadline_s=0.0)
        node.run_until_drained()
        err = route.error("r0")
        assert err["error"] == "deadline_exceeded"
        assert route.result("r0") is not None
        st = route.stats()
        assert st["deadline_skips"] == 1 and st["escalated"] == 0
        assert heavy.stats()["submitted"] == 0

"""Issue-queue scheduler: SLO classes, EDF priority, per-session FIFO,
out-of-order readiness.  Pure host-side — no engine, no jax dispatch."""
import numpy as np

from repro.serving.scheduler import (Request, Scheduler, SLO_BATCH,
                                     SLO_INTERACTIVE, SLO_TARGETS,
                                     virtual_deadline)


def _req(rid, session="s", *, slo=SLO_BATCH, deadline_s=None, arrived_s=None,
         max_new=4, prompt_len=4):
    r = Request(request_id=rid, session_key=session,
                prompt=np.arange(prompt_len, dtype=np.int32),
                max_new_tokens=max_new, deadline_s=deadline_s, slo=slo)
    if arrived_s is not None:
        r.arrived_s = arrived_s
    return r


def test_virtual_deadline_explicit_beats_class_target():
    r = _req("r", slo=SLO_BATCH, deadline_s=0.1, arrived_s=100.0)
    assert virtual_deadline(r) == 100.1
    r2 = _req("r2", slo=SLO_INTERACTIVE, arrived_s=100.0)
    assert virtual_deadline(r2) == 100.0 + SLO_TARGETS[SLO_INTERACTIVE]


def test_interactive_issues_ahead_of_earlier_batch():
    s = Scheduler(n_replicas=1)
    s.submit(_req("b0", "sb", slo=SLO_BATCH, arrived_s=100.0))
    s.submit(_req("i0", "si", slo=SLO_INTERACTIVE, arrived_s=100.1))
    got = s.admit_one(0, free_slots=1)
    assert got.request_id == "i0"
    assert s.admit_one(0, free_slots=1).request_id == "b0"


def test_batch_ages_past_fresh_interactive():
    # absolute virtual deadlines ARE the aging mechanism: a batch request
    # older than the class-target gap beats any fresh interactive arrival
    gap = SLO_TARGETS[SLO_BATCH] - SLO_TARGETS[SLO_INTERACTIVE]
    s = Scheduler(n_replicas=1)
    s.submit(_req("b0", "sb", slo=SLO_BATCH, arrived_s=100.0))
    s.submit(_req("i0", "si", slo=SLO_INTERACTIVE,
                  arrived_s=100.0 + gap + 0.01))
    assert s.admit_one(0, free_slots=1).request_id == "b0"


def test_uniform_class_degenerates_to_fifo():
    s = Scheduler(n_replicas=1)
    for i in range(5):
        s.submit(_req(f"r{i}", f"s{i}", arrived_s=100.0 + i))
    order = [s.admit_one(0, free_slots=1).request_id for _ in range(5)]
    assert order == [f"r{i}" for i in range(5)]


def test_per_session_fifo_holds_across_classes():
    # a session's later INTERACTIVE turn must not overtake its earlier
    # BATCH turn: only the oldest waiting entry per session is eligible
    s = Scheduler(n_replicas=1)
    s.submit(_req("t0", "sess", slo=SLO_BATCH, arrived_s=100.0))
    s.submit(_req("t1", "sess", slo=SLO_INTERACTIVE, arrived_s=100.1))
    s.submit(_req("x0", "other", slo=SLO_BATCH, arrived_s=100.2))
    assert s.admit_one(0, free_slots=1).request_id == "t0"
    # t1 now IS its session's oldest entry and its class wins over x0
    assert s.admit_one(0, free_slots=1).request_id == "t1"
    assert s.admit_one(0, free_slots=1).request_id == "x0"


def test_blocked_head_does_not_stall_other_sessions():
    # out-of-order issue: session A's head can't get blocks; session B's
    # ready request issues past it, but session A's OWN later turn cannot
    s = Scheduler(n_replicas=1)
    s.submit(_req("a0", "sa", arrived_s=100.0))
    s.submit(_req("a1", "sa", arrived_s=100.1))
    s.submit(_req("b0", "sb", arrived_s=100.2))
    cost = {"a0": 8, "a1": 1, "b0": 2}.__getitem__

    def admit(free):
        return s.admit_one(0, free_slots=1, free_blocks=free,
                           block_cost=lambda r: cost(r.request_id),
                           max_blocks=10)

    got = admit(4)
    assert got is not None and got.request_id == "b0"
    assert admit(4) is None          # a0 still blocked, a1 still gated
    got = admit(8)
    assert got.request_id == "a0"    # blocks freed: session order intact
    assert admit(8).request_id == "a1"


def test_oversized_demand_pops_through_for_rejection():
    s = Scheduler(n_replicas=1)
    s.submit(_req("huge", "s", arrived_s=100.0))
    got = s.admit_one(0, free_slots=1, free_blocks=2,
                      block_cost=lambda r: 99, max_blocks=10)
    assert got is not None and got.request_id == "huge"


def test_admit_skips_expired_entries():
    # dense-path satellite: a dead head must not consume a slot or a
    # prefill-budget lane — admit() leaves it queued for pop_expired
    s = Scheduler(n_replicas=1, prefill_budget=4)
    s.submit(_req("dead", "sd", deadline_s=0.0, arrived_s=0.0))
    s.submit(_req("ok", "so", arrived_s=100.0))
    got = s.admit(0, free_slots=4)
    assert [r.request_id for r in got] == ["ok"]
    assert [r.request_id for r in s.pop_expired(0)] == ["dead"]
    assert s.pending(0) == 0


def test_expired_older_turn_gates_its_sessions_younger_turn():
    # per-session order is absolute: until the sweep clears the expired
    # older turn, the session's younger turn stays held back
    s = Scheduler(n_replicas=1)
    s.submit(_req("dead", "sess", deadline_s=0.0, arrived_s=0.0))
    s.submit(_req("next", "sess", arrived_s=100.0))
    assert s.admit_one(0, free_slots=1) is None
    s.pop_expired(0)
    assert s.admit_one(0, free_slots=1).request_id == "next"


def test_best_waiting_is_read_only_and_priority_ordered():
    s = Scheduler(n_replicas=1)
    s.submit(_req("b0", "sb", slo=SLO_BATCH, arrived_s=100.0))
    s.submit(_req("i0", "si", slo=SLO_INTERACTIVE, arrived_s=100.1))
    assert s.best_waiting(0).request_id == "i0"
    assert s.pending(0) == 2          # nothing popped
    assert s.best_waiting(0).request_id == "i0"


def test_requeue_restores_session_precedence():
    s = Scheduler(n_replicas=1)
    s.submit(_req("r0", "s", arrived_s=100.0))
    s.submit(_req("r1", "s", arrived_s=100.1))
    got = s.admit_one(0, free_slots=1)
    assert got.request_id == "r0"
    s.requeue(0, got)
    assert s.admit_one(0, free_slots=1).request_id == "r0"
    assert s.admit_one(0, free_slots=1).request_id == "r1"


def test_fold_for_replay_round_trip():
    r = _req("r", prompt_len=3)
    r.tokens = [7, 8]
    assert r.fold_for_replay()
    assert r.replay_offset == 2
    assert list(np.asarray(r.prompt)) == [0, 1, 2, 7, 8]
    # idempotent: nothing new to fold
    assert r.fold_for_replay()
    assert len(np.asarray(r.prompt)) == 5


def test_fold_for_replay_refuses_embeds():
    r = Request(request_id="e", session_key="s",
                prompt=np.zeros((3, 4), np.float32))
    r.tokens = [1]
    assert not r.fold_for_replay()

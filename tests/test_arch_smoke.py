"""Per-assigned-architecture smoke tests (deliverable f): a REDUCED config of
the same family runs one forward + one train step on CPU; output shapes and
no NaNs asserted.  Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import decode_step, forward, init_params, prefill
from repro.training.optimizer import get_optimizer
from repro.training.train import init_train_state, make_train_step


def _batch(cfg, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    if cfg.input_mode == "embeds":
        inputs = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    else:
        inputs = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {
        "inputs": inputs,
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "positions": pos,
        "mask": jnp.ones((B, S), jnp.float32),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg)
    logits, aux = forward(params, b["inputs"], b["positions"], cfg, mode="score")
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not jnp.isnan(logits).any()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    opt = get_optimizer(cfg.optimizer, lr=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    b = _batch(cfg)
    state, metrics = step(state, b)
    assert not jnp.isnan(metrics["loss"])
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    state2, metrics2 = step(state, _batch(cfg, seed=1))
    assert float(metrics2["step"]) == 2


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg)
    _, caches = prefill(params, b["inputs"][:, :15] if b["inputs"].ndim > 2
                        else b["inputs"][:, :15], b["positions"][:, :15],
                        cfg, max_len=32)
    last = (b["inputs"][:, 15] if cfg.input_mode == "tokens"
            else b["inputs"][:, 15:16])
    logits, caches = decode_step(params, caches, last,
                                 b["positions"][:, 15:16], cfg)
    assert logits.shape == (2, cfg.vocab_size)
    assert not jnp.isnan(logits).any()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_layout_consistent(arch):
    """Full config structural invariants (no allocation)."""
    cfg = get_config(arch)
    segs = cfg.layout()
    assert sum(s.n_layers for s in segs) == cfg.n_layers + \
        (sum(1 for seg in segs for p in seg.pattern if p.kind == "shared_attn")
         * 0 if cfg.family != "hybrid" else
         sum(seg.repeat for seg in segs for p in seg.pattern
             if p.kind == "shared_attn"))
    assert cfg.param_count() > 0
    assert cfg.active_param_count() <= cfg.param_count()

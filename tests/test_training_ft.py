"""Training substrate + fault tolerance: optimizers, grad accum, checkpoint
restart through the Cascade persistent log, straggler monitor, elastic
resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, init_params, param_axes
from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, synthetic_batch
from repro.training.ft import FaultTolerantLoop, StepMonitor, elastic_reshard
from repro.training.optimizer import clip_by_global_norm, get_optimizer
from repro.training.train import init_train_state, make_train_step

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab_size=128, dtype="float32",
                  q_chunk=16)


def _batches(cfg, dcfg):
    i = 0
    while True:
        yield {k: jnp.asarray(v) for k, v in synthetic_batch(cfg, dcfg, i).items()}
        i += 1


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizer_descends(opt_name):
    opt = get_optimizer(opt_name, lr=1e-2)
    state = init_train_state(jax.random.PRNGKey(0), CFG, opt)
    step = jax.jit(make_train_step(CFG, opt))
    it = _batches(CFG, DataConfig(batch=4, seq_len=16))
    losses = []
    for _ in range(6):
        state, metrics = step(state, next(it))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_grad_accum_matches_full_batch():
    """grad_accum=2 must equal one full-batch step (same tokens)."""
    opt = get_optimizer("adamw", lr=1e-2)
    s0 = init_train_state(jax.random.PRNGKey(0), CFG, opt)
    b = next(_batches(CFG, DataConfig(batch=4, seq_len=16)))
    s1, m1 = jax.jit(make_train_step(CFG, opt))(s0, b)
    s2, m2 = jax.jit(make_train_step(CFG, opt, grad_accum=2))(s0, b)
    # loss averages match; params land close (identical up to sum order)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, c in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(a, c, rtol=2e-4, atol=2e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(norm), 10.0)
    total = np.sqrt(sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(clipped)))
    assert np.isclose(total, 1.0, rtol=1e-5)


def test_checkpoint_restart_resumes(tmp_path):
    opt = get_optimizer("adamw", lr=1e-2)
    state = init_train_state(jax.random.PRNGKey(0), CFG, opt)
    step = jax.jit(make_train_step(CFG, opt))
    path = os.path.join(tmp_path, "ckpt.log")
    ck = CheckpointManager(path)
    loop = FaultTolerantLoop(step, state, ckpt=ck, ckpt_every=2)
    loop.run(_batches(CFG, DataConfig(batch=4, seq_len=16)), 5)
    ck.close()
    # crash + restart: resumes from the stable checkpoint at step 5
    ck2 = CheckpointManager(path)
    fresh = init_train_state(jax.random.PRNGKey(0), CFG, opt)
    loop2 = FaultTolerantLoop(step, fresh, ckpt=ck2, ckpt_every=2)
    assert loop2.step == 5
    assert int(loop2.state.opt_state.step) == 5
    ck2.close()


def test_checkpoint_time_travel(tmp_path):
    ck = CheckpointManager(os.path.join(tmp_path, "c.log"))
    tree = {"w": jnp.arange(4, dtype=jnp.float32)}
    ck.save(1, tree)
    obj = ck.log.latest("/ckpt/__meta__")
    t1 = obj.timestamp_ns
    ck.save(2, {"w": jnp.arange(4, dtype=jnp.float32) * 10})
    step, restored = ck.restore(tree, at_time_ns=t1)
    assert step == 1
    np.testing.assert_array_equal(restored["w"], np.arange(4, dtype=np.float32))
    ck.close()


def test_straggler_monitor():
    m = StepMonitor(threshold=2.0)
    for i in range(10):
        m.observe(i, 0.1)
    assert m.observe(10, 0.5)       # 5× median → straggler
    assert not m.observe(11, 0.12)
    assert m.stragglers == [10]


def test_elastic_reshard_roundtrip():
    """Params move between meshes of different shapes without value change."""
    from jax.sharding import PartitionSpec as P

    params = init_params(jax.random.PRNGKey(0), CFG)
    devs = jax.devices()
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    moved = elastic_reshard(params, mesh1, lambda path, leaf: P())
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(moved)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

"""Multi-replica serving cluster on the Cascade fast path: dispatch-policy
routing, drain semantics, and the one-device→host-transfer-per-tick rule."""
import jax
import numpy as np
import pytest

from repro.core.pools import DispatchPolicy
from repro.models import ModelConfig, init_params
from repro.serving.cluster import ServeCluster
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import Request

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab_size=128, dtype="float32",
                  q_chunk=16)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _prompt(rng, lo=3, hi=9):
    return rng.integers(0, CFG.vocab_size,
                        (int(rng.integers(lo, hi)),)).astype(np.int32)


# ------------------------------------------------------------------ routing
def _collect_completed(cluster):
    """Wrap each engine's completion hook to retain finished Request objects."""
    done = {}
    for eng in cluster.engines:
        orig = eng.on_complete
        eng.on_complete = (lambda req, orig=orig:
                           (done.__setitem__(req.request_id, req), orig(req))[1])
    return done


def test_fifo_session_affinity_and_order(params):
    """All turns of a session land on ONE replica, admitted in turn order."""
    with ServeCluster(CFG, params, n_replicas=3, n_slots=2, max_len=32,
                      policy=DispatchPolicy.FIFO) as cluster:
        done = _collect_completed(cluster)
        rng = np.random.default_rng(0)
        sessions = ["alice", "bob", "carol", "dave"]
        turns = 4
        for t in range(turns):
            for s in sessions:
                cluster.submit(s, f"{s}-t{t}", _prompt(rng), max_new_tokens=3)
        cluster.run_until_drained()
        for s in sessions:
            replicas = {cluster.routed[f"{s}-t{t}"] for t in range(turns)}
            assert len(replicas) == 1, f"session {s} hopped replicas"
            # turns were admitted in order: first-token times non-decreasing
            times = [done[f"{s}-t{t}"].first_token_s for t in range(turns)]
            assert times == sorted(times), f"session {s} turns reordered"
        # requests were really dispatched through the store's fast path
        assert sum(w.dispatcher.dispatched for w in cluster.workers) \
            >= len(sessions) * turns


def test_fifo_turn_order_via_token_stream(params):
    """Stronger FIFO check: one slot per replica forces strictly serial
    execution, so a session's turns must finish in submission order."""
    with ServeCluster(CFG, params, n_replicas=2, n_slots=1, max_len=32,
                      policy=DispatchPolicy.FIFO) as cluster:
        rng = np.random.default_rng(1)
        order = []
        done_order = []
        for t in range(5):
            rid = f"s-t{t}"
            order.append(rid)
            cluster.submit("one-session", rid, _prompt(rng), max_new_tokens=2)
        # completion hook order: wrap on_complete to record finish sequence
        for eng in cluster.engines:
            orig = eng.on_complete
            eng.on_complete = (lambda req, orig=orig:
                               (done_order.append(req.request_id), orig(req))[1])
        cluster.run_until_drained()
        assert done_order == order


def test_round_robin_spreads_evenly(params):
    with ServeCluster(CFG, params, n_replicas=2, n_slots=4, max_len=32,
                      policy=DispatchPolicy.ROUND_ROBIN) as cluster:
        rng = np.random.default_rng(2)
        n = 12
        for i in range(n):
            # same session for every request: RR must STILL spread the load
            cluster.submit("sess", f"r{i}", _prompt(rng), max_new_tokens=2)
        cluster.run_until_drained()
        counts = [e.stats.prefills for e in cluster.engines]
        assert sum(counts) == n
        assert counts == [n // 2, n // 2], f"uneven spread {counts}"


# -------------------------------------------------------------------- drain
def test_drain_mixed_lengths_exact_token_budget(params):
    """Mixed prompt lengths; every request emits EXACTLY max_new_tokens and
    its response lands back in the store."""
    with ServeCluster(CFG, params, n_replicas=2, n_slots=2, max_len=32,
                      policy=DispatchPolicy.ROUND_ROBIN) as cluster:
        rng = np.random.default_rng(3)
        budgets = {}
        for i in range(9):
            budget = int(rng.integers(1, 6))     # includes the ==1 edge case
            budgets[f"r{i}"] = budget
            cluster.submit(f"s{i % 3}", f"r{i}", _prompt(rng, 2, 12),
                           max_new_tokens=budget)
        cluster.run_until_drained()
        for rid, budget in budgets.items():
            out = cluster.result(rid)
            assert out is not None, f"{rid} response missing from store"
            assert out.shape == (budget,), \
                f"{rid}: got {out.shape[0]} tokens, wanted exactly {budget}"
        st = cluster.stats()
        assert st["requests"] == 9
        assert st["tokens_out"] == sum(budgets.values())
        for eng in cluster.engines:
            assert eng.cm.n_active == 0
            assert not eng.live


# -------------------------------------------------- one transfer per tick
def test_one_host_sync_per_unified_tick(params):
    """The unified mixed tick does exactly ONE device→host transfer no
    matter how many decode rows and prefill chunks it packs: four prompts
    (24 tokens) fit one token budget, so tick 1 carries all four prefills
    and every later tick carries four decode rows — one sync each."""
    eng = ServeEngine(CFG, params, n_slots=4, max_len=32)
    rng = np.random.default_rng(4)
    for i in range(4):
        eng.submit(Request(request_id=f"r{i}", session_key=f"s{i}",
                           prompt=rng.integers(0, 128, (6,)).astype(np.int32),
                           max_new_tokens=5))
    eng.run_until_drained()
    assert eng.stats.prefill_chunks == 4          # one chunk per prompt...
    assert eng.stats.ticks == 5                   # ...all in tick 1, then 4
    assert eng.stats.decode_ticks == 4            #    pure-decode ticks
    # THE invariant: one fixed-shape dispatch, hence one sync, per tick
    assert eng.stats.host_syncs == eng.stats.ticks
    assert eng.stats.prefill_batches == 0         # no separate prefill phase
    assert eng.stats.tokens_out == 4 * 5


def test_mixed_lengths_pack_into_one_tick(params):
    """No same-length grouping needed: DIFFERENT prompt lengths pack into
    one fixed-shape mixed dispatch (per-token positions/rows), so the whole
    admission wave costs one tick and one sync."""
    eng = ServeEngine(CFG, params, n_slots=4, max_len=32)
    rng = np.random.default_rng(5)
    lengths = [5, 5, 7, 7]
    for i, L in enumerate(lengths):
        eng.submit(Request(request_id=f"r{i}", session_key="s",
                           prompt=rng.integers(0, 128, (L,)).astype(np.int32),
                           max_new_tokens=2))
    eng.run_until_drained()
    assert eng.stats.prefills == 4
    assert eng.stats.prefill_chunks == 4          # all four in ONE tick:
    assert eng.stats.ticks == 2                   # prefill tick + decode tick
    assert eng.stats.host_syncs == eng.stats.ticks


def test_cluster_one_sync_per_tick_end_to_end(params):
    with ServeCluster(CFG, params, n_replicas=2, n_slots=3, max_len=32,
                      policy=DispatchPolicy.ROUND_ROBIN) as cluster:
        rng = np.random.default_rng(6)
        for i in range(8):
            cluster.submit("s", f"r{i}", _prompt(rng), max_new_tokens=3)
        cluster.run_until_drained()
        st = cluster.stats()
        assert st["host_syncs"] == st["ticks"]


def test_batched_prefill_matches_single_prefill(params):
    """Packing k identical prompts into one mixed tick must produce the same
    first token as packing one: lane position within the ragged batch cannot
    leak into a request's logits."""
    prompt = np.arange(1, 9, dtype=np.int32)
    firsts = []
    for batch in (1, 3):
        eng = ServeEngine(CFG, params, n_slots=4, max_len=32)
        reqs = [Request(request_id=f"r{i}", session_key="s", prompt=prompt,
                        max_new_tokens=1) for i in range(batch)]
        done = []
        eng.on_complete = done.append
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        assert {len(r.tokens) for r in done} == {1}
        firsts.append({r.tokens[0] for r in done})
        assert len(firsts[-1]) == 1               # identical rows, same token
    assert firsts[0] == firsts[1]


def test_rejected_request_surfaces_error_to_clients(params):
    """A request the engine refuses (here: prompt > max_len) must complete at
    the store boundary — empty tokens at the normal out key plus the reason
    under <request_id>/error — instead of silently looking like a zero-token
    generation or hanging the drain."""
    rng = np.random.default_rng(11)
    with ServeCluster(CFG, params, n_replicas=2, n_slots=2, max_len=32,
                      policy=DispatchPolicy.ROUND_ROBIN) as cluster:
        cluster.submit("s", "good", _prompt(rng), max_new_tokens=2)
        cluster.submit("s", "huge", rng.integers(0, 128, (40,)).astype(np.int32),
                       max_new_tokens=2)
        cluster.run_until_drained()
        assert len(cluster.result("good")) == 2
        assert cluster.error("good") is None
        assert len(cluster.result("huge")) == 0
        err = cluster.error("huge")
        assert err is not None and "max_len" in err

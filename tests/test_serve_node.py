"""Multi-tenant ServeNode: multi-model hosting, bounded admission with
shed/redirect, cascade escalation, and deployment teardown.

The node hosts a paged attention model and a dense SSM model side by side on
one shared worker set / store / KV device store; each deployment keeps its
own host-sync invariant.  Bounded admission (MultiTASC++-style) is checked
deterministically by waiting each trigger_put's upcall before the next, so
queue depths at each admission decision are exact.
"""
import math

import jax
import numpy as np
import pytest

from repro.core.pools import DispatchPolicy
from repro.models import ModelConfig, init_params
from repro.serving.cluster import CascadeGate, CascadeRoute, ServeNode
from repro.serving.scheduler import Request

LIGHT = ModelConfig(name="light", family="dense", n_layers=2, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                    dtype="float32", q_chunk=16)
# d_inner = 2*d_model must divide by ssm_head_dim (64): d_model=64 → 2 heads
HEAVY = ModelConfig(name="heavy", family="ssm", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                    dtype="float32")


@pytest.fixture(scope="module")
def light_params():
    return init_params(jax.random.PRNGKey(0), LIGHT)


@pytest.fixture(scope="module")
def heavy_params():
    return init_params(jax.random.PRNGKey(1), HEAVY)


def _prompt(rng, lo=3, hi=9):
    return rng.integers(0, 128, (int(rng.integers(lo, hi)),)).astype(np.int32)


# ============================================================ multi-model
def test_two_models_side_by_side_keep_their_invariants(light_params,
                                                       heavy_params):
    """One node, one worker set: a paged attention deployment and a dense
    SSM deployment interleave on the same driver loop, and each upholds its
    own host-sync discipline."""
    rng = np.random.default_rng(0)
    with ServeNode(n_workers=2) as node:
        light = node.deploy("light", LIGHT, light_params, n_replicas=2,
                            n_slots=2, max_len=48)
        heavy = node.deploy("heavy", HEAVY, heavy_params, n_replicas=2,
                            n_slots=2, max_len=48)
        assert light.paged and not heavy.paged
        for i in range(6):
            light.submit(f"ls{i % 2}", f"l{i}", _prompt(rng),
                         max_new_tokens=3)
            heavy.submit(f"hs{i % 2}", f"h{i}", _prompt(rng),
                         max_new_tokens=4)
        node.run_until_drained()
        for i in range(6):
            assert light.result(f"l{i}").shape == (3,)
            assert heavy.result(f"h{i}").shape == (4,)
        ls, hs = light.stats(), heavy.stats()
        assert ls["requests"] == 6 and hs["requests"] == 6
        # the paged invariant, per deployment
        assert ls["host_syncs"] == ls["ticks"]
        # the dense discipline, per deployment
        assert hs["host_syncs"] == hs["decode_ticks"] + hs["prefill_batches"]
        # paged KV pools are namespaced per model/replica on the ONE store
        kv_keys = sorted(node.kv_store().keys())
        assert kv_keys == ["/kv/light/replica0/pool",
                           "/kv/light/replica1/pool"]
        st = node.stats()
        assert st["submitted"] == st["completed"] == 12
        assert set(st["deployments"]) == {"light", "heavy"}


# ======================================================= bounded admission
def test_shed_over_watermark_with_structured_reason(light_params):
    """A single-replica deployment with watermark W accepts exactly W
    requests from a burst and sheds the rest with a structured reason —
    never a silent drop, never an unbounded queue."""
    rng = np.random.default_rng(1)
    with ServeNode(n_workers=1) as node:
        dep = node.deploy("light", LIGHT, light_params, n_replicas=1,
                          n_slots=1, max_len=48, watermark=2)
        # wait each upcall so every admission decision sees an exact depth
        for i in range(8):
            dep.submit("s", f"r{i}", _prompt(rng), max_new_tokens=2).wait()
        assert dep.shed == 6 and dep.redirected == 0
        node.run_until_drained()
        served = [i for i in range(8) if len(dep.result(f"r{i}")) == 2]
        assert served == [0, 1]
        for i in range(2, 8):
            err = dep.error(f"r{i}")
            assert err["error"] == "shed_overload"
            assert err["deployment"] == "light"
            assert err["watermark"] == 2 and err["depth"] >= 2
            assert len(dep.result(f"r{i}")) == 0
        assert dep.stats()["shed"] == 6


def test_redirect_to_least_loaded_sibling_then_shed(light_params):
    """FIFO pins a session to one replica; once that replica's queue hits
    the watermark, arrivals are redirected to the least-loaded sibling —
    and only when EVERY sibling is saturated do they shed."""
    rng = np.random.default_rng(2)
    with ServeNode(n_workers=2) as node:
        dep = node.deploy("light", LIGHT, light_params, n_replicas=2,
                          n_slots=1, max_len=48, watermark=2,
                          policy=DispatchPolicy.FIFO)
        for i in range(5):
            dep.submit("one-session", f"r{i}", _prompt(rng),
                       max_new_tokens=2).wait()
        # 2 admitted at home, 2 redirected to the sibling, 1 shed
        assert dep.redirected == 2 and dep.shed == 1
        home = dep.routed["r0"]
        assert dep.routed["r1"] == home
        assert dep.routed["r2"] == dep.routed["r3"] == 1 - home
        node.run_until_drained()
        for i in range(4):
            assert len(dep.result(f"r{i}")) == 2
        assert dep.error("r4")["error"] == "shed_overload"
        st = dep.stats()
        assert st["redirected"] == 2 and st["shed"] == 1


def test_unbounded_deployment_never_sheds(light_params):
    """watermark=None (the default) keeps the old accept-everything
    behavior: a burst far beyond capacity just queues."""
    rng = np.random.default_rng(3)
    with ServeNode(n_workers=1) as node:
        dep = node.deploy("light", LIGHT, light_params, n_replicas=1,
                          n_slots=1, max_len=48)
        for i in range(10):
            dep.submit("s", f"r{i}", _prompt(rng), max_new_tokens=2).wait()
        node.run_until_drained()
        assert dep.shed == 0 and dep.redirected == 0
        assert all(len(dep.result(f"r{i}")) == 2 for i in range(10))


# ======================================================== cascade routing
def test_cascade_gate_reads_per_token_scores():
    r = Request(request_id="r", session_key="s", prompt=[1])
    r.scores = [-0.5, -1.5]          # mean -1.0
    r.entropies = [1.0, 3.0]         # mean 2.0
    assert CascadeGate("logprob", threshold=-0.5).trips(r)
    assert not CascadeGate("logprob", threshold=-2.0).trips(r)
    assert CascadeGate("entropy", threshold=1.5).trips(r)
    assert not CascadeGate("entropy", threshold=2.5).trips(r)
    with pytest.raises(ValueError):
        CascadeGate("vibes", threshold=0.0)


def test_cascade_route_escalates_when_gate_trips(light_params, heavy_params):
    """threshold=+inf trips the logprob gate on every request: all requests
    re-run on the heavy deployment via the internal trigger_put, and the
    cascade answer equals a direct heavy-deployment answer."""
    rng = np.random.default_rng(4)
    with ServeNode(n_workers=2) as node:
        light = node.deploy("light", LIGHT, light_params, n_replicas=2,
                            n_slots=2, max_len=48)
        heavy = node.deploy("heavy", HEAVY, heavy_params, n_replicas=2,
                            n_slots=2, max_len=48)
        route = CascadeRoute(light, heavy,
                             CascadeGate("logprob", threshold=math.inf))
        prompts = {f"r{i}": _prompt(rng) for i in range(4)}
        for rid, p in prompts.items():
            route.submit("sess", rid, p, max_new_tokens=3)
        node.run_until_drained()
        st = route.stats()
        assert st["escalated"] == st["gate_trips"] == 4
        assert st["escalation_rate"] == 1.0
        assert heavy.stats()["requests"] == 4
        for rid, p in prompts.items():
            assert route.escalated(rid)
            got = route.result(rid)
            assert got is not None and got.shape == (3,)
            # the cascade answer IS the heavy model's answer
            heavy.submit("ref", f"ref-{rid}", p, max_new_tokens=3)
            node.run_until_drained()
            np.testing.assert_array_equal(got, heavy.result(f"ref-{rid}"))


def test_cascade_route_keeps_confident_requests_on_light(light_params,
                                                         heavy_params):
    """threshold=-inf never trips: the heavy model is never touched and the
    route resolves to the light answers."""
    rng = np.random.default_rng(5)
    with ServeNode(n_workers=2) as node:
        light = node.deploy("light", LIGHT, light_params, n_replicas=2,
                            n_slots=2, max_len=48)
        heavy = node.deploy("heavy", HEAVY, heavy_params, n_replicas=1,
                            n_slots=2, max_len=48)
        route = CascadeRoute(light, heavy,
                             CascadeGate("logprob", threshold=-math.inf))
        for i in range(4):
            route.submit("sess", f"r{i}", _prompt(rng), max_new_tokens=3)
        node.run_until_drained()
        assert route.stats()["escalated"] == 0
        assert heavy.stats()["requests"] == 0
        for i in range(4):
            assert not route.escalated(f"r{i}")
            np.testing.assert_array_equal(route.result(f"r{i}"),
                                          light.result(f"r{i}"))


def test_cascade_result_survives_escalation_set_eviction(light_params,
                                                         heavy_params):
    """The bounded escalation set only caps INTROSPECTION state: once an
    escalated request's id has been evicted, result()/error() still resolve
    to the heavy answer (durable in the heavy out pool) — never silently
    back to the light answer the gate rejected."""
    rng = np.random.default_rng(12)
    with ServeNode(n_workers=2) as node:
        light = node.deploy("light", LIGHT, light_params, n_replicas=1,
                            n_slots=2, max_len=48)
        heavy = node.deploy("heavy", HEAVY, heavy_params, n_replicas=1,
                            n_slots=2, max_len=48)
        route = CascadeRoute(light, heavy,
                             CascadeGate("logprob", threshold=math.inf))
        route._escalated_cap = 2                 # force eviction quickly
        for i in range(4):
            route.submit("s", f"r{i}", _prompt(rng), max_new_tokens=3)
        node.run_until_drained()
        assert route.stats()["escalated"] == 4
        assert not route.escalated("r0")         # evicted from the set...
        np.testing.assert_array_equal(           # ...answer still heavy's
            route.result("r0"), heavy.result("r0"))
        assert route.error("r0") is None


def test_listener_exception_cannot_lose_a_completion(light_params,
                                                     heavy_params):
    """A raising on_done listener (e.g. a cascade escalating into a stopped
    heavy deployment) is contained: the light answer still lands in the out
    pool, the completion is still counted (drain finishes), and the fault
    is visible in stats."""
    rng = np.random.default_rng(13)
    with ServeNode(n_workers=2) as node:
        light = node.deploy("light", LIGHT, light_params, n_replicas=1,
                            n_slots=2, max_len=48)
        heavy = node.deploy("heavy", HEAVY, heavy_params, n_replicas=1,
                            n_slots=2, max_len=48)
        route = CascadeRoute(light, heavy,
                             CascadeGate("logprob", threshold=math.inf))
        node.undeploy("heavy")                   # escalation target is gone
        route.submit("s", "r0", _prompt(rng), max_new_tokens=2)
        node.run_until_drained()                 # must NOT TimeoutError
        assert len(light.result("r0")) == 2      # light answer survived
        assert light.stats()["listener_errors"] == 1
        # the un-escalated light answer is what the route resolves to
        np.testing.assert_array_equal(route.result("r0"),
                                      light.result("r0"))
    """A light-tier shed is not the end of the request: escalate_on_error
    fails it over to the heavy deployment, which serves it normally."""
    rng = np.random.default_rng(6)
    with ServeNode(n_workers=2) as node:
        light = node.deploy("light", LIGHT, light_params, n_replicas=1,
                            n_slots=1, max_len=48, watermark=0)  # sheds ALL
        heavy = node.deploy("heavy", HEAVY, heavy_params, n_replicas=1,
                            n_slots=2, max_len=48)
        route = CascadeRoute(light, heavy,
                             CascadeGate("logprob", threshold=-math.inf))
        for i in range(3):
            route.submit("s", f"r{i}", _prompt(rng), max_new_tokens=2)
        node.run_until_drained()
        st = route.stats()
        assert st["error_failovers"] == 3 and st["gate_trips"] == 0
        assert light.shed == 3
        for i in range(3):
            assert route.escalated(f"r{i}")
            assert len(route.result(f"r{i}")) == 2    # heavy answered
            assert route.error(f"r{i}") is None       # ...successfully


# ========================================================== score surfacing
def test_engines_surface_per_token_scores(light_params, heavy_params):
    """Both engine disciplines emit one (logprob, entropy) pair per emitted
    token, from the same in-dispatch sampler that picked it."""
    rng = np.random.default_rng(7)
    with ServeNode(n_workers=1) as node:
        light = node.deploy("light", LIGHT, light_params, n_replicas=1,
                            n_slots=2, max_len=48)
        heavy = node.deploy("heavy", HEAVY, heavy_params, n_replicas=1,
                            n_slots=2, max_len=48)
        done = []
        light.on_done.append(done.append)
        heavy.on_done.append(done.append)
        light.submit("s", "lp", _prompt(rng), max_new_tokens=5)
        heavy.submit("s", "hp", _prompt(rng), max_new_tokens=5)
        node.run_until_drained()
        assert len(done) == 2
        for req in done:
            assert len(req.scores) == len(req.tokens) == 5
            assert len(req.entropies) == 5
            assert all(s <= 0.0 for s in req.scores)       # log-probs
            assert all(e >= 0.0 for e in req.entropies)    # entropies
            assert math.isfinite(req.mean_logprob())
            assert math.isfinite(req.mean_entropy())


# ================================================================ teardown
def test_deployment_stop_tears_down_pools_lambdas_and_kv(light_params,
                                                         heavy_params):
    rng = np.random.default_rng(8)
    with ServeNode(n_workers=2) as node:
        light = node.deploy("light", LIGHT, light_params, n_replicas=2,
                            n_slots=2, max_len=48)
        heavy = node.deploy("heavy", HEAVY, heavy_params, n_replicas=1,
                            n_slots=2, max_len=48)
        light.submit("s", "r0", _prompt(rng), max_new_tokens=2)
        node.run_until_drained()
        assert node.kv_store().keys()
        node.undeploy("light")
        # pools gone: the store no longer owns the deployment's keys
        with pytest.raises(RuntimeError):
            light.submit("s", "r1", _prompt(rng))
        with pytest.raises(KeyError):
            node.store.trigger_put("/serve/light/req/s/r1", {"prompt": [1]})
        # lambdas unregistered on every worker
        for w in node.workers:
            assert w.dispatcher.match("/serve/light/req/s/r1") == []
        # KV pools freed on the device store
        assert not [k for k in node.kv_store().keys()
                    if k.startswith("/kv/light")]
        assert "light" not in node.deployments
        # the surviving deployment still serves
        heavy.submit("s", "h0", _prompt(rng), max_new_tokens=2)
        node.run_until_drained()
        assert len(heavy.result("h0")) == 2


def test_stop_with_common_name_prefix_spares_the_other_tenant(light_params):
    """Teardown is per path COMPONENT: stopping "light" must not take
    "light2"'s KV pools (or service) with it."""
    rng = np.random.default_rng(10)
    with ServeNode(n_workers=1) as node:
        a = node.deploy("light", LIGHT, light_params, n_replicas=1,
                        n_slots=2, max_len=48)
        b = node.deploy("light2", LIGHT, light_params, n_replicas=1,
                        n_slots=2, max_len=48)
        a.submit("s", "a0", _prompt(rng), max_new_tokens=2)
        b.submit("s", "b0", _prompt(rng), max_new_tokens=2)
        node.run_until_drained()
        node.undeploy("light")
        assert [k for k in node.kv_store().keys()
                if k.startswith("/kv/light2/")] == ["/kv/light2/replica0/pool"]
        assert not [k for k in node.kv_store().keys()
                    if k.startswith("/kv/light/")]
        b.submit("s", "b1", _prompt(rng), max_new_tokens=2)
        node.run_until_drained()
        assert len(b.result("b1")) == 2


def test_queue_depth_is_per_tenant_not_per_worker(light_params,
                                                  heavy_params):
    """Replica depth counts only THIS deployment's in-flight upcalls: a
    burst bound for the heavy deployment, stuck on the shared worker's
    upcall queue, must not register on the idle light deployment's depth
    (and so can never trip its watermark)."""
    import threading

    from repro.core.dispatcher import LambdaHandle
    from repro.core.pools import Persistence, PoolSpec

    rng = np.random.default_rng(11)
    with ServeNode(n_workers=1) as node:
        light = node.deploy("light", LIGHT, light_params, n_replicas=1,
                            n_slots=2, max_len=48, watermark=2)
        heavy = node.deploy("heavy", HEAVY, heavy_params, n_replicas=1,
                            n_slots=2, max_len=48)
        # wedge worker 0's single upcall thread behind a blocker lambda,
        # then pile heavy-bound events up behind it
        release = threading.Event()
        node.store.create_pool(PoolSpec(path="/blocker",
                                        persistence=Persistence.TRANSIENT))
        node.store.register_lambda(
            LambdaHandle("blocker", "/blocker",
                         lambda o, ev: release.wait(5)), worker_ids=[0])
        node.store.trigger_put("/blocker/x", b"")
        for i in range(6):
            heavy.submit("s", f"h{i}", _prompt(rng), max_new_tokens=2)
        d = node.workers[0].dispatcher
        assert d.queue_depth() == 7                       # blocker + 6 heavy
        assert d.queue_depth("heavy-replica-0") == 6      # per-handle view
        assert d.queue_depth("light-replica-0") == 0
        # THE point: light's admission depth is untouched by heavy traffic
        assert heavy.queue_depth(0) == 6
        assert light.queue_depth(0) == 0
        release.set()
        node.run_until_drained()
        assert all(len(heavy.result(f"h{i}")) == 2 for i in range(6))
        assert light.shed == 0


# ========================================================== drain timeout
def test_drain_timeout_names_still_busy_replicas(light_params, monkeypatch):
    """The wall-clock drain timeout must say WHO is stuck, not just that
    something is."""
    rng = np.random.default_rng(9)
    with ServeNode(n_workers=1) as node:
        dep = node.deploy("light", LIGHT, light_params, n_replicas=1,
                          n_slots=2, max_len=48)
        dep.submit("s", "r0", _prompt(rng), max_new_tokens=2).wait()
        monkeypatch.setattr(dep.engines[0], "tick", lambda: 0)  # wedge it
        with pytest.raises(TimeoutError) as ei:
            node.run_until_drained(timeout_s=0.3)
        msg = str(ei.value)
        assert "light/replica0" in msg
        assert "queued=1" in msg

"""Unified token-budget tick: chunked prefill fused with decode in one
fixed-shape ragged dispatch.

Covers the tick's admission edge cases (budget smaller than one chunk, FIFO
preserved across repeated begin() failures, oversized-demand heads escaping
through the rejection path mid-stream), the head-of-line property the budget
exists for (a long prefill cannot stall decoding sessions), intra-batch
prefix sharing, the fixed-shape/one-compile property, and a regression
guard that the dense (SSM) path's phase-separated discipline is untouched.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (ModelConfig, init_paged_pools, init_params,
                          paged_decode_step, paged_mixed_step, paged_prefill)
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import Request

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab_size=128, dtype="float32",
                  q_chunk=16)
SSM = ModelConfig(name="m", family="ssm", n_layers=2, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab_size=128, dtype="float32")


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def ssm_params():
    return init_params(jax.random.PRNGKey(0), SSM)


def _toks(rng, n):
    return rng.integers(0, CFG.vocab_size, (n,)).astype(np.int32)


def _run(cfg, params, reqs, **kw):
    eng = ServeEngine(cfg, params, **kw)
    done = []
    eng.on_complete = done.append
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return eng, {r.request_id: list(r.tokens) for r in done}


def _mk(rng, rid, n_prompt, n_new):
    return Request(request_id=rid, session_key=rid, prompt=_toks(rng, n_prompt),
                   max_new_tokens=n_new)


# ==================================================== model-level parity
def test_mixed_step_matches_phase_separated_oracle(params):
    """paged_mixed_step vs the phase-separated model API it fuses: a prompt
    prefilled in two ragged chunks then decoded one packed token at a time
    must reproduce paged_prefill + paged_decode_step logits exactly (same
    pool layout, same block tables — packing is a scheduling change)."""
    bs = 4
    prompt = np.arange(1, 11, dtype=np.int32)          # 10 tokens, 3 blocks
    bt1 = jnp.asarray([[1, 2, 3, -1]], jnp.int32)
    pools = init_paged_pools(CFG, num_blocks=10, block_size=bs)
    logits_ref, pools_ref = paged_prefill(
        params, pools, bt1, jnp.asarray(prompt)[None],
        jnp.arange(10, dtype=jnp.int32)[None], CFG)
    tok = int(jnp.argmax(logits_ref[0]))
    dl_ref, _ = paged_decode_step(params, pools_ref, bt1,
                                  jnp.asarray([tok], jnp.int32),
                                  jnp.asarray([[10]], jnp.int32), CFG)

    T = 8                                              # packed budget
    btR = jnp.asarray([[1, 2, 3, -1], [-1, -1, -1, -1]], jnp.int32)

    def pack(toks, poss, sidx):
        t = np.zeros(T, np.int32)
        p = np.full(T, -1, np.int32)
        r = np.full(T, -1, np.int32)
        t[:len(toks)], p[:len(poss)], r[:len(poss)] = toks, poss, 0
        return (jnp.asarray(t), jnp.asarray(p), jnp.asarray(r),
                jnp.asarray(sidx, jnp.int32))

    pools2 = init_paged_pools(CFG, num_blocks=10, block_size=bs)
    _, pools2 = paged_mixed_step(params, pools2, btR,
                                 *pack(prompt[:6], range(6), [0, 0]), CFG)
    lg, pools2 = paged_mixed_step(params, pools2, btR,
                                  *pack(prompt[6:], range(6, 10), [3, 0]),
                                  CFG)
    np.testing.assert_allclose(np.asarray(lg[0]), np.asarray(logits_ref[0]),
                               atol=2e-5, rtol=2e-5)
    assert int(jnp.argmax(lg[0])) == tok
    dlg, _ = paged_mixed_step(params, pools2, btR, *pack([tok], [10], [0, 0]),
                              CFG)
    np.testing.assert_allclose(np.asarray(dlg[0]), np.asarray(dl_ref[0]),
                               atol=2e-5, rtol=2e-5)


# =================================================== token-budget admission
def test_budget_smaller_than_one_chunk_still_progresses(params):
    """A prompt far bigger than the whole token budget prefills over many
    ticks in budget-sized chunks — and the token stream is identical to the
    dense engine's (chunking is a scheduling change, not a numerics one)."""
    def reqs():
        rng = np.random.default_rng(0)
        return [_mk(rng, f"r{i}", L, 4) for i, L in enumerate((20, 37, 9))]

    _, dense = _run(CFG, params, reqs(), n_slots=4, max_len=96, paged=False)
    eng, chunked = _run(CFG, params, reqs(), n_slots=4, max_len=96, paged=True,
                        block_size=16, token_budget=8)
    assert chunked == dense
    # 20+37+9 = 66 prefill tokens through an 8-token window → many chunks
    assert eng.stats.prefill_chunks > 8
    assert eng.stats.host_syncs == eng.stats.ticks


def test_token_budget_must_cover_decode_rows(params):
    """Every live decode row costs one token per tick, so a budget smaller
    than n_slots could starve decodes forever — rejected at construction."""
    with pytest.raises(ValueError, match="token_budget"):
        ServeEngine(CFG, params, n_slots=8, max_len=32, paged=True,
                    token_budget=4)


def test_requeue_preserves_fifo_across_repeated_begin_failures(params,
                                                               monkeypatch):
    """begin() refusals (accounting drift) across SEVERAL ticks must retry
    the same head each tick — younger requests never leapfrog it."""
    rng = np.random.default_rng(1)
    eng = ServeEngine(CFG, params, n_slots=4, max_len=64, paged=True,
                      block_size=16)
    real = eng.cm.begin
    calls = {"n": 0}

    def flaky(slot, prompt, max_new):
        calls["n"] += 1
        if calls["n"] <= 3:                       # three ticks of refusal
            eng.cm.release(slot)
            return None
        return real(slot, prompt, max_new)

    monkeypatch.setattr(eng.cm, "begin", flaky)
    done = []
    eng.on_complete = done.append
    for rid in ("r1", "r2", "r3"):
        eng.submit(Request(request_id=rid, session_key="s",
                           prompt=_toks(rng, 8), max_new_tokens=2))
    eng.run_until_drained()
    assert [r.request_id for r in done] == ["r1", "r2", "r3"]
    # 3 failures all burned on r1, then r1+r2+r3 admitted in one tick
    assert calls["n"] == 6
    assert eng.cm.n_active == 0


def test_scheduler_requeue_fifo_with_interleaved_submits():
    """Scheduler-level: a requeued head goes back IN FRONT of arrivals that
    were submitted while it was un-placed — repeated admit/requeue rounds
    interleaved with fresh submit()s must never let a younger request
    leapfrog the restored head."""
    from repro.serving.scheduler import Scheduler

    s = Scheduler(n_replicas=1)
    mk = lambda rid: Request(request_id=rid, session_key="s", prompt=[1])
    s.submit(mk("r1"))
    s.submit(mk("r2"))
    for round_ in range(3):                   # three failed-begin rounds,
        head = s.admit_one(0, free_slots=1)   # each with a fresh arrival
        assert head.request_id == "r1"
        s.submit(mk(f"new{round_}"))
        s.requeue(0, head)
    order = []
    while (r := s.admit_one(0, free_slots=1)) is not None:
        order.append(r.request_id)
    assert order == ["r1", "r2", "new0", "new1", "new2"]


def test_engine_requeue_fifo_with_interleaved_submits(params, monkeypatch):
    """Engine-level: begin() refusals across several ticks WHILE new
    requests keep arriving — completion order must still be submission
    order (the restored head is retried before any of the newcomers)."""
    rng = np.random.default_rng(11)
    eng = ServeEngine(CFG, params, n_slots=4, max_len=64, paged=True,
                      block_size=16)
    real = eng.cm.begin
    calls = {"n": 0}

    def flaky(slot, prompt, max_new):
        calls["n"] += 1
        if calls["n"] <= 2:
            eng.cm.release(slot)
            return None
        return real(slot, prompt, max_new)

    monkeypatch.setattr(eng.cm, "begin", flaky)
    done = []
    eng.on_complete = done.append
    eng.submit(Request(request_id="r1", session_key="s",
                       prompt=_toks(rng, 8), max_new_tokens=2))
    eng.tick()                                # begin fails: r1 requeued
    eng.submit(Request(request_id="r2", session_key="s",
                       prompt=_toks(rng, 8), max_new_tokens=2))
    eng.tick()                                # fails again; r2 behind r1
    eng.submit(Request(request_id="r3", session_key="s",
                       prompt=_toks(rng, 8), max_new_tokens=2))
    eng.run_until_drained()
    assert [r.request_id for r in done] == ["r1", "r2", "r3"]
    assert calls["n"] == 2 + 3                # 2 refusals + 3 admissions
    assert eng.cm.n_active == 0


def test_oversized_demand_head_escapes_mid_stream(params):
    """A never-servable request enqueued straight into the scheduler WHILE
    other sessions are decoding must pop through admit_one into the engine's
    rejection path without disturbing the live pool."""
    rng = np.random.default_rng(2)
    eng = ServeEngine(CFG, params, n_slots=2, max_len=96, paged=True,
                      block_size=16, num_blocks=9)    # 8 usable blocks
    done = []
    eng.on_complete = done.append
    eng.submit(_mk(rng, "live", 8, 8))
    eng.tick()                                        # live is now decoding
    eng.scheduler.submit(Request(request_id="huge", session_key="s",
                                 prompt=_toks(rng, 90),
                                 max_new_tokens=20))  # needs 7 > ... fits?
    eng.scheduler.submit(Request(request_id="impossible", session_key="s",
                                 prompt=_toks(rng, 70),
                                 max_new_tokens=60))  # needs 9 > 8: never
    eng.run_until_drained()
    byid = {r.request_id: r for r in done}
    assert byid["impossible"].error is not None
    assert "KV blocks" in byid["impossible"].error or \
        "max_len" in byid["impossible"].error
    assert byid["live"].error is None and len(byid["live"].tokens) == 8
    assert byid["huge"].error is not None             # 90+19 > max_len=96


# ================================================== head-of-line / latency
def test_long_prefill_never_stalls_decode_rows(params):
    """THE property the unified tick exists for: while a long prompt is
    being chunk-prefilled, every already-decoding session still emits
    exactly one token per tick — the prefill rides in the budget remainder
    instead of taking the tick hostage."""
    rng = np.random.default_rng(3)
    eng = ServeEngine(CFG, params, n_slots=4, max_len=96, paged=True,
                      block_size=16, token_budget=8)
    done = []
    eng.on_complete = done.append
    eng.submit(_mk(rng, "chat", 4, 30))
    eng.tick()                                        # chat decodes from now
    chat = next(r for s, r in eng.live.items())
    eng.submit(_mk(rng, "wall", 60, 2))               # 60 ≫ budget 8
    while "wall" not in {r.request_id for r in done}:
        n_before = len(chat.tokens)
        eng.tick()
        assert len(chat.tokens) == n_before + 1, \
            "decode stalled behind a prefill chunk"
    # the wall of prefill really was spread over many ticks
    assert eng.stats.prefill_chunks >= 60 // 8
    eng.run_until_drained()
    assert {r.request_id for r in done} == {"chat", "wall"}
    assert eng.stats.host_syncs == eng.stats.ticks


def test_intra_batch_prefix_sharing(params):
    """Two same-prefix requests admitted in ONE tick: chunk-granularity trie
    commit lets the second match the first's blocks — prefilling only its
    divergent tail — and both token streams still equal a cold dense run."""
    rng = np.random.default_rng(4)
    shared = _toks(rng, 32)                           # 2 full blocks of 16
    pa = np.concatenate([shared, _toks(rng, 8)])
    pb = np.concatenate([shared, _toks(rng, 8)])
    eng = ServeEngine(CFG, params, n_slots=4, max_len=96, paged=True,
                      block_size=16, token_budget=64)
    done = []
    eng.on_complete = done.append
    eng.submit(Request(request_id="a", session_key="a", prompt=pa,
                       max_new_tokens=3))
    eng.submit(Request(request_id="b", session_key="b", prompt=pb,
                       max_new_tokens=3))
    eng.tick()                                        # ONE dispatch, both in
    assert eng.stats.prefix_hit_tokens == 32 and eng.stats.prefix_hits == 1
    assert eng.stats.prefill_tokens == len(pa) + 8    # b prefilled only 8
    eng.run_until_drained()
    byid = {r.request_id: list(r.tokens) for r in done}
    for rid, p in (("a", pa), ("b", pb)):
        _, cold = _run(CFG, params, [Request(request_id=rid, session_key="s",
                                             prompt=p, max_new_tokens=3)],
                       n_slots=4, max_len=96, paged=False)
        assert cold[rid] == byid[rid]


# ====================================================== fixed-shape compile
def test_mixed_step_compiles_exactly_once(params):
    """The packed shape is fixed at token_budget and the block-table operand
    at (n_slots, max_blocks), so serving mixed prompt lengths, partial
    chunks, and pure-decode ticks never recompiles the step."""
    rng = np.random.default_rng(5)
    eng = ServeEngine(CFG, params, n_slots=4, max_len=96, paged=True,
                      block_size=16, token_budget=16)
    for i, (L, n) in enumerate(((5, 3), (40, 2), (17, 4), (3, 1), (29, 2))):
        eng.submit(_mk(rng, f"r{i}", L, n))
    eng.run_until_drained()
    assert eng.stats.ticks > 5                    # several distinct tick mixes
    assert eng._mixed._cache_size() == 1          # ...one compiled program


# ===================================================== dense path untouched
def test_dense_ssm_path_discipline_unchanged(ssm_params):
    """Regression guard for the refactor: SSM/hybrid configs (no paged
    support) keep the phase-separated tick verbatim — batched equal-length
    prefill groups, masked fused decode, and the ORIGINAL host-sync
    invariant ``host_syncs == decode_ticks + prefill_batches``."""
    rng = np.random.default_rng(6)
    from repro.models import supports_speculative
    assert not supports_speculative(SSM)      # mirrors supports_paged
    eng = ServeEngine(SSM, ssm_params, n_slots=4, max_len=32)
    assert not eng.paged and eng.token_budget is None
    assert eng.spec_k == 0 and eng.draft_source is None
    eng.scheduler.prefill_budget = 4
    done = []
    eng.on_complete = done.append
    for i, L in enumerate((6, 6, 9, 9)):          # two same-length runs
        eng.submit(Request(request_id=f"r{i}", session_key="s",
                           prompt=_toks(rng, L), max_new_tokens=3))
    eng.run_until_drained()
    assert len(done) == 4 and all(len(r.tokens) == 3 for r in done)
    assert eng.stats.prefill_batches == 2         # grouped batched prefill
    assert eng.stats.prefill_chunks == 0          # no mixed-tick machinery
    assert eng.stats.host_syncs == \
        eng.stats.decode_ticks + eng.stats.prefill_batches

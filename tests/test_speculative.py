"""Speculative decoding on the unified token-budget tick.

Covers the acceptance rule (greedy prefix-accept; seeded deterministic
distribution sweep showing rejection sampling emits EXACTLY the target
distribution regardless of the drafter), the engine fast path (greedy
streams bit-identical to non-speculative decode for perfect AND adversarial
drafters, drafted/accepted/rolled-back counter consistency, KV-pool
exactness after rollback), the invariants (``host_syncs == ticks`` with
speculation on, one compiled program, the ``supports_speculative`` gate),
the token-budget audit (a k-token row can never oversubscribe the fixed
packed shape — the latent 1-token-per-row assumption), and the
self-drafting cascade (light generation verified by a speculative heavy
deployment).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (ModelConfig, init_params, sample_with_scores,
                          speculative_verify, supports_speculative)
from repro.serving.draft import (ChainDraftSource, DraftSource,
                                 NgramDraftSource, RequestDraftSource)
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import Request

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab_size=128, dtype="float32",
                  q_chunk=16)
SSM = ModelConfig(name="m", family="ssm", n_layers=2, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab_size=128, dtype="float32")


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _toks(rng, n):
    return rng.integers(0, CFG.vocab_size, (n,)).astype(np.int32)


class EagerDrafts(DraftSource):
    """Always proposes k tokens: the NEXT tokens of a planted oracle stream
    when given one, else a fixed junk token (never the model's argmax for
    the tiny test configs, so acceptance is 0)."""

    def __init__(self, oracle: dict | None = None, junk: int = 1):
        self.oracle = oracle or {}
        self.junk = junk

    def propose(self, req, history, k):
        s = self.oracle.get(req.request_id)
        if s is not None:
            g = len(req.tokens)
            return [int(t) for t in s[g:g + k]]
        return [self.junk] * k


# ======================================================== acceptance rule
def test_verify_greedy_accepts_matching_prefix():
    """Greedy: accept while the draft equals the argmax chain; the token at
    the first mismatch is the correction, a full accept appends the bonus."""
    V = 8
    # row logits whose argmax chain is [3, 5, 2, 7]
    chain = [3, 5, 2, 7]
    logits = np.full((3, 4, V), -4.0, np.float32)
    for i, t in enumerate(chain):
        logits[:, i, t] = 4.0
    drafts = np.asarray([[3, 5, 9],      # accept 2, correction at index 2
                         [3, 5, 2],      # accept all 3, bonus at index 3
                         [0, 0, 0]], np.int32)
    dlen = np.asarray([3, 3, 0], np.int32)   # row 2: plain (no drafts)
    toks, n_acc, scores = speculative_verify(
        jnp.asarray(logits), jnp.asarray(drafts), jnp.asarray(dlen),
        seed=0, temperature=0.0)
    toks, n_acc = np.asarray(toks), np.asarray(n_acc)
    assert list(n_acc) == [2, 3, 0]
    assert list(toks[0]) == chain            # [3, 5, 2(correction), ·]
    assert list(toks[1]) == chain            # [3, 5, 2, 7(bonus)]
    assert toks[2, 0] == chain[0]            # plain row samples position 0
    # scores are finite logprob/entropy rows for every emitted position
    assert np.isfinite(np.asarray(scores)).all()


def test_rejection_sampling_matches_target_distribution():
    """THE losslessness property (seeded deterministic sweep, no hypothesis
    dep): the speculative rejection sampler's empirical next-token
    distribution equals vanilla sampling from the target model — for a
    GOOD drafter (draft = target mode) and an ADVERSARIAL one (draft =
    target anti-mode) alike.  Verified with a chi-square bound against the
    analytic target distribution at the first emitted position and,
    conditionally on acceptance, at the second."""
    V, K, temp = 8, 2, 1.0
    rng = np.random.default_rng(0)
    logits1 = jnp.asarray(rng.normal(size=(1, K + 1, V)) * 1.5, jnp.float32)
    p0 = np.asarray(jax.nn.softmax(logits1[0, 0] / temp))
    p1 = np.asarray(jax.nn.softmax(logits1[0, 1] / temp))
    R = 4000                                  # rows are iid samples
    logits = jnp.broadcast_to(logits1, (R, K + 1, V))
    seeds = range(5)
    verify = jax.jit(lambda d, n, s: speculative_verify(
        logits, d, n, s, temp))
    vanilla = jax.jit(lambda s: sample_with_scores(logits[:, 0, :], s, temp))

    # chi-square, df = V-1 = 7: the 0.999 quantile is 24.3; the sweep is
    # seeded so the statistic is deterministic — 30 is a stable margin
    def chi2(counts, probs, n):
        return float(np.sum((counts - n * probs) ** 2 / (n * probs)))

    for name, d0 in (("mode", int(np.argmax(p0))),
                     ("antimode", int(np.argmin(p0)))):
        drafts = jnp.broadcast_to(
            jnp.asarray([[d0, int(np.argmax(p1))]], jnp.int32), (R, K))
        dlen = jnp.full((R,), K, jnp.int32)
        c0 = np.zeros(V)
        c1 = np.zeros(V)
        cv = np.zeros(V)
        n1 = 0
        for seed in seeds:
            toks, n_acc, _ = verify(drafts, dlen, seed)
            toks, n_acc = np.asarray(toks), np.asarray(n_acc)
            np.add.at(c0, toks[:, 0], 1)
            sel = n_acc >= 1                 # reached position 1
            np.add.at(c1, toks[sel, 1], 1)
            n1 += int(sel.sum())
            vt, _ = vanilla(seed + 1000)
            np.add.at(cv, np.asarray(vt), 1)
        N = R * len(seeds)
        assert chi2(c0, p0, N) < 30, f"{name}: first-token dist diverged"
        assert chi2(cv, p0, N) < 30          # vanilla control on same bound
        # empirical spec vs empirical vanilla: total variation is small
        assert 0.5 * np.abs(c0 / N - cv / N).sum() < 0.05
        assert n1 > 300                      # enough mass for the cond. test
        assert chi2(c1, p1, n1) < 30, f"{name}: post-accept dist diverged"


# ======================================================= engine fast path
def _run(params, reqs, **kw):
    eng = ServeEngine(CFG, params, **kw)
    done = []
    eng.on_complete = done.append
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return eng, {r.request_id: list(r.tokens) for r in done}


def _mk_reqs(rng, lens, max_new=8, drafts=None):
    out = []
    for i, L in enumerate(lens):
        r = Request(request_id=f"r{i}", session_key=f"s{i}",
                    prompt=_toks(rng, L), max_new_tokens=max_new)
        if drafts is not None:
            r.draft_tokens = np.asarray(drafts[f"r{i}"], np.int32)
        out.append(r)
    return out


def test_greedy_spec_stream_identical_with_perfect_drafts(params):
    """Perfect drafts (the baseline's own output): every draft accepted,
    generated streams bit-identical, strictly fewer ticks, counters
    consistent, and the one-sync-per-tick invariant holds throughout."""
    lens = (10, 25, 5)
    kw = dict(n_slots=4, max_len=96, paged=True, block_size=16,
              token_budget=32)
    rng = np.random.default_rng(0)
    e0, base = _run(params, _mk_reqs(rng, lens), **kw)
    rng = np.random.default_rng(0)
    e1, spec = _run(params, _mk_reqs(rng, lens, drafts=base), spec_k=4, **kw)
    assert spec == base
    assert e1.stats.spec_drafted > 0
    assert e1.stats.spec_accepted == e1.stats.spec_drafted   # all on-script
    assert e1.stats.spec_rolled_back == 0
    assert e1.stats.ticks < e0.stats.ticks   # >1 token per sync, amortized
    assert e1.stats.host_syncs == e1.stats.ticks
    assert e1.stats.spec_acceptance_rate() == 1.0
    assert e1._mixed._cache_size() == 1      # speculation adds no programs


def test_greedy_spec_stream_identical_with_adversarial_drafts(params):
    """A drafter that is ALWAYS wrong: every draft rejected and rolled
    back, the stream still bit-identical to the non-speculative baseline
    (rejection sampling is lossless), and the block pool drains to exactly
    its full capacity — rejected-tail blocks were freed exactly once."""
    lens = (10, 25, 5)
    kw = dict(n_slots=4, max_len=96, paged=True, block_size=16,
              token_budget=32)
    rng = np.random.default_rng(0)
    _, base = _run(params, _mk_reqs(rng, lens), **kw)
    junk = (int(np.argmax([v.count(v[0]) for v in base.values()])) + 17) % 128
    rng = np.random.default_rng(0)
    e2, spec = _run(params, _mk_reqs(rng, lens), spec_k=4,
                    draft_source=EagerDrafts(junk=junk), **kw)
    assert spec == base
    assert e2.stats.spec_drafted > 0
    assert e2.stats.spec_accepted + e2.stats.spec_rolled_back \
        == e2.stats.spec_drafted
    assert e2.stats.host_syncs == e2.stats.ticks
    a = e2.cm.alloc
    assert a.available() == a.num_blocks - 1
    got = a.allocate(a.num_blocks - 1)
    assert got is not None and len(set(got)) == a.num_blocks - 1


def test_spec_counters_consistent_with_ngram_self_drafting(params):
    """The default drafter (request draft → n-gram fallback) on its own:
    accepted <= drafted always, rolled-back = drafted - accepted, and the
    emitted stream still equals the baseline."""
    lens = (16, 33)
    kw = dict(n_slots=4, max_len=96, paged=True, block_size=16,
              token_budget=32)
    rng = np.random.default_rng(3)
    _, base = _run(params, _mk_reqs(rng, lens, max_new=10), **kw)
    rng = np.random.default_rng(3)
    e, spec = _run(params, _mk_reqs(rng, lens, max_new=10), spec_k=3, **kw)
    assert spec == base
    assert 0 <= e.stats.spec_accepted <= e.stats.spec_drafted
    assert e.stats.spec_rolled_back \
        == e.stats.spec_drafted - e.stats.spec_accepted
    assert e.stats.host_syncs == e.stats.ticks


# ===================================================== token-budget audit
def test_k_token_rows_never_oversubscribe_token_budget(params):
    """THE latent-bug audit (failing-first): the old packing charged every
    decode row exactly ONE budget token, so appending k draft lanes
    unchecked would write past the fixed packed shape the step compiled
    for.  With token_budget == n_slots (the legal minimum) there is no
    surplus at full occupancy: speculation must quietly stand down (zero
    drafts packed) rather than oversubscribe, and every row still emits
    >= 1 token per tick."""
    rng = np.random.default_rng(4)
    eng = ServeEngine(CFG, params, n_slots=4, max_len=64, paged=True,
                      block_size=16, token_budget=4, spec_k=4,
                      draft_source=EagerDrafts())
    done = []
    eng.on_complete = done.append
    for r in _mk_reqs(rng, (2, 2, 2, 2), max_new=6):
        eng.submit(r)
    saw_full = False
    while not eng.idle():
        live = len(eng.live)
        before = eng.stats.spec_drafted
        eng.tick()
        drafted = eng.stats.spec_drafted - before
        # the audit: draft lanes only ever claim the surplus past every
        # live row's mandatory lane (pre-fix: the packing would overrun
        # the fixed T-lane arrays and crash/oversubscribe here)
        assert drafted <= max(0, eng.token_budget - live)
        saw_full = saw_full or live == eng.cm.n_slots
    assert saw_full                  # full occupancy (zero surplus) reached
    assert len(done) == 4 and all(len(r.tokens) == 6 for r in done)
    assert eng.stats.host_syncs == eng.stats.ticks


def test_draft_lanes_bounded_by_surplus(params):
    """With a surplus of 2 lanes over the mandatory ones, at most 2 draft
    tokens are packed per tick no matter how eager the drafter, and no
    live decode row is ever starved of its mandatory lane."""
    rng = np.random.default_rng(5)
    eng = ServeEngine(CFG, params, n_slots=4, max_len=64, paged=True,
                      block_size=16, token_budget=6, spec_k=4,
                      draft_source=EagerDrafts())
    done = []
    eng.on_complete = done.append
    for r in _mk_reqs(rng, (4, 4, 4, 4), max_new=6):
        eng.submit(r)
    drafted = []
    while not eng.idle():
        before = eng.stats.spec_drafted
        live = {s: len(r.tokens) for s, r in eng.live.items()}
        eng.tick()
        drafted.append(eng.stats.spec_drafted - before)
        # the surplus bound: drafts never exceed budget minus the live
        # rows' mandatory lanes (prefill chunks only tighten it further)
        assert drafted[-1] <= max(0, eng.token_budget - len(live))
        for s, n in live.items():
            req = eng.live.get(s)
            if req is not None:
                assert len(req.tokens) > n, "decode row starved by drafts"
    assert max(drafted, default=0) > 0       # speculation did engage
    assert len(done) == 4 and all(len(r.tokens) == 6 for r in done)


def test_long_prefill_with_speculation_never_stalls_decodes(params):
    """The head-of-line property survives speculation: while a long prompt
    chunk-prefills, every decoding session still advances every tick (by
    at least its mandatory token), and the sync invariant holds."""
    rng = np.random.default_rng(6)
    eng = ServeEngine(CFG, params, n_slots=4, max_len=96, paged=True,
                      block_size=16, token_budget=10, spec_k=2,
                      draft_source=EagerDrafts())
    done = []
    eng.on_complete = done.append
    eng.submit(Request(request_id="chat", session_key="c",
                       prompt=_toks(rng, 4), max_new_tokens=30))
    eng.tick()
    chat = next(iter(eng.live.values()))
    eng.submit(Request(request_id="wall", session_key="w",
                       prompt=_toks(rng, 60), max_new_tokens=2))
    while "wall" not in {r.request_id for r in done}:
        n_before = len(chat.tokens)
        eng.tick()
        assert len(chat.tokens) > n_before, "decode stalled behind prefill"
    eng.run_until_drained()
    assert {r.request_id for r in done} == {"chat", "wall"}
    assert eng.stats.host_syncs == eng.stats.ticks


def test_draft_ensure_skips_same_tick_finished_prompts(params):
    """Review regression (crashed pre-fix): the mid-tick draft ensure must
    grow ONLY the rows drafts were planned for.  A slot that completed a
    block-aligned, full-max_len prompt in this very tick already sits at
    pos = S with max_new_tokens == 1 — it will never decode-write, its
    admission budget reserved no decode block, and growing it would raise
    "overran max_len" and kill the whole tick for a perfectly valid
    request."""
    rng = np.random.default_rng(12)
    eng = ServeEngine(CFG, params, n_slots=4, max_len=32, paged=True,
                      block_size=16, token_budget=40, spec_k=4,
                      draft_source=EagerDrafts())
    done = []
    eng.on_complete = done.append
    eng.submit(Request(request_id="live", session_key="a",
                       prompt=_toks(rng, 4), max_new_tokens=20))
    eng.tick()                                # live decoding, drafts planned
    eng.submit(Request(request_id="edge", session_key="b",
                       prompt=_toks(rng, 32),       # == max_len, block-aligned
                       max_new_tokens=1))
    eng.run_until_drained()                   # pre-fix: RuntimeError mid-tick
    byid = {r.request_id: r for r in done}
    assert byid["edge"].error is None and len(byid["edge"].tokens) == 1
    assert byid["live"].error is None and len(byid["live"].tokens) == 20
    assert eng.stats.host_syncs == eng.stats.ticks


# ========================================================== gating + dense
def test_supports_speculative_gate():
    """Speculation is gated exactly like paging: pure-attention token
    models only.  A dense/SSM engine cannot be constructed with spec_k>0,
    so the dense phase-separated path is untouched by this feature."""
    assert supports_speculative(CFG)
    assert not supports_speculative(SSM)
    with pytest.raises(ValueError, match="speculative"):
        ServeEngine(SSM, None, spec_k=2)
    with pytest.raises(ValueError, match="spec_k"):
        ServeEngine(CFG, None, spec_k=-1)
    # same gate one level up: a dense deployment cannot be speculative
    from repro.serving.cluster import ServeNode
    with ServeNode(n_workers=1) as node:
        with pytest.raises(ValueError, match="spec_k"):
            node.deploy("ssm", SSM, None, n_replicas=1, spec_k=2)


# ============================================================ draft sources
def test_ngram_draft_source_prompt_lookup():
    src = NgramDraftSource(n=3)
    req = Request(request_id="r", session_key="s", prompt=None)
    hist = np.asarray([7, 1, 2, 3, 9, 9, 4, 1, 2, 3], np.int32)
    # suffix [1,2,3] matched at index 1 → continuation [9, 9, 4]
    assert src.propose(req, lambda: hist, 3) == [9, 9, 4]
    assert src.propose(req, lambda: hist, 2) == [9, 9]
    assert src.propose(req, lambda: np.asarray([1, 2, 3]), 2) == []  # no hist
    # the scan window is bounded: a match older than max_history is missed
    capped = NgramDraftSource(n=3, max_history=6)
    assert capped.propose(req, lambda: hist, 3) == []


def test_request_draft_source_never_builds_history():
    """The cascade-path source must not pay the O(prompt+generated) history
    concatenation on the tick's critical path."""
    def boom():
        raise AssertionError("cascade draft source touched history")

    src = RequestDraftSource()
    req = Request(request_id="r", session_key="s", prompt=None,
                  draft_tokens=np.asarray([5, 6, 7, 8], np.int32))
    req.tokens = [5, 6]
    assert src.propose(req, boom, 3) == [7, 8]
    req.tokens = [5, 9]                       # diverged: no more drafts
    assert src.propose(req, boom, 3) == []
    req.tokens = []
    assert src.propose(req, boom, 3) == []


def test_chain_draft_source_first_yield_wins():
    class A(DraftSource):
        def propose(self, req, history, k):
            return []

    class B(DraftSource):
        def propose(self, req, history, k):
            return [1, 2][:k]

    req = Request(request_id="r", session_key="s", prompt=None)
    assert ChainDraftSource([A(), B()]).propose(req, lambda: np.asarray([0]),
                                                2) == [1, 2]


# ===================================================== self-drafting cascade
def test_cascade_self_drafting_speculative_heavy(params):
    """CascadeServe closed loop: everything escalates (threshold 0 trips on
    any negative mean logprob), the escalated request carries the light
    generation as its draft, and the SPECULATIVE heavy deployment — same
    weights here, the perfect-drafter limit — verifies it at full
    acceptance while producing the exact greedy answer."""
    from repro.serving.cluster import CascadeGate, CascadeRoute, ServeNode

    rng = np.random.default_rng(7)
    with ServeNode(n_workers=2) as node:
        light = node.deploy("light", CFG, params, n_replicas=1, n_slots=4,
                            max_len=96)
        heavy = node.deploy("heavy", CFG, params, n_replicas=1, n_slots=4,
                            max_len=96, spec_k=3, token_budget=32)
        route = CascadeRoute(light, heavy,
                             CascadeGate("logprob", threshold=0.0))
        prompts = {f"r{i}": _toks(rng, 8 + 3 * i) for i in range(3)}
        for rid, p in prompts.items():
            route.submit(rid, rid, p, max_new_tokens=6)
        node.run_until_drained()
        hs, rs = heavy.stats(), route.stats()
        assert rs["escalated"] == 3          # threshold 0 trips everything
        assert hs["spec_drafted"] > 0
        assert hs["spec_accepted"] == hs["spec_drafted"]
        assert hs["spec_acceptance_rate"] == 1.0
        assert hs["spec_rolled_back"] == 0
        for rid in prompts:
            heavy_ans = route.result(rid)
            light_ans = light.result(rid)
            assert heavy_ans is not None and light_ans is not None
            # same weights + lossless speculation ⇒ identical greedy answers
            np.testing.assert_array_equal(heavy_ans, light_ans)
        for eng in light.engines + heavy.engines:
            assert eng.stats.host_syncs == eng.stats.ticks

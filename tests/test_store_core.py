"""K/V store semantics (paper §3.2): versioning, seqlock, replication,
trigger/volatile/persistent puts, temporal gets, access control.

Property tests use a seeded local random-case generator (deterministic, no
extra dependency) in place of hypothesis draws."""
import random
import threading
import time

import pytest

from repro.core import (CascadeObject, CascadeService, CascadeStore,
                        DispatchPolicy, Persistence, PoolSpec, Worker)
from repro.core.objects import monotonic_ns
from repro.core.versioning import SeqlockCell, VersionChain


# ---------------------------------------------------------------- seqlock
def test_seqlock_basic():
    c = SeqlockCell()
    assert c.load() is None
    o = CascadeObject(key="/k", payload=b"1")
    c.store(o)
    assert c.load().payload == b"1"


def test_seqlock_under_race():
    """A reader never observes a torn write (paper's v_a/v_b argument)."""
    c = SeqlockCell()
    stop = threading.Event()
    errors = []

    def writer():
        i = 0
        while not stop.is_set():
            c.store(CascadeObject(key="/k", payload=f"{i:012d}".encode() * 4))
            i += 1

    def reader():
        while not stop.is_set():
            o = c.load()
            if o is not None:
                s = o.payload
                chunks = {s[j : j + 12] for j in range(0, 48, 12)}
                if len(chunks) > 1:  # torn payload mixes two versions
                    errors.append(s)

    ts = [threading.Thread(target=writer), threading.Thread(target=reader),
          threading.Thread(target=reader)]
    for t in ts:
        t.start()
    time.sleep(0.2)
    stop.set()
    for t in ts:
        t.join()
    assert not errors


# ----------------------------------------------------------- version chain
@pytest.mark.parametrize("seed", range(12))
def test_chain_version_queries(seed):
    rng = random.Random(seed)
    payloads = [bytes(rng.getrandbits(8) for _ in range(rng.randint(1, 8)))
                for _ in range(rng.randint(1, 20))]
    ch = VersionChain()
    for i, p in enumerate(payloads):
        ch.append(CascadeObject(key="/k", payload=p), i)
    assert ch.latest().payload == payloads[-1]
    for i, p in enumerate(payloads):
        assert ch.at_version(i).payload == p
    full = ch.version_range(0, len(payloads) - 1)
    assert [o.payload for o in full] == payloads
    hi = max(1, len(payloads) - 2)
    mid = ch.version_range(1, hi)
    assert [o.version for o in mid] == [v for v in range(len(payloads)) if 1 <= v <= hi]


def test_chain_temporal():
    ch = VersionChain()
    stamps = []
    for i in range(5):
        o = ch.append(CascadeObject(key="/k", payload=str(i).encode()), i)
        stamps.append(o.timestamp_ns)
    for i, ts in enumerate(stamps):
        assert ch.at_time(ts).version == i
    assert ch.at_time(stamps[0] - 1) is None
    got = ch.time_range(stamps[1], stamps[3])
    assert [o.version for o in got] == [1, 2, 3]


# ------------------------------------------------------------------ store
def make_store(n=4, **kw):
    return CascadeStore([Worker(i, **kw) for i in range(n)])


def test_volatile_put_replicates_to_all_members():
    s = make_store()
    s.create_pool(PoolSpec(path="/v", replication=4))
    s.put("/v/k", b"x")
    holders = [w for w in s.workers.values() if w.load_latest("/v/k")]
    assert len(holders) == 4
    s.close()


def test_trigger_put_stores_nothing():
    s = make_store()
    s.create_pool(PoolSpec(path="/t", persistence=Persistence.TRANSIENT))
    r = s.trigger_put("/t/k", b"x")
    assert all(w.load_latest("/t/k") is None for w in s.workers.values())
    assert s.get("/t/k") is None
    s.close()


def test_get_any_member_consistent():
    s = make_store()
    s.create_pool(PoolSpec(path="/v", replication=2))
    for i in range(10):
        s.put("/v/k", str(i).encode())
    for _ in range(20):  # get picks a random member; all must agree
        assert s.get("/v/k").payload == b"9"
    s.close()


def test_version_monotonic_per_key():
    s = make_store()
    s.create_pool(PoolSpec(path="/v", replication=2))
    versions = [s.put("/v/k", str(i).encode()).obj.version for i in range(5)]
    assert versions == sorted(versions)
    assert len(set(versions)) == 5
    s.close()


def test_persistent_put_survives_in_log(tmp_path):
    s = CascadeStore([Worker(i, log_dir=str(tmp_path / f"w{i}")) for i in range(2)])
    s.create_pool(PoolSpec(path="/p", persistence=Persistence.PERSISTENT,
                           replication=2))
    s.put("/p/k", b"alpha")
    s.put("/p/k", b"beta")
    w = next(iter(s.workers.values()))
    log = w.logs["/p"]
    objs = log.version_range_from_disk("/p/k", 0, 10)
    assert [o.payload for o in objs] == [b"alpha", b"beta"]
    s.close()


def test_remove_pool_tears_down_storage_and_log_handles(tmp_path):
    """Pool teardown drops volatile chains, shard state, AND the open
    persistent-log handles (no leaked file objects, no stale cached log
    serving a later tenant); the on-disk log itself survives — persistent
    pools are durable by definition, so a re-created pool resumes it the
    way a restarted node would."""
    s = CascadeStore([Worker(0, log_dir=str(tmp_path / "w0"))])
    s.create_pool(PoolSpec(path="/p", persistence=Persistence.PERSISTENT))
    s.put("/p/k", b"alpha")
    w = s.workers[0]
    old_log = w.logs["/p"]
    s.remove_pool("/p")
    assert "/p" not in w.logs                 # handle dropped and closed
    assert w.load_latest("/p/k") is None      # volatile chain gone
    with pytest.raises(KeyError):
        s.put("/p/k", b"orphan")              # no pool owns the key anymore
    # durable storage: a re-created pool opens a FRESH handle onto the
    # surviving log file and appends after the old records
    s.create_pool(PoolSpec(path="/p", persistence=Persistence.PERSISTENT))
    s.put("/p/k", b"beta")
    new_log = w.logs["/p"]
    assert new_log is not old_log
    objs = new_log.version_range_from_disk("/p/k", 0, 10)
    assert [o.payload for o in objs] == [b"alpha", b"beta"]
    s.close()


def test_persistent_put_acks_after_all_members_stable(tmp_path):
    """§3.2: a persistent put is acknowledged only once EVERY member's log
    has the record durable — not just the last member's."""
    s = CascadeStore([Worker(i, log_dir=str(tmp_path / f"w{i}")) for i in range(3)])
    s.create_pool(PoolSpec(path="/p", persistence=Persistence.PERSISTENT,
                           replication=3))
    for i in range(4):
        s.put("/p/k", str(i).encode())
        # at ack time, every member must have flushed this record to disk
        for w in s.workers.values():
            log = w.logs["/p"]
            assert log.flushed_records >= i + 1
            assert log.latest("/p/k").payload == str(i).encode()
    s.close()


def test_persistent_put_concurrent_writers_ack_independently(tmp_path):
    """A put waits for ITS record's stability, not for the whole write-back
    queue — concurrent writers must not inherit each other's latency or
    trip the stability timeout."""
    s = CascadeStore([Worker(i, log_dir=str(tmp_path / f"w{i}")) for i in range(2)])
    s.create_pool(PoolSpec(path="/p", persistence=Persistence.PERSISTENT,
                           replication=2))
    errors = []

    def writer(tag):
        try:
            for i in range(25):
                s.put(f"/p/{tag}", f"{tag}-{i}".encode())
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    ts = [threading.Thread(target=writer, args=(t,)) for t in ("a", "b", "c")]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors
    for w in s.workers.values():
        log = w.logs["/p"]
        assert log.latest("/p/a").payload == b"a-24"
        assert log.latest("/p/b").payload == b"b-24"
    s.close()


def test_temporal_get_through_log(tmp_path):
    s = CascadeStore([Worker(0, log_dir=str(tmp_path / "w0"))])
    s.create_pool(PoolSpec(path="/p", persistence=Persistence.PERSISTENT))
    r1 = s.put("/p/k", b"one")
    time.sleep(0.002)
    r2 = s.put("/p/k", b"two")
    assert s.get_time("/p/k", r1.obj.timestamp_ns).payload == b"one"
    assert s.get_time("/p/k", r2.obj.timestamp_ns).payload == b"two"
    s.close()


def test_fifo_trigger_put_reaches_all_shard_members():
    """FIFO member pick must be decorrelated from the shard pick: with
    2 shards × 2 members, every worker must be reachable, and a given key
    must always land on the same worker (affinity)."""
    s = make_store(4)
    s.create_pool(PoolSpec(path="/f", persistence=Persistence.TRANSIENT,
                           replication=2, dispatch=DispatchPolicy.FIFO))
    targets = {}
    for i in range(64):
        key = f"/f/k{i}"
        t1 = s.trigger_put(key, b"x").processing_worker
        t2 = s.trigger_put(key, b"x").processing_worker
        assert t1 == t2, "FIFO affinity broken: same key moved workers"
        targets[key] = t1
    assert set(targets.values()) == set(s.workers), \
        f"unreachable workers: {set(s.workers) - set(targets.values())}"
    s.close()


def test_access_control():
    s = make_store()
    s.create_pool(PoolSpec(path="/acl", writers=frozenset({"alice"})))
    with pytest.raises(PermissionError):
        s.put("/acl/k", b"x", principal="bob")
    s.put("/acl/k", b"x", principal="alice")
    s.close()


def test_pool_routing_longest_prefix():
    s = make_store()
    s.create_pool(PoolSpec(path="/a"))
    s.create_pool(PoolSpec(path="/a/b", replication=2))
    spec, members = s._route("/a/b/k")
    assert spec.path == "/a/b" and len(members) == 2
    spec2, _ = s._route("/a/x")
    assert spec2.path == "/a"
    s.close()


def test_affinity_hash_groups_related_keys():
    from repro.core.pools import affinity_shard_hash
    h1 = affinity_shard_hash("/cams/cam0/frame/1")
    h2 = affinity_shard_hash("/cams/cam0/frame/2")
    h3 = affinity_shard_hash("/cams/cam1/frame/1")
    assert h1 == h2  # same camera → same home shard
    assert h1 != h3 or True  # different camera usually differs (no guarantee)

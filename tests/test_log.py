"""Persistent log (paper §3.6): write-back batching, mmap reads, backpointer
range queries, temporal index, stable-prefix blocking, crash recovery."""
import os
import threading
import time

import pytest

from repro.core.log import PersistentLog
from repro.core.objects import monotonic_ns


def test_append_get_roundtrip(tmp_path):
    log = PersistentLog(str(tmp_path / "a.log"))
    o1 = log.append("/k", b"v1")
    o2 = log.append("/k", b"v2")
    assert log.latest("/k").payload == b"v2"
    assert log.get_version("/k", o1.version).payload == b"v1"
    log.close()


def test_backpointer_chain_on_disk(tmp_path):
    log = PersistentLog(str(tmp_path / "a.log"))
    for i in range(10):
        log.append("/k", f"v{i}".encode())
        log.append("/other", b"noise")  # interleave another key
    objs = log.version_range_from_disk("/k", 0, 100)
    assert [o.payload for o in objs] == [f"v{i}".encode() for i in range(10)]
    log.close()


def test_write_back_batches(tmp_path):
    """Many unwaited appends should flush in fewer batches than records."""
    log = PersistentLog(str(tmp_path / "a.log"), flush_interval_s=0.01)
    for i in range(200):
        log.append("/k", b"x" * 100, wait_stable=False)
    log.append("/k", b"final")  # wait for stability
    assert log.flushed_records >= 201
    assert log.flush_batches < log.flushed_records
    log.close()


def test_temporal_get_and_range(tmp_path):
    log = PersistentLog(str(tmp_path / "a.log"))
    stamps = []
    for i in range(5):
        o = log.append("/k", f"v{i}".encode())
        stamps.append(o.timestamp_ns)
        time.sleep(0.001)
    assert log.get_time("/k", stamps[2]).payload == b"v2"
    rng = log.time_range("/k", stamps[1], stamps[3])
    assert [o.payload for o in rng] == [b"v1", b"v2", b"v3"]
    log.close()


def test_stable_prefix_blocks_future_reads(tmp_path):
    """A temporal get 'into the future' must not return early (§3.6)."""
    log = PersistentLog(str(tmp_path / "a.log"))
    log.append("/k", b"v0")
    future = monotonic_ns() + int(0.15e9)
    t0 = time.monotonic()
    log.get_time("/k", future, timeout_s=2.0)
    assert time.monotonic() - t0 >= 0.10  # actually waited for the frontier
    log.close()


def test_recovery_after_restart(tmp_path):
    path = str(tmp_path / "a.log")
    log = PersistentLog(path)
    for i in range(7):
        log.append("/k", f"v{i}".encode())
    log.append("/j", b"other")
    log.close()

    log2 = PersistentLog(path)
    assert log2.latest("/k").payload == b"v6"
    assert log2.latest("/j").payload == b"other"
    objs = log2.version_range_from_disk("/k", 0, 100)
    assert len(objs) == 7
    # appends continue with fresh versions
    o = log2.append("/k", b"post")
    assert o.version == 8
    log2.close()


def test_concurrent_appenders(tmp_path):
    log = PersistentLog(str(tmp_path / "a.log"))
    n_threads, per = 4, 25

    def work(t):
        for i in range(per):
            log.append(f"/t{t}", f"{t}:{i}".encode(), wait_stable=False)

    ts = [threading.Thread(target=work, args=(t,)) for t in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    log.append("/done", b"x")  # barrier on stability
    for t in range(n_threads):
        objs = log.version_range_from_disk(f"/t{t}", 0, 10_000)
        assert [o.payload for o in objs] == [f"{t}:{i}".encode() for i in range(per)]
    log.close()

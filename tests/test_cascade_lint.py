"""Unit tests for the cascade-lint static passes.

Each pass gets: a seeded violation that must be flagged, a clean snippet
that must not be, and pragma-suppression checks — including the rule that
a ``guarded-by`` pragma naming the WRONG lock keeps the finding, so
annotations cannot rot silently.  The final test runs the full driver
over ``src/repro`` and requires zero unsuppressed findings: the tree is
clean (fixed or pragma-justified) by construction.
"""
import textwrap
from pathlib import Path

from repro.analysis import (
    DonationPass,
    LockDisciplinePass,
    SourceInfo,
    SyncDisciplinePass,
    lint_paths,
)

REPO_SRC = Path(__file__).resolve().parents[1] / "src" / "repro"


def _run(pass_cls, source: str):
    src = SourceInfo.from_source(textwrap.dedent(source), "snippet.py")
    return pass_cls().run(src)


# --------------------------------------------------------------------------
# Pass 1: lock discipline
# --------------------------------------------------------------------------

LOCKED_CLASS = """
    import threading

    class Box:
        def __init__(self):
            self._lock = threading.Lock()
            self.items = []

        def add(self, x):
            with self._lock:
                self.items.append(x)

        def {bad_method}
"""


def test_lock_pass_flags_unguarded_mutation():
    src = LOCKED_CLASS.format(bad_method=(
        "drain(self):\n            self.items = []"))
    findings = _run(LockDisciplinePass, src)
    assert len(findings) == 1
    f = findings[0]
    assert f.rule == "lock-discipline"
    assert "Box.items" in f.message and "_lock" in f.message


def test_lock_pass_flags_unguarded_mutator_call():
    src = LOCKED_CLASS.format(bad_method=(
        "steal(self):\n            self.items.pop()"))
    findings = _run(LockDisciplinePass, src)
    assert len(findings) == 1
    assert "Box.items" in findings[0].message


def test_lock_pass_clean_when_consistent():
    src = LOCKED_CLASS.format(bad_method=(
        "drain(self):\n            with self._lock:\n"
        "                self.items = []"))
    assert _run(LockDisciplinePass, src) == []


def test_lock_pass_ignores_init_and_unlocked_attrs():
    # construction is single-threaded; attrs never locked are single-writer
    src = """
        import threading

        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self.items = []
                self.count = 0

            def bump(self):
                self.count += 1

            def add(self, x):
                with self._lock:
                    self.items.append(x)
    """
    assert _run(LockDisciplinePass, src) == []


def test_lock_pass_pragma_with_held_local_lock_suppresses():
    src = """
        import threading

        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self.items = []

            def add(self, x):
                with self._lock:
                    self.items.append(x)

            def drain(self, other_lock):
                with other_lock:
                    # lint: guarded-by(other_lock) shard lock owns this slice
                    self.items = []
    """
    assert _run(LockDisciplinePass, src) == []


def test_lock_pass_wrong_lock_name_pragma_still_flags():
    src = """
        import threading

        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self.items = []

            def add(self, x):
                with self._lock:
                    self.items.append(x)

            def drain(self):
                # lint: guarded-by(_other_lock) stale justification
                self.items = []
    """
    findings = _run(LockDisciplinePass, src)
    assert len(findings) == 1
    assert "pragma names" in findings[0].message
    assert "_other_lock" in findings[0].message


def test_lock_pass_nested_def_loses_held_set():
    # a closure defined inside `with` runs later, when the lock is gone
    src = """
        import threading

        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self.items = []

            def add(self, x):
                with self._lock:
                    self.items.append(x)

            def sched(self, pool):
                with self._lock:
                    def later():
                        self.items = []
                    pool.submit(later)
    """
    findings = _run(LockDisciplinePass, src)
    assert len(findings) == 1
    assert "Box.items" in findings[0].message


# --------------------------------------------------------------------------
# Pass 2: host-sync discipline
# --------------------------------------------------------------------------

def test_sync_pass_flags_device_get_outside_sync_site():
    findings = _run(SyncDisciplinePass, """
        import jax

        def peek(arr):
            return jax.device_get(arr)
    """)
    assert len(findings) == 1
    assert findings[0].rule == "host-sync"
    assert "device_get" in findings[0].message


def test_sync_pass_flags_item_and_block_until_ready():
    findings = _run(SyncDisciplinePass, """
        def peek(arr):
            arr.block_until_ready()
            return arr.item()
    """)
    assert [f.line for f in findings] == [3, 4]


def test_sync_pass_flags_implicit_asarray_of_device_value():
    findings = _run(SyncDisciplinePass, """
        import jax.numpy as jnp
        import numpy as np

        def step(x):
            y = jnp.sum(x)
            return np.asarray(y)
    """)
    assert len(findings) == 1
    assert "np.asarray" in findings[0].message


def test_sync_pass_flags_float_of_jitted_result():
    findings = _run(SyncDisciplinePass, """
        import jax

        _step = jax.jit(lambda x: x)

        def drive(x):
            out = _step(x)
            return float(out)
    """)
    assert len(findings) == 1
    assert "float()" in findings[0].message


def test_sync_pass_clean_on_host_math():
    assert _run(SyncDisciplinePass, """
        import numpy as np

        def host_only(xs):
            acc = np.asarray(xs)
            return float(sum(xs))
    """) == []


def test_sync_pass_sync_site_pragma_exempts_function():
    findings = _run(SyncDisciplinePass, """
        import jax

        class Engine:
            # lint: sync-site(the one per-tick pull)
            def _to_host(self, arr):
                return jax.device_get(arr)

            def peek(self, arr):
                return jax.device_get(arr)
    """)
    assert len(findings) == 1
    assert "Engine.peek" in findings[0].message


def test_sync_pass_allow_sync_pragma_suppresses():
    assert _run(SyncDisciplinePass, """
        import jax

        def debug_dump(arr):
            return jax.device_get(arr)  # lint: allow-sync(offline debug path)
    """) == []


def test_runner_enforces_single_sync_site_budget(tmp_path):
    serving = tmp_path / "serving"
    serving.mkdir()
    site = ("import jax\n\n\n"
            "# lint: sync-site(per-tick pull)\n"
            "def pull(arr):\n"
            "    return jax.device_get(arr)\n")
    (serving / "a.py").write_text(site)
    (serving / "b.py").write_text(site.replace("pull", "pull2"))
    findings = lint_paths([str(tmp_path)])
    assert len(findings) == 1
    assert "second `sync-site` pragma" in findings[0].message


def test_runner_sync_site_budget_covers_fault_injection_module(tmp_path):
    """The fault-injection seam lives in ``serving/`` — a spill path (or any
    fault hook) declaring its own sanctioned sync site must trip the global
    budget rather than quietly becoming a second sync seam (spills are
    required to pull through the engine's one site)."""
    serving = tmp_path / "serving"
    serving.mkdir()
    (serving / "engine.py").write_text(
        "import jax\n\n\n"
        "# lint: sync-site(THE one per-tick device->host pull)\n"
        "def _to_host(arr):\n"
        "    return jax.device_get(arr)\n")
    (serving / "faults.py").write_text(
        "import jax\n\n\n"
        "# lint: sync-site(spill pull)\n"
        "def spill_pull(arr):\n"
        "    return jax.device_get(arr)\n")
    findings = lint_paths([str(tmp_path)])
    assert len(findings) == 1
    assert findings[0].path.endswith("faults.py")
    assert "second `sync-site` pragma" in findings[0].message


# --------------------------------------------------------------------------
# Pass 3: donation & recompile hazards
# --------------------------------------------------------------------------

DONATING = """
    import jax

    _step = jax.jit(lambda p, s: s, donate_argnums=(1,))

    def drive(params, state):
        out = _step(params, state)
        {after}
"""


def test_donation_pass_flags_read_after_donate():
    findings = _run(DonationPass, DONATING.format(after="return state"))
    assert len(findings) == 1
    f = findings[0]
    assert f.rule == "donation"
    assert "use-after-donate" in f.message and "state" in f.message


def test_donation_pass_rebind_revives_operand():
    assert _run(DonationPass, DONATING.format(
        after="state = out\n        return state")) == []


def test_donation_pass_pragma_suppresses():
    assert _run(DonationPass, DONATING.format(
        after="return state  # lint: allow-donated-read(aliased on purpose)"
    )) == []


def test_donation_pass_tracks_self_attributes():
    findings = _run(DonationPass, """
        import jax

        class Engine:
            def __init__(self, fn):
                self._mixed = jax.jit(fn, donate_argnums=(1,))

            def tick(self, bt):
                pools = self._mixed(self.params, self.cm.pools, bt)
                return self.cm.pools.shape
    """)
    assert len(findings) == 1
    assert "self.cm.pools" in findings[0].message


def test_recompile_pass_flags_scalar_literal_to_jit():
    findings = _run(DonationPass, """
        import jax

        _step = jax.jit(lambda x, n: x)

        def drive(x):
            return _step(x, 7)
    """)
    assert len(findings) == 1
    assert findings[0].rule == "recompile"
    assert "static_argnums" in findings[0].message


def test_recompile_pass_flags_len_argument():
    findings = _run(DonationPass, """
        import jax

        _step = jax.jit(lambda x, n: x)

        def drive(x, rows):
            return _step(x, len(rows))
    """)
    assert len(findings) == 1
    assert "len(...)" in findings[0].message


def test_recompile_pass_static_argnums_is_clean():
    assert _run(DonationPass, """
        import jax

        _step = jax.jit(lambda x, n: x, static_argnums=(1,))

        def drive(x):
            return _step(x, 7)
    """) == []


def test_recompile_pass_static_ok_pragma_suppresses():
    assert _run(DonationPass, """
        import jax

        _step = jax.jit(lambda x, n: x)

        def drive(x):
            return _step(x, 7)  # lint: static-ok(constant per build)
    """) == []


# --------------------------------------------------------------------------
# The whole tree is clean under all three passes
# --------------------------------------------------------------------------

def test_full_tree_is_clean():
    findings = lint_paths([str(REPO_SRC)])
    assert findings == [], "\n".join(f.render() for f in findings)

"""Device fast path + device store: fusion equivalence, handoff, broker hop,
versioned device objects."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DeviceStore, FastPathPipeline, PoolSpec, Stage,
                        broker_hop, chain_stages, fuse_stages)
from repro.core.pools import Persistence


def _stages():
    return [
        Stage("a", lambda x: x * 2.0),
        Stage("b", lambda x: x + 1.0),
        Stage("c", lambda x: jnp.tanh(x)),
    ]


def test_fused_equals_chained_equals_broker():
    x = jnp.arange(8.0)
    expected = jnp.tanh(x * 2.0 + 1.0)
    fused = fuse_stages(_stages(), donate=False)(x)
    chained = chain_stages(_stages())(jnp.arange(8.0))
    hopped = x
    for st in _stages():
        hopped = st.fn(broker_hop(hopped))
    np.testing.assert_allclose(fused, expected, rtol=1e-6)
    np.testing.assert_allclose(chained, expected, rtol=1e-6)
    np.testing.assert_allclose(hopped, expected, rtol=1e-6)


def test_fastpath_pipeline_groups_collocated_stages():
    pipe = FastPathPipeline(_stages())
    run = pipe.build()
    out = run(jnp.arange(8.0))
    np.testing.assert_allclose(out, jnp.tanh(jnp.arange(8.0) * 2.0 + 1.0),
                               rtol=1e-6)


def test_fastpath_pipeline_donates_intermediate_groups(monkeypatch):
    """Regression: build() must keep the zero-copy donation discipline for
    every group after the one consuming the caller's input (no extra buffer
    per inter-group handoff)."""
    from repro.core import fastpath as fp

    seen = []
    real = fp.fuse_stages

    def spy(stages, *, donate=True):
        seen.append(donate)
        return real(stages, donate=donate)

    monkeypatch.setattr(fp, "fuse_stages", spy)
    # three placement groups: None, sharded, None
    dev = jax.devices()[0]
    place = jax.sharding.SingleDeviceSharding(dev)
    stages = [
        Stage("a", lambda x: x * 2.0),
        Stage("b", lambda x: x + 1.0),
        Stage("c", lambda x: x - 3.0, out_sharding=place),
        Stage("d", lambda x: jnp.tanh(x)),
    ]
    run = fp.FastPathPipeline(stages).build()
    assert seen == [False, True, True]
    x = jnp.arange(8.0)
    out = run(x)
    np.testing.assert_allclose(out, jnp.tanh(jnp.arange(8.0) * 2.0 + 1.0 - 3.0),
                               rtol=1e-6)
    # the caller's input was NOT donated and is still readable
    np.testing.assert_allclose(np.asarray(x), np.arange(8.0))

    seen.clear()
    run2 = fp.FastPathPipeline(stages).build(donate_input=True)
    assert seen == [True, True, True]
    np.testing.assert_allclose(run2(jnp.arange(8.0)),
                               jnp.tanh(jnp.arange(8.0) * 2.0 + 1.0 - 3.0),
                               rtol=1e-6)


def test_fused_program_is_single_dispatch():
    """Fusion compiles the chain into one executable (the DLL-lambda rung)."""
    fused = fuse_stages(_stages(), donate=False)
    lowered = fused.lower(jnp.arange(8.0))
    text = lowered.as_text()
    assert text.count("func.func public @main") == 1


def test_devstore_versions_and_time_travel():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ds = DeviceStore(mesh, keep_versions=3)
    ds.create_pool(PoolSpec(path="/w", persistence=Persistence.VOLATILE,
                            device_axes=(None, None)))
    for i in range(4):
        ds.put("/w/m", jnp.full((2, 2), float(i)))
    assert ds.latest_version("/w/m") == 3
    assert float(ds.get("/w/m")[0, 0]) == 3.0
    # keep_versions=3: version 0 evicted, 1..3 retained
    assert ds.get("/w/m", version=0) is None or float(ds.get("/w/m", version=1)[0, 0]) == 1.0
    assert float(ds.get("/w/m", version=2)[0, 0]) == 2.0


def test_devstore_zero_copy_put():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ds = DeviceStore(mesh)
    ds.create_pool(PoolSpec(path="/w", device_axes=(None,)))
    arr = jax.device_put(jnp.arange(4.0), ds.sharding_for("/w/x"))
    stored = ds.put("/w/x", arr, donate=True)
    assert stored is arr  # reference install, no copy


def test_devstore_snapshot():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ds = DeviceStore(mesh)
    ds.create_pool(PoolSpec(path="/ckpt", persistence=Persistence.PERSISTENT,
                            device_axes=(None,)))
    ds.put("/ckpt/a", jnp.arange(3.0))
    ds.put("/ckpt/b", jnp.ones((2,)))
    snap = ds.snapshot("/ckpt")
    assert set(snap) == {"/ckpt/a", "/ckpt/b"}
    np.testing.assert_array_equal(snap["/ckpt/a"], np.arange(3.0))

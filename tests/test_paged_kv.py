"""Paged KV cache + trie prefix reuse: allocator bookkeeping (refcounts, LRU
eviction, COW-by-alignment), block-budget admission, and the serving engine
on the paged fast path (warm sessions skip prefix prefill; one device→host
sync per tick still holds; paged == dense token streams)."""
import jax
import numpy as np
import pytest

from repro.models import ModelConfig, init_params, supports_paged
from repro.serving.engine import ServeEngine
from repro.serving.kvcache import PagedCacheManager, PrefixBlockAllocator
from repro.serving.scheduler import Request, Scheduler

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab_size=128, dtype="float32",
                  q_chunk=16)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _toks(rng, n):
    return rng.integers(0, CFG.vocab_size, (n,)).astype(np.int32)


# ===================================================== allocator bookkeeping
def test_allocator_match_then_reuse_refcounts():
    a = PrefixBlockAllocator(num_blocks=8, block_size=4)
    toks = list(range(12))                        # 3 full blocks
    table = a.allocate(3)
    assert a.cache_blocks(toks, table) == 3
    a.unref(table)                                # request done; blocks cached
    assert a.available() == 7                     # all reclaimable, none free
    assert len(a.free) == 4
    # a new prompt sharing 2 blocks then diverging matches exactly 2
    toks2 = list(range(8)) + [99, 98, 97, 96]
    m = a.match(toks2, max_blocks=3)
    assert m == table[:2]
    assert a.refcount[m[0]] == 1 and a.refcount[m[1]] == 1
    a.unref(m)
    assert a.refcount[m[0]] == 0


def test_allocator_block_aligned_reuse_never_writes_shared():
    """COW degenerates to refcounting: reuse is capped below the full prompt,
    so the suffix (>=1 token) always lands in fresh private blocks."""
    a = PrefixBlockAllocator(num_blocks=8, block_size=4)
    toks = list(range(8))                         # exactly 2 full blocks
    t1 = a.allocate(2)
    a.cache_blocks(toks, t1)
    a.unref(t1)
    # same prompt again: at most (S-1)//bs = 1 block may be reused — the
    # last block is recomputed so last-token logits exist
    m = a.match(toks, max_blocks=(len(toks) - 1) // 4)
    assert m == t1[:1]
    a.unref(m)


def test_allocator_lru_eviction_order_and_child_pinning():
    a = PrefixBlockAllocator(num_blocks=4, block_size=2)   # 3 usable blocks
    t1 = a.allocate(2)
    a.cache_blocks([1, 2, 3, 4], t1)              # chain: parent + child
    a.unref(t1)
    t2 = a.allocate(1)
    a.cache_blocks([9, 9], t2)
    a.unref(t2)
    assert a.n_cached == 3 and len(a.free) == 0
    # the [1,2] parent is the globally-oldest entry but is PINNED by its
    # cached child, so eviction takes the child (oldest unpinned), not [9,9]
    t3 = a.allocate(1)
    assert t3 is not None and a.evictions == 1
    assert set(m.key for m in a._cached.values()) == {"/1-2", "/9-9"}
    # now the parent is unpinned and older than [9,9] → evicted next
    t4 = a.allocate(1)
    assert t4 is not None and a.evictions == 2
    assert [m.key for m in a._cached.values()] == ["/9-9"]
    a.unref(t3 + t4)
    assert a.match([9, 9, 5, 5], max_blocks=1) != []


def test_allocator_exhaustion_returns_none():
    a = PrefixBlockAllocator(num_blocks=4, block_size=2)
    t = a.allocate(3)
    assert t is not None
    assert a.allocate(1) is None                  # all blocks referenced
    a.unref(t)
    assert a.allocate(1) is not None


def test_manager_rejects_prompt_longer_than_max_len():
    """An oversized prompt must fail fast with a clear error (not overflow
    the fixed-width block table mid-admission) and leak nothing."""
    cm = PagedCacheManager(CFG, n_slots=1, max_len=16, block_size=8,
                           num_blocks=12)
    slot = cm.acquire("r1")
    with pytest.raises(ValueError, match="max_len"):
        cm.begin(slot, np.arange(24, dtype=np.int32), max_new_tokens=4)
    assert cm.n_active == 0 and cm.blocks_in_use == 0
    assert cm.block_tables().shape == (1, 2)


def test_manager_reserves_decode_growth():
    cm = PagedCacheManager(CFG, n_slots=2, max_len=32, block_size=8,
                           num_blocks=9)          # 8 usable
    slot = cm.acquire("r1")
    seq = cm.begin(slot, np.arange(8, dtype=np.int32), max_new_tokens=17)
    assert seq is not None and len(seq.table) == 1
    # 8 prompt + 16 written decode tokens → reserve 3 blocks, 2 outstanding
    assert seq.reserve == 3
    assert cm.available_for_admission() == 8 - 1 - 2


# ====================================================== scheduler admission
def test_scheduler_block_budget_is_head_of_line():
    s = Scheduler(n_replicas=1, prefill_budget=8)
    for i, n in enumerate((4, 1, 1)):
        s.submit(Request(request_id=f"r{i}", session_key="s", prompt=None,
                         max_new_tokens=n))
    cost = lambda r: {"r0": 4, "r1": 1, "r2": 1}[r.request_id]
    # r0 does not fit; r1/r2 must NOT leapfrog it (FIFO sessions stay ordered)
    assert s.admit_one(0, free_slots=3, free_blocks=3, block_cost=cost) is None
    assert s.pending(0) == 3
    # the engine loop re-reads the block budget between admissions
    got = []
    for free in (5, 1, 0):
        r = s.admit_one(0, free_slots=3, free_blocks=free, block_cost=cost)
        if r is not None:
            got.append(r.request_id)
    assert got == ["r0", "r1"] and s.pending(0) == 1


# ========================================================== engine fast path
def _run(params, reqs, **kw):
    eng = ServeEngine(CFG, params, n_slots=4, max_len=96, **kw)
    done = []
    eng.on_complete = done.append
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return eng, {r.request_id: list(r.tokens) for r in done}


def test_paged_engine_matches_dense_tokens(params):
    rng = np.random.default_rng(0)
    prompts = [_toks(rng, L) for L in (5, 40, 17, 40, 3)]
    mk = lambda: [Request(request_id=f"r{i}", session_key="s", prompt=p,
                          max_new_tokens=4) for i, p in enumerate(prompts)]
    _, dense = _run(params, mk(), paged=False)
    eng, paged = _run(params, mk(), paged=True, block_size=16)
    assert dense == paged
    # THE unified-tick invariant: one mixed dispatch, one sync, per tick
    assert eng.stats.host_syncs == eng.stats.ticks


def test_warm_session_skips_prefix_prefill(params):
    """The acceptance check: a warm multi-turn session reuses its prefix —
    prefix_hit_tokens > 0 and strictly fewer tokens are prefilled than the
    prompt carries (skipped-block count × block size) — while the
    one-sync-per-tick rule still holds and outputs match a cold engine."""
    rng = np.random.default_rng(1)
    eng = ServeEngine(CFG, params, n_slots=4, max_len=96, paged=True,
                      block_size=16)
    done = []
    eng.on_complete = done.append
    p1 = _toks(rng, 40)
    eng.submit(Request(request_id="t1", session_key="s", prompt=p1,
                       max_new_tokens=4))
    eng.run_until_drained()
    assert eng.stats.prefix_hit_tokens == 0            # cold
    # turn 2: the session's history (prompt + all generated tokens) plus new
    # user tokens — exactly what FIFO affinity delivers back to this replica
    p2 = np.concatenate([p1, np.asarray(done[0].tokens, np.int32),
                         _toks(rng, 7)])
    eng.submit(Request(request_id="t2", session_key="s", prompt=p2,
                       max_new_tokens=4))
    eng.run_until_drained()
    # turn 1 wrote KV for 40 + 3 tokens → 2 full blocks of 16 are cached
    assert eng.stats.prefix_hit_tokens == 32
    assert eng.stats.prefix_hits == 1
    skipped_blocks = eng.stats.prefix_hit_tokens // 16
    assert skipped_blocks == 2
    # strictly fewer prefill FLOPs: prefilled tokens < prompt tokens
    assert eng.stats.prefill_tokens == eng.stats.prompt_tokens - 32
    assert eng.stats.host_syncs == eng.stats.ticks
    assert eng.stats.blocks_in_use > 0
    # reused-prefix decode must equal a cold full recompute
    _, cold = _run(params, [Request(request_id="t2", session_key="s",
                                    prompt=p2, max_new_tokens=4)], paged=False)
    assert cold["t2"] == done[1].tokens


def test_paged_decode_via_pallas_kernel_matches_xla(params):
    """The block-gather Pallas kernel wired through the model: same tokens
    as the XLA gather path."""
    rng = np.random.default_rng(2)
    p = _toks(rng, 20)
    mk = lambda: [Request(request_id="k", session_key="s", prompt=p,
                          max_new_tokens=3)]
    _, xla = _run(params, mk(), paged=True, block_size=16)
    cfg_p = CFG.replace(attn_backend="pallas_interpret")
    eng = ServeEngine(cfg_p, params, n_slots=4, max_len=96, paged=True,
                      block_size=16)
    done = []
    eng.on_complete = done.append
    eng.submit(mk()[0])
    eng.run_until_drained()
    assert list(done[0].tokens) == xla["k"]


def test_prefix_cache_eviction_under_pressure(params):
    """A tiny pool: old sessions' cached blocks are evicted LRU-first and
    serving keeps going (admission never overruns the pool)."""
    rng = np.random.default_rng(3)
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, paged=True,
                      block_size=16, num_blocks=9)     # 8 usable blocks
    for i in range(6):
        eng.submit(Request(request_id=f"r{i}", session_key=f"s{i}",
                           prompt=_toks(rng, 33), max_new_tokens=2))
    eng.run_until_drained()
    assert eng.stats.prefills == 6
    assert eng.cm.alloc.evictions > 0
    assert eng.cm.n_active == 0
    assert eng.stats.host_syncs == eng.stats.ticks


# ============================================ review regressions (PR 2 fixes)
def test_allocator_commit_dedup_swaps_duplicates():
    """Two tables caching the same not-yet-cached prefix: the second commit
    must adopt the incumbent blocks (table rewritten in place) and free its
    duplicates, so available() only counts truly reclaimable blocks."""
    a = PrefixBlockAllocator(num_blocks=8, block_size=4)
    shared = [1, 2, 3, 4, 5, 6, 7, 8]             # 2 full blocks
    ta = a.allocate(3)
    tb = a.allocate(3)
    a.cache_blocks(shared + [10, 11, 12, 13], ta)
    dup = list(tb)
    assert a.cache_blocks(shared + [20, 21, 22, 23], tb) == 1  # divergent only
    assert tb[:2] == ta[:2] and a.dedup_blocks == 2
    assert a.refcount[ta[0]] == 2 and a.refcount[ta[1]] == 2
    assert a.refcount[dup[0]] == 0 and dup[0] in a.free and dup[1] in a.free
    a.unref(ta)
    a.unref(tb)
    assert a.available() == 7
    got = a.allocate(7)                           # every counted block is
    assert got is not None and len(set(got)) == 7  # actually obtainable


def test_same_tick_divergent_prefix_never_strands_blocks(params):
    """High-severity regression: A and B admitted in ONE tick share two
    blocks of prompt then diverge in their third; A finishes while B keeps
    decoding, and C then needs every block available() advertises.  With the
    unified tick's chunk-granularity trie commit, B matches A's same-tick
    committed blocks at admission (intra-batch sharing — no duplicate
    prefill, no dedup needed) and the allocator's accounting stays exact."""
    rng = np.random.default_rng(5)
    eng = ServeEngine(CFG, params, n_slots=4, max_len=32, paged=True,
                      block_size=4, num_blocks=11)      # 10 usable blocks
    done = []
    eng.on_complete = done.append
    shared = _toks(rng, 8)
    mk = lambda rid, tail, n: Request(
        request_id=rid, session_key=rid,
        prompt=np.concatenate([shared, tail]), max_new_tokens=n)
    eng.submit(mk("a", _toks(rng, 4), 2))               # cost 4 blocks
    eng.submit(mk("b", _toks(rng, 4), 6))               # cost 5 blocks
    eng.tick()                             # ONE mixed dispatch prefills both
    # intra-batch sharing: B reused A's 2 shared blocks (committed when A's
    # chunk was packed, read in the same dispatch) instead of duplicating
    assert eng.stats.prefix_hit_tokens == 8 and eng.stats.prefix_hits == 1
    assert eng.stats.prefill_tokens == 12 + 4
    assert eng.cm.alloc.dedup_blocks == 0               # nothing to reconcile
    assert eng.cm.n_active == 2                # both live after first token
    eng.tick()                                          # A's 2nd token: done
    assert [r.request_id for r in done] == ["a"] and eng.cm.n_active == 1
    eng.submit(Request(request_id="c", session_key="c",
                       prompt=_toks(rng, 20), max_new_tokens=1))  # cost 5
    eng.run_until_drained()
    assert sorted(r.request_id for r in done) == ["a", "b", "c"]
    assert all(r.error is None for r in done)
    a = eng.cm.alloc
    assert a.available() == a.num_blocks - 1
    got = a.allocate(a.num_blocks - 1)       # drain: all blocks reclaimable
    assert got is not None and len(set(got)) == a.num_blocks - 1


def test_oversized_prompt_fails_via_completion_path(params):
    """Medium regression: an oversized prompt mid-batch must fail ALONE
    through the completion path (error set, no tokens) without stranding
    the same-tick requests admitted before it."""
    rng = np.random.default_rng(6)
    eng = ServeEngine(CFG, params, n_slots=4, max_len=32, paged=True,
                      block_size=16)
    done = []
    eng.on_complete = done.append
    for rid, n, new in (("g1", 8, 2), ("bad", 40, 2), ("g2", 8, 2),
                        ("over", 30, 5)):       # 30 + 4 written > max_len=32
        eng.submit(Request(request_id=rid, session_key=rid,
                           prompt=_toks(rng, n), max_new_tokens=new))
    eng.run_until_drained()
    byid = {r.request_id: r for r in done}
    assert set(byid) == {"g1", "bad", "g2", "over"}
    assert byid["bad"].error is not None and "max_len" in byid["bad"].error
    assert byid["bad"].tokens == []
    # a prompt that fits but whose DECODE would overrun max_len must also be
    # rejected up front — mid-decode it would crash the whole replica tick
    assert byid["over"].error is not None and "max_len" in byid["over"].error
    for rid in ("g1", "g2"):
        assert byid[rid].error is None and len(byid[rid].tokens) == 2
    assert eng.cm.n_active == 0


def test_impossible_block_demand_rejected_not_stalled(params):
    """Scheduler regression: a request whose worst-case block demand exceeds
    what the pool can EVER provide is rejected with an explicit error at
    submit instead of parking at the head of the queue forever."""
    rng = np.random.default_rng(7)
    eng = ServeEngine(CFG, params, n_slots=2, max_len=96, paged=True,
                      block_size=16, num_blocks=5)      # 4 usable blocks
    done = []
    eng.on_complete = done.append
    eng.submit(Request(request_id="big", session_key="s",
                       prompt=_toks(rng, 70), max_new_tokens=20))  # needs 6
    # the harder path: enqueued straight into the scheduler (bypassing
    # engine.submit's up-front check) — admit() must pop it through to the
    # engine's admission-time rejection instead of parking it forever
    eng.scheduler.submit(Request(request_id="big2", session_key="s",
                                 prompt=_toks(rng, 70), max_new_tokens=20))
    eng.submit(Request(request_id="ok", session_key="s",
                       prompt=_toks(rng, 8), max_new_tokens=2))
    eng.run_until_drained()                   # would TimeoutError when stalled
    byid = {r.request_id: r for r in done}
    for rid in ("big", "big2"):
        assert byid[rid].error is not None and "KV blocks" in byid[rid].error
    assert byid["ok"].error is None and len(byid["ok"].tokens) == 2


def test_begin_failure_requeues_in_order(params, monkeypatch):
    """Engine regression: a begin() refusal (accounting drift) requeues the
    request and everything admitted after it — order preserved — instead of
    crashing the tick on an assert."""
    rng = np.random.default_rng(8)
    eng = ServeEngine(CFG, params, n_slots=4, max_len=64, paged=True,
                      block_size=16)
    real = eng.cm.begin
    calls = {"n": 0}

    def flaky(slot, prompt, max_new):
        calls["n"] += 1
        if calls["n"] == 1:
            eng.cm.release(slot)
            return None
        return real(slot, prompt, max_new)

    monkeypatch.setattr(eng.cm, "begin", flaky)
    done = []
    eng.on_complete = done.append
    for rid in ("r1", "r2"):
        eng.submit(Request(request_id=rid, session_key="s",
                           prompt=_toks(rng, 8), max_new_tokens=2))
    eng.run_until_drained()
    assert [r.request_id for r in done] == ["r1", "r2"]
    assert calls["n"] == 3 and eng.cm.n_active == 0


def test_decode_donates_pool_buffers(params):
    """Perf regression: the jitted paged steps donate the pool operand (no
    whole-pool copy per tick); the devstore entry always holds the live
    leaves after publish()."""
    rng = np.random.default_rng(9)
    eng = ServeEngine(CFG, params, n_slots=2, max_len=32, paged=True,
                      block_size=16)
    before = jax.tree.leaves(eng.cm.pools)
    eng.submit(Request(request_id="r", session_key="s", prompt=_toks(rng, 5),
                       max_new_tokens=3))
    eng.run_until_drained()
    assert all(leaf.is_deleted() for leaf in before)
    stored = eng.cm.devstore.get(eng.cm.kv_key)
    assert all(a is b for a, b in zip(jax.tree.leaves(stored),
                                      jax.tree.leaves(eng.cm.pools)))


# =============================================== speculative KV rollback
def test_rollback_accounting_matches_accepted_only_replay():
    """Speculative rollback invariant (seeded deterministic sweep): after
    ANY accept/reject pattern — random draft lengths, random accepted
    prefixes, across a request's whole lifetime — the allocator's state
    (blocks in use, free-list size, trie residency, refcount multiset,
    available()) equals a from-scratch replay that only ever wrote the
    accepted tokens; and rejected-tail blocks are freed exactly once (the
    free list never holds a duplicate)."""
    for seed in range(8):
        rng = np.random.default_rng(100 + seed)
        bs = 4
        mk = lambda: PagedCacheManager(CFG, n_slots=1, max_len=64,
                                       block_size=bs, num_blocks=24)
        cm, cm2 = mk(), mk()
        S = int(rng.integers(3, 12))
        max_new = int(rng.integers(4, 14))
        prompt = rng.integers(0, 100, (S,)).astype(np.int32)
        # --- speculative lifetime on cm: each "tick" drafts m tokens,
        # accepts a <= m, rolls the rejected tail back
        slot = cm.acquire("r")
        seq = cm.begin(slot, prompt, max_new)
        assert cm.commit_prefill_progress(slot, S)
        generated = [int(rng.integers(0, 100))]        # boundary token
        while len(generated) < max_new:
            room = max_new - len(generated) - 1
            m = int(rng.integers(0, min(4, room) + 1))
            cm.ensure_decode_blocks({slot: m})
            a = int(rng.integers(0, m + 1))            # accepted prefix
            generated += [int(rng.integers(0, 100)) for _ in range(a + 1)]
            seq.pos += a + 1
            if a < m:
                cm.rollback_writes(slot, seq.pos)
            free = cm.alloc.free
            assert len(set(free)) == len(free), "block freed twice"
        cm.finish(slot, generated)
        # --- plain replay on cm2: the same accepted stream, one token at
        # a time, no speculation
        slot2 = cm2.acquire("r")
        seq2 = cm2.begin(slot2, prompt, max_new)
        assert cm2.commit_prefill_progress(slot2, S)
        for _ in range(len(generated) - 1):
            cm2.ensure_decode_blocks()
            seq2.pos += 1
        cm2.finish(slot2, generated)
        a1, a2 = cm.alloc, cm2.alloc
        assert a1.blocks_in_use == a2.blocks_in_use
        assert len(a1.free) == len(a2.free)
        assert a1.n_cached == a2.n_cached
        assert sorted(a1.refcount) == sorted(a2.refcount)
        assert a1.available() == a2.available()
        # identical trie CONTENT (paths key on tokens, not block ids)
        assert set(a1._cached.keys()) == set(a2._cached.keys())


def test_rollback_never_touches_shared_prefix_blocks():
    """A rolled-back speculative tail must free only the request's private
    tail blocks: trie-resident shared prefix blocks keep their refcounts,
    residency, and children pins."""
    bs = 4
    cm = PagedCacheManager(CFG, n_slots=2, max_len=32, block_size=bs,
                           num_blocks=16)
    prompt = np.arange(9, dtype=np.int32)              # 2 full blocks + 1
    sa = cm.acquire("a")
    cm.begin(sa, prompt, 4)
    assert cm.commit_prefill_progress(sa, 9)           # blocks 0-1 cached
    sb = cm.acquire("b")
    seq_b = cm.begin(sb, prompt, 12)
    assert seq_b.reused == 8                           # both full blocks
    shared = list(seq_b.table[:2])
    rc_before = [cm.alloc.refcount[b] for b in shared]
    cached_before = cm.alloc.n_cached
    assert cm.commit_prefill_progress(sb, 9)
    # b speculates 5 drafts deep past its prompt, all rejected
    seq_b.pos = 9
    cm.ensure_decode_blocks({sb: 5})
    grown = len(seq_b.table)
    seq_b.pos += 1                                      # only t_last kept
    freed = cm.rollback_writes(sb, seq_b.pos)
    assert freed == grown - len(seq_b.table) and freed > 0
    assert [cm.alloc.refcount[b] for b in shared] == rc_before
    assert cm.alloc.n_cached == cached_before
    assert len(set(cm.alloc.free)) == len(cm.alloc.free)
    # the freed blocks are genuinely reusable: drain the whole pool
    cm.finish(sb, [1, 2])
    cm.release(sa)
    a = cm.alloc
    got = a.allocate(a.num_blocks - 1)
    assert got is not None and len(set(got)) == a.num_blocks - 1


def test_rollback_noop_when_everything_accepted():
    """Full acceptance leaves nothing to roll back: the table already
    covers exactly the written positions."""
    cm = PagedCacheManager(CFG, n_slots=1, max_len=32, block_size=4,
                           num_blocks=12)
    slot = cm.acquire("r")
    seq = cm.begin(slot, np.arange(5, dtype=np.int32), 10)
    assert cm.commit_prefill_progress(slot, 5)
    cm.ensure_decode_blocks({slot: 3})
    seq.pos += 4                                        # all 3 drafts + bonus
    assert cm.rollback_writes(slot, seq.pos) == 0
    assert len(seq.table) * 4 >= seq.pos


def test_supports_paged_gating():
    assert supports_paged(CFG)
    mamba = ModelConfig(name="m", family="ssm", n_layers=2, d_model=32,
                        n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                        dtype="float32")
    assert not supports_paged(mamba)
    with pytest.raises(ValueError):
        ServeEngine(mamba, None, paged=True)


def test_kv_pool_lives_on_devstore(params):
    """KV blocks are Cascade objects: the engine's pool tree is installed on
    the device store and re-installed (same leaves, no copy) every tick."""
    eng = ServeEngine(CFG, params, n_slots=2, max_len=32, paged=True,
                      block_size=16)
    stored = eng.cm.devstore.get(eng.cm.kv_key)
    assert stored is not None
    assert jax.tree.structure(stored) == jax.tree.structure(eng.cm.pools)
    rng = np.random.default_rng(4)
    eng.submit(Request(request_id="r", session_key="s", prompt=_toks(rng, 5),
                       max_new_tokens=2))
    eng.run_until_drained()
    stored = eng.cm.devstore.get(eng.cm.kv_key)
    # zero-copy install: the stored leaves ARE the live pool leaves
    assert all(a is b for a, b in zip(jax.tree.leaves(stored),
                                      jax.tree.leaves(eng.cm.pools)))


# ===================================================== quantized KV pools
def test_quantized_pool_bytes_match_roofline_accounting():
    """The manager's measured kv_bytes_per_token must equal the roofline
    theoretical formula at every precision (the int8-vs-bf16 byte-ratio
    claim is made on that formula), and the pool leaves must carry the
    advertised storage dtypes — quantized pools with f32 scale leaves."""
    import jax.numpy as jnp

    from benchmarks.roofline import kv_bytes_per_decode_token
    D = CFG.d_model // CFG.n_heads
    expect_dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
                 "int8": jnp.int8, "fp8_e4m3": jnp.float8_e4m3fn}
    bytes_by_dt = {}
    for kv_dtype in ("float32", "bfloat16", "int8", "fp8_e4m3"):
        cm = PagedCacheManager(CFG, n_slots=2, max_len=32, block_size=8,
                               num_blocks=8, kv_dtype=kv_dtype)
        got = cm.kv_bytes_per_token()
        theor = kv_bytes_per_decode_token(CFG.n_layers, CFG.n_kv_heads, D,
                                          kv_dtype)
        assert got == theor, (kv_dtype, got, theor)
        bytes_by_dt[kv_dtype] = got
        dts = {l.dtype for l in jax.tree.leaves(cm.pools)}
        if kv_dtype in ("int8", "fp8_e4m3"):
            assert dts == {jnp.dtype(expect_dt[kv_dtype]),
                           jnp.dtype(jnp.float32)}
        else:
            assert dts == {jnp.dtype(expect_dt[kv_dtype])}
    assert (bytes_by_dt["float32"] > bytes_by_dt["bfloat16"]
            > bytes_by_dt["int8"] == bytes_by_dt["fp8_e4m3"])


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8_e4m3"])
def test_quantized_streams_deterministic_across_backends(params, kv_dtype):
    """Fixed precision is a determinism contract: the same prompts through
    the quantized pool yield bit-identical greedy streams run-to-run AND
    across attention backends (XLA gather vs Pallas kernel — both
    dequantize the same stored integers)."""
    rng = np.random.default_rng(21)
    prompts = [_toks(rng, L) for L in (5, 40, 17)]
    mk = lambda: [Request(request_id=f"r{i}", session_key="s", prompt=p,
                          max_new_tokens=4) for i, p in enumerate(prompts)]
    eng, xla1 = _run(params, mk(), paged=True, block_size=16,
                     kv_dtype=kv_dtype)
    _, xla2 = _run(params, mk(), paged=True, block_size=16,
                   kv_dtype=kv_dtype)
    assert xla1 == xla2
    cfg_p = CFG.replace(attn_backend="pallas_interpret")
    eng_p = ServeEngine(cfg_p, params, n_slots=4, max_len=96, paged=True,
                        block_size=16, kv_dtype=kv_dtype)
    done = []
    eng_p.on_complete = done.append
    for r in mk():
        eng_p.submit(r)
    eng_p.run_until_drained()
    assert {r.request_id: list(r.tokens) for r in done} == xla1
    assert eng.stats.host_syncs == eng.stats.ticks
    assert eng_p.stats.host_syncs == eng_p.stats.ticks


def test_quantized_spill_adopt_scales_bit_exact():
    """Property test on the migration path: spill_device → host → adopt on
    a sibling manager round-trips EVERY pool leaf bit-exactly — the int8
    payloads and their f32 scales travel as ordinary tree leaves, no
    requantization anywhere."""
    import jax.numpy as jnp

    from repro.serving.kvcache import SpilledKV
    rng = np.random.default_rng(7)
    src = PagedCacheManager(CFG, n_slots=2, max_len=64, block_size=8,
                            num_blocks=12, kv_dtype="int8")
    leaves, treedef = jax.tree.flatten(src.pools)
    filled = []
    for leaf in leaves:
        if leaf.dtype == jnp.int8:
            filled.append(jnp.asarray(
                rng.integers(-127, 128, leaf.shape), jnp.int8))
        else:                                   # f32 scale leaves
            assert leaf.dtype == jnp.float32
            filled.append(jnp.asarray(
                rng.uniform(0.25, 4.0, leaf.shape), jnp.float32))
    src.pools = jax.tree.unflatten(treedef, filled)
    src.publish()
    slot = src.acquire("mig")
    src.slots[slot].table = [3, 1, 5]           # table ORDER must survive
    host = jax.tree.map(np.asarray, src.spill_device(slot))
    sp = SpilledKV(request_id="mig", pos=20, n_blocks=3, block_size=8,
                   blocks=host)
    dst = PagedCacheManager(CFG, n_slots=2, max_len=64, block_size=8,
                            num_blocks=12, kv_dtype="int8")
    slot2 = dst.acquire("mig")
    seq = dst.adopt(slot2, np.arange(10, dtype=np.int32), sp,
                    max_new_tokens=4)
    assert seq is not None and seq.pos == 20
    back = jax.tree.map(np.asarray, dst.spill_device(slot2))
    h_leaves = jax.tree.leaves(host)
    b_leaves = jax.tree.leaves(back)
    assert len(h_leaves) == len(b_leaves)
    for a, b in zip(h_leaves, b_leaves):
        assert a.dtype == b.dtype
        assert np.array_equal(a, b)             # bit-exact, scales included
    assert {a.dtype for a in h_leaves} == {np.dtype(np.int8),
                                           np.dtype(np.float32)}


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8_e4m3"])
def test_quantized_preempt_resume_bit_identical(params, kv_dtype):
    """Preempt → spill to host pool → re-issue → adopt, all at fixed
    quantized precision: the greedy streams must be bit-identical to the
    uninterrupted quantized run (a written token's quantized bytes depend
    only on that token, so migration never perturbs neighbours)."""
    import time

    from repro.core.store import SpillPool
    from repro.serving.scheduler import SLO_BATCH, SLO_INTERACTIVE
    rng = np.random.default_rng(13)
    prompts = {"b0": _toks(rng, 8), "b1": _toks(rng, 8), "i0": _toks(rng, 4)}
    mk = lambda rid, slo: Request(
        request_id=rid, session_key=f"sess-{rid}", prompt=prompts[rid],
        max_new_tokens=3 if slo == SLO_INTERACTIVE else 8, slo=slo)

    # uninterrupted reference at the SAME precision: slack capacity
    ref_eng = ServeEngine(CFG, params, n_slots=8, max_len=48,
                          temperature=0.0, block_size=4, num_blocks=64,
                          prefix_cache=False, kv_dtype=kv_dtype)
    ref_done = {}
    ref_eng.on_complete = lambda r: ref_done.setdefault(r.request_id, r)
    for rid in ("b0", "b1", "i0"):
        ref_eng.submit(mk(rid, SLO_INTERACTIVE if rid == "i0"
                          else SLO_BATCH))
    ref_eng.run_until_drained()
    assert ref_eng.stats.preemptions == 0
    ref = {rid: list(r.tokens) for rid, r in ref_done.items()}

    # tight engine: interactive arrival mid-decode forces a preemption
    pool = SpillPool(capacity_blocks=64)
    eng = ServeEngine(CFG, params, n_slots=2, max_len=48, temperature=0.0,
                      block_size=4, num_blocks=11, prefix_cache=False,
                      spill_pool=pool, preempt=True, kv_dtype=kv_dtype)
    done = {}
    eng.on_complete = lambda r: done.setdefault(r.request_id, r)
    eng.submit(mk("b0", SLO_BATCH))
    eng.submit(mk("b1", SLO_BATCH))
    stop = time.monotonic() + 30
    while not (len(eng.live) == 2
               and all(r.tokens for r in eng.live.values())):
        eng.tick()
        assert time.monotonic() < stop, "batch requests never went live"
    eng.submit(mk("i0", SLO_INTERACTIVE))
    eng.run_until_drained()
    got = {rid: list(r.tokens) for rid, r in done.items()}
    assert got == ref
    assert eng.stats.preemptions >= 1
    assert eng.stats.resumes >= 1               # adopted, not replayed
    assert eng.stats.host_syncs == eng.stats.ticks + eng.stats.spill_syncs
    assert pool.blocks == 0 and pool.evicted == 0


def test_quantized_decode_donates_pool_buffers(params):
    """Donation must stay exact-match with the scale leaves in the tree:
    the jitted paged step still donates the whole pool (no copy-per-tick
    fallback when the tree gains k_scale/v_scale)."""
    rng = np.random.default_rng(17)
    eng = ServeEngine(CFG, params, n_slots=2, max_len=32, paged=True,
                      block_size=16, kv_dtype="int8")
    before = jax.tree.leaves(eng.cm.pools)
    assert len({l.dtype for l in before}) == 2  # int8 payload + f32 scales
    eng.submit(Request(request_id="r", session_key="s", prompt=_toks(rng, 5),
                       max_new_tokens=3))
    eng.run_until_drained()
    assert all(leaf.is_deleted() for leaf in before)
    stored = eng.cm.devstore.get(eng.cm.kv_key)
    assert all(a is b for a, b in zip(jax.tree.leaves(stored),
                                      jax.tree.leaves(eng.cm.pools)))

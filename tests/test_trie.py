"""PathTrie unit + property tests (paper §3.3: trie prefix matching).

Property tests are driven by a seeded local case generator (deterministic,
no extra dependency): a small alphabet keeps prefix collisions common so the
match == brute-force invariant is exercised on overlapping paths.
"""
import random

import pytest

from repro.core.trie import PathTrie, split_path


def _rand_path(rng: random.Random, max_comps: int = 5) -> str:
    comps = [
        "".join(rng.choice("abc") for _ in range(rng.randint(1, 2)))
        for _ in range(rng.randint(1, max_comps))
    ]
    return "/" + "/".join(comps)


def test_basic_match():
    t = PathTrie()
    t.insert("/sf/detect_animal", "filter")
    t.insert("/sf", "root")
    assert t.match("/sf/detect_animal/cam0/f1") == ["root", "filter"]
    assert t.match("/sf/other") == ["root"]
    assert t.match("/other") == []


def test_multi_lambda_one_prefix():
    t = PathTrie()
    t.insert("/p", "a")
    t.insert("/p", "b")
    assert t.match("/p/x") == ["a", "b"]


def test_remove():
    t = PathTrie()
    t.insert("/p/q", 1)
    assert t.remove("/p/q", 1)
    assert not t.remove("/p/q", 1)
    assert t.match("/p/q/r") == []


def test_longest_prefix():
    t = PathTrie()
    t.insert("/a", "shallow")
    t.insert("/a/b/c", "deep")
    path, vals = t.longest_prefix("/a/b/c/d")
    assert path == "/a/b/c" and vals == ["deep"]


@pytest.mark.parametrize("seed", range(20))
def test_match_equals_bruteforce(seed):
    """Property: trie match == brute-force component-prefix scan."""
    rng = random.Random(seed)
    entries = [(_rand_path(rng), rng.randint(-1000, 1000))
               for _ in range(rng.randint(0, 20))]
    t = PathTrie()
    for p, v in entries:
        t.insert(p, v)
    # probe random keys plus inserted paths (guaranteed hits) and extensions
    keys = [_rand_path(rng) for _ in range(10)]
    keys += [p for p, _ in entries[:5]]
    keys += [p + "/x" for p, _ in entries[:5]]
    for key in keys:
        got = t.match(key)
        kc = split_path(key)
        expected = [v for p, v in entries
                    if kc[: len(split_path(p))] == split_path(p)]
        assert sorted(map(repr, got)) == sorted(map(repr, expected))


def test_iter_prefixes():
    t = PathTrie()
    t.insert("/a/b", 1)
    t.insert("/c", 2)
    got = dict(t.iter_prefixes())
    assert got == {"/a/b": [1], "/c": [2]}

"""PathTrie unit + property tests (paper §3.3: trie prefix matching)."""
import string

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.trie import PathTrie, split_path

COMP = st.text(alphabet=string.ascii_lowercase, min_size=1, max_size=4)
PATH = st.lists(COMP, min_size=1, max_size=5).map(lambda cs: "/" + "/".join(cs))


def test_basic_match():
    t = PathTrie()
    t.insert("/sf/detect_animal", "filter")
    t.insert("/sf", "root")
    assert t.match("/sf/detect_animal/cam0/f1") == ["root", "filter"]
    assert t.match("/sf/other") == ["root"]
    assert t.match("/other") == []


def test_multi_lambda_one_prefix():
    t = PathTrie()
    t.insert("/p", "a")
    t.insert("/p", "b")
    assert t.match("/p/x") == ["a", "b"]


def test_remove():
    t = PathTrie()
    t.insert("/p/q", 1)
    assert t.remove("/p/q", 1)
    assert not t.remove("/p/q", 1)
    assert t.match("/p/q/r") == []


def test_longest_prefix():
    t = PathTrie()
    t.insert("/a", "shallow")
    t.insert("/a/b/c", "deep")
    path, vals = t.longest_prefix("/a/b/c/d")
    assert path == "/a/b/c" and vals == ["deep"]


@given(st.lists(st.tuples(PATH, st.integers()), max_size=20), PATH)
@settings(max_examples=100, deadline=None)
def test_match_equals_bruteforce(entries, key):
    """Property: trie match == brute-force component-prefix scan."""
    t = PathTrie()
    for p, v in entries:
        t.insert(p, v)
    got = t.match(key)
    kc = split_path(key)
    expected = [v for p, v in entries if kc[: len(split_path(p))] == split_path(p)]
    assert sorted(map(repr, got)) == sorted(map(repr, expected))


def test_iter_prefixes():
    t = PathTrie()
    t.insert("/a/b", 1)
    t.insert("/c", 2)
    got = dict(t.iter_prefixes())
    assert got == {"/a/b": [1], "/c": [2]}

"""Runtime sanitizer tests: lock-order tracking and sync-site checking.

The headline regression test: a deliberately inverted acquisition order
(A then B on one path, B then A on another) is reported as a lock-order
inversion even though no deadlock actually occurred — the tracker works
from the acquisition graph, not from a lucky schedule.
"""
import threading

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.sanitizer import (
    LockOrderTracker,
    SyncSiteSanitizer,
    TrackedLock,
)
from repro.core.log import PersistentLog


# --------------------------------------------------------------------------
# lock-order tracker
# --------------------------------------------------------------------------

def test_inverted_acquisition_order_is_detected():
    tracker = LockOrderTracker()
    a = tracker.wrap(name="A")
    b = tracker.wrap(name="B")
    with a:
        with b:
            pass
    # the reverse nesting: with another thread interleaving, this deadlocks
    with b:
        with a:
            pass
    assert len(tracker.violations) == 1
    assert "lock-order inversion" in tracker.violations[0]
    assert "A" in tracker.violations[0] and "B" in tracker.violations[0]


def test_consistent_acquisition_order_is_clean():
    tracker = LockOrderTracker()
    a = tracker.wrap(name="A")
    b = tracker.wrap(name="B")
    for _ in range(3):
        with a:
            with b:
                pass
    assert tracker.violations == []


def test_three_lock_cycle_is_detected():
    tracker = LockOrderTracker()
    a, b, c = (tracker.wrap(name=n) for n in "ABC")
    with a:
        with b:
            pass
    with b:
        with c:
            pass
    with c:
        with a:
            pass           # closes A -> B -> C -> A
    assert len(tracker.violations) == 1
    assert "inversion" in tracker.violations[0]


def test_self_deadlock_fails_fast():
    tracker = LockOrderTracker()
    a = tracker.wrap(name="A")
    a.acquire()
    with pytest.raises(RuntimeError, match="self-deadlock"):
        a.acquire()
    assert any("self-deadlock" in v for v in tracker.violations)
    a.release()


def test_reentrant_reacquire_is_allowed():
    tracker = LockOrderTracker()
    r = tracker.wrap(name="R", reentrant=True)
    with r:
        with r:
            pass
    assert tracker.violations == []


def test_install_wraps_only_matching_modules():
    tracker = LockOrderTracker()
    tracker.install(module_prefixes=("tests.", "test_"))
    try:
        ours = threading.Lock()          # created from this test module
    finally:
        tracker.uninstall()
    assert isinstance(ours, TrackedLock)
    assert not isinstance(threading.Lock(), TrackedLock)   # uninstalled
    # default prefixes leave test-module locks native
    tracker2 = LockOrderTracker()
    tracker2.install()
    try:
        native = threading.Lock()
    finally:
        tracker2.uninstall()
    assert not isinstance(native, TrackedLock)


def test_install_detects_inversion_through_threading_api():
    tracker = LockOrderTracker()
    tracker.install(module_prefixes=("tests.", "test_"))
    try:
        a = threading.Lock()
        b = threading.Lock()
    finally:
        tracker.uninstall()
    with a, b:
        pass
    with b, a:
        pass
    assert len(tracker.violations) == 1


def test_persistent_log_runs_clean_under_tracker(tmp_path):
    """End-to-end: the write-back thread + append path (Condition over a
    tracked Lock, _meta_lock/_queue_cv nesting) produce no violations."""
    tracker = LockOrderTracker()
    tracker.install()
    try:
        log = PersistentLog(str(tmp_path / "wal.log"))
        for i in range(8):
            log.append(f"k{i % 3}", f"payload-{i}".encode())
        log.close()
    finally:
        tracker.uninstall()
    assert tracker.violations == []


# --------------------------------------------------------------------------
# sync-site sanitizer
# --------------------------------------------------------------------------

def _fastpath_fn(module_name, fn_name):
    """A function whose frame claims to live in ``module_name``."""
    ns = {"__name__": module_name, "jax": jax}
    exec(f"def {fn_name}(arr):\n    return jax.device_get(arr)", ns)
    return ns[fn_name]


def test_device_get_from_wrong_fastpath_site_is_flagged():
    san = SyncSiteSanitizer()
    san.install()
    try:
        _fastpath_fn("repro.serving.scheduler", "_peek")(jnp.zeros((2,)))
    finally:
        san.uninstall()
    assert len(san.violations) == 1
    assert "repro.serving.scheduler::_peek" in san.violations[0]


def test_device_get_from_the_sync_site_is_allowed():
    san = SyncSiteSanitizer()
    san.install()
    try:
        _fastpath_fn("repro.serving.engine", "_to_host")(jnp.zeros((2,)))
    finally:
        san.uninstall()
    assert san.violations == []


def test_device_get_outside_fastpath_is_allowed():
    san = SyncSiteSanitizer()
    san.install()
    try:
        jax.device_get(jnp.zeros((2,)))             # test code: fine
        _fastpath_fn("repro.training.loop", "pull")(jnp.zeros((2,)))
    finally:
        san.uninstall()
    assert san.violations == []

"""DeviceStore retention policies: keep_versions trimming (volatile vs
persistent), LRU read-cache eviction under a small byte budget, and the
tree-aware puts that back the serving engines' paged-KV pools."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DeviceStore, PoolSpec
from repro.core.pools import Persistence


def _store(**kw):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return DeviceStore(mesh, **kw)


# ------------------------------------------------------------ keep_versions
def test_volatile_retains_exactly_keep_versions():
    ds = _store(keep_versions=2)
    ds.create_pool(PoolSpec(path="/v"))
    for i in range(5):
        ds.put("/v/x", jnp.full((2,), float(i)))
    e = ds._entries["/v/x"]
    assert list(e.versions) == [3, 4]
    assert ds.latest_version("/v/x") == 4
    # requests below the retention window miss; inside it, the newest
    # retained version <= requested is served
    assert ds.get("/v/x", version=2) is None
    assert float(ds.get("/v/x", version=3)[0]) == 3.0
    assert float(ds.get("/v/x", version=4)[0]) == 4.0


def test_keep_versions_one_keeps_only_latest():
    ds = _store(keep_versions=1)
    ds.create_pool(PoolSpec(path="/v"))
    for i in range(3):
        ds.put("/v/x", jnp.full((2,), float(i)))
    assert list(ds._entries["/v/x"].versions) == [2]
    assert ds.get("/v/x", version=0) is None


def test_persistent_pool_keeps_every_version():
    ds = _store(keep_versions=1)
    ds.create_pool(PoolSpec(path="/p", persistence=Persistence.PERSISTENT))
    for i in range(4):
        ds.put("/p/x", jnp.full((2,), float(i)))
    assert list(ds._entries["/p/x"].versions) == [0, 1, 2, 3]
    assert float(ds.get("/p/x", version=0)[0]) == 0.0


def test_get_time_respects_retention():
    ds = _store(keep_versions=2)
    ds.create_pool(PoolSpec(path="/v"))
    stamps = []
    for i in range(4):
        ds.put("/v/x", jnp.full((1,), float(i)))
        stamps.append(ds._entries["/v/x"].timestamps[i])
    # version 0/1 trimmed: a time-travel read at their stamps finds nothing
    assert ds.get_time("/v/x", stamps[1]) is None
    assert float(ds.get_time("/v/x", stamps[2])[0]) == 2.0


# -------------------------------------------------------------- LRU budget
def test_lru_cache_evicts_under_small_byte_budget():
    """Reads flow through the §3.5 LRU; a budget of ~2 arrays evicts the
    least-recently-read key once a third is pulled."""
    nbytes = int(jnp.zeros((4,), jnp.float32).nbytes)       # 16 B per key
    ds = _store(lru_bytes=2 * nbytes)
    ds.create_pool(PoolSpec(path="/v"))
    for k in ("a", "b", "c"):
        ds.put(f"/v/{k}", jnp.zeros((4,), jnp.float32))
    ds.get("/v/a")
    ds.get("/v/b")
    assert "/v/a" in ds.lru and "/v/b" in ds.lru
    ds.get("/v/c")                                          # budget blown
    assert "/v/a" not in ds.lru                             # LRU victim
    assert "/v/b" in ds.lru and "/v/c" in ds.lru
    assert ds.lru.nbytes <= 2 * nbytes


# ---------------------------------------------------------------- tree puts
def test_tree_put_donate_installs_references():
    """A pytree value (e.g. a paged-KV pool) installs without copying when
    its leaves already sit on the pool's devices."""
    ds = _store(keep_versions=1)
    ds.create_pool(PoolSpec(path="/kv"))
    tree = {"k": jnp.zeros((4, 2)), "v": (jnp.ones((3,)), jnp.arange(2.0))}
    stored = ds.put("/kv/pool", tree, donate=True)
    assert all(a is b for a, b in zip(jax.tree.leaves(stored),
                                      jax.tree.leaves(tree)))
    got = ds.get("/kv/pool")
    assert jax.tree.structure(got) == jax.tree.structure(tree)
    # byte accounting sums the leaves
    assert ds.nbytes() == sum(int(l.nbytes) for l in jax.tree.leaves(tree))
    snap = ds.snapshot("/kv")
    np.testing.assert_array_equal(snap["/kv/pool"]["k"], np.zeros((4, 2)))


def test_tree_put_without_donate_copies_to_placement():
    ds = _store()
    ds.create_pool(PoolSpec(path="/kv"))
    tree = {"a": np.arange(4.0)}                            # host values
    stored = ds.put("/kv/pool", tree)
    assert isinstance(stored["a"], jax.Array)
    np.testing.assert_array_equal(np.asarray(stored["a"]), np.arange(4.0))

"""DFG + lambda API + CascadeService end-to-end (paper §3.1, §5)."""
import json
import time

import pytest

from repro.core import (DFG, CascadeService, DispatchPolicy, Persistence,
                        Vertex)


def test_dfg_json_roundtrip():
    dfg = DFG(name="app")
    dfg.add_vertex(Vertex("a", "/app/a", dispatch=DispatchPolicy.FIFO))
    dfg.add_vertex(Vertex("b", "/app/b", persistence=Persistence.PERSISTENT,
                          replication=2))
    dfg.add_edge("a", "b")
    dfg2 = DFG.from_json(dfg.to_json())
    assert dfg2.vertices["a"].dispatch is DispatchPolicy.FIFO
    assert dfg2.vertices["b"].persistence is Persistence.PERSISTENT
    assert dfg2.edges == [("a", "b")]


def test_topo_order_deterministic_across_insertion_orders():
    """Equal-indegree vertices must come out in a stable (lexicographic)
    order no matter how the DFG was assembled."""
    import itertools
    import random

    names = ["d", "b", "a", "c", "e"]
    edges = [("a", "d"), ("b", "d"), ("c", "e")]  # {a,b,c} then {d,e}
    orders = []
    for seed in range(6):
        rng = random.Random(seed)
        vs = names[:]
        es = edges[:]
        rng.shuffle(vs)
        rng.shuffle(es)
        dfg = DFG(name="t")
        for n in vs:
            dfg.add_vertex(Vertex(n, f"/t/{n}"))
        for s, d in es:
            dfg.add_edge(s, d)
        orders.append([v.name for v in dfg.topo_order()])
    assert all(o == orders[0] for o in orders)
    assert orders[0] == ["a", "b", "c", "d", "e"]


def test_dfg_cycle_rejected():
    dfg = DFG(name="bad")
    dfg.add_vertex(Vertex("a", "/x/a"))
    dfg.add_vertex(Vertex("b", "/x/b"))
    dfg.add_edge("a", "b")
    dfg.add_edge("b", "a")
    with pytest.raises(ValueError, match="cycle"):
        dfg.validate()


def test_dfg_duplicate_prefix_rejected():
    dfg = DFG(name="bad")
    dfg.add_vertex(Vertex("a", "/x/a"))
    dfg.add_vertex(Vertex("b", "/x/a"))
    with pytest.raises(ValueError, match="unique"):
        dfg.validate()


def test_three_stage_pipeline(tmp_path):
    with CascadeService(n_workers=4, log_dir=str(tmp_path)) as svc:
        dfg = DFG(name="pipe")
        dfg.add_vertex(Vertex("a", "/pipe/a"))
        dfg.add_vertex(Vertex("b", "/pipe/b"))
        dfg.add_vertex(Vertex("sink", "/pipe/out",
                              persistence=Persistence.PERSISTENT))
        dfg.add_edge("a", "b")
        dfg.add_edge("b", "sink")

        def lam_a(ctx, obj):
            ctx.emit(obj.key.rsplit("/", 1)[-1], obj.payload + b">a",
                     trigger=True)

        def lam_b(ctx, obj):
            ctx.emit(obj.key.rsplit("/", 1)[-1], obj.payload + b">b")

        svc.deploy(dfg, {"a": lam_a, "b": lam_b})
        svc.inject("pipe", "k", b"in")
        deadline = time.monotonic() + 5
        out = None
        while time.monotonic() < deadline:
            out = svc.get("/pipe/out/k")
            if out is not None:
                break
            time.sleep(0.005)
        assert out is not None and out.payload == b"in>a>b"


def test_lambda_context_get_put(tmp_path):
    """Lambdas can consult contextual K/V state (paper: 'world state')."""
    with CascadeService(n_workers=2, log_dir=str(tmp_path)) as svc:
        dfg = DFG(name="ctxapp")
        dfg.add_vertex(Vertex("f", "/ctxapp/in"))
        dfg.add_vertex(Vertex("out", "/ctxapp/out"))
        dfg.add_edge("f", "out")
        svc.store.create_pool(
            __import__("repro.core.pools", fromlist=["PoolSpec"]).PoolSpec(
                path="/world"))
        svc.put("/world/greeting", b"hello ")

        def lam(ctx, obj):
            ctx_obj = ctx.get("/world/greeting")
            ctx.emit("res", ctx_obj.payload + obj.payload)

        svc.deploy(dfg, {"f": lam})
        rs = svc.inject("ctxapp", "x", b"world")
        for r in rs:
            r.wait()
        time.sleep(0.02)
        assert svc.get("/ctxapp/out/res").payload == b"hello world"


def test_shard_workers_placement(tmp_path):
    """A vertex pinned to specific workers dispatches only there."""
    with CascadeService(n_workers=4, log_dir=str(tmp_path)) as svc:
        dfg = DFG(name="pin")
        dfg.add_vertex(Vertex("f", "/pin/in", shard_workers=(2,)))
        ran_on = []

        def lam(ctx, obj):
            ran_on.append(True)
            return "ok"

        svc.deploy(dfg, {"f": lam})
        rs = svc.inject("pin", "k", b"x")
        for r in rs:
            assert r.processing_worker == 2
            r.wait()
        assert ran_on

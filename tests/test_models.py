"""Model zoo behaviour: forward shapes, decode-vs-forward parity, MoE
equivalence, SSD chunking invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (ModelConfig, decode_step, forward,
                          init_decode_caches, init_params, param_axes, prefill)


def tiny(family="dense", **kw):
    base = dict(name="t", family=family, n_layers=4, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
                max_target_length=64, q_chunk=16, ssm_chunk=8)
    base.update(kw)
    return ModelConfig(**base)


FAMILIES = {
    "dense": tiny(),
    "swa": tiny(window=8),
    "gemma_style": tiny(window=8, local_global_pattern=1,
                        attn_logit_softcap=50.0, final_logit_softcap=30.0,
                        post_norm=True, embed_scale=True),
    "qknorm": tiny(qk_norm=True, local_global_pattern=3, window=8),
    "moe": tiny("moe", n_experts=8, top_k=2, n_shared_experts=1, moe_d_ff=32,
                first_layer_dense=True),
    "moe_interleaved": tiny("moe", n_experts=4, top_k=1, moe_every=2),
    "ssm": tiny("ssm", ssm_state=16, ssm_head_dim=16),
    "hybrid": tiny("hybrid", ssm_state=16, ssm_head_dim=16,
                   shared_attn_every=2, head_dim=32),
    "embeds": tiny("audio", input_mode="embeds"),
}


def _inputs(cfg, B=2, S=24, seed=0):
    key = jax.random.PRNGKey(seed)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.input_mode == "embeds":
        return jax.random.normal(key, (B, S, cfg.d_model)), pos
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size), pos


@pytest.mark.parametrize("name", list(FAMILIES))
def test_forward_shapes_no_nan(name):
    cfg = FAMILIES[name]
    params = init_params(jax.random.PRNGKey(1), cfg)
    inp, pos = _inputs(cfg)
    logits, aux = forward(params, inp, pos, cfg, mode="score")
    assert logits.shape == (2, 24, cfg.vocab_size)
    assert not jnp.isnan(logits).any()
    assert not jnp.isnan(aux)


@pytest.mark.parametrize("name", ["dense", "swa", "gemma_style", "qknorm",
                                  "ssm", "hybrid", "embeds"])
def test_decode_matches_forward(name):
    """prefill(S-1) + decode_step(last) == forward at the last position."""
    cfg = FAMILIES[name]
    params = init_params(jax.random.PRNGKey(1), cfg)
    inp, pos = _inputs(cfg)
    B, S = 2, 24
    logits, _ = forward(params, inp, pos, cfg, mode="score")
    _, caches = prefill(params, inp[:, : S - 1], pos[:, : S - 1], cfg,
                        max_len=32)
    last = inp[:, S - 1] if cfg.input_mode == "tokens" else inp[:, S - 1 : S]
    dec, _ = decode_step(params, caches, last, pos[:, S - 1 : S], cfg)
    np.testing.assert_allclose(dec, logits[:, -1], rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("name", ["moe", "moe_interleaved"])
def test_moe_decode_matches_forward_no_drop(name):
    """With generous capacity (no token drops) MoE decode == forward."""
    cfg = FAMILIES[name].replace(capacity_factor=16.0)
    params = init_params(jax.random.PRNGKey(1), cfg)
    inp, pos = _inputs(cfg)
    B, S = 2, 24
    logits, _ = forward(params, inp, pos, cfg, mode="score")
    _, caches = prefill(params, inp[:, : S - 1], pos[:, : S - 1], cfg, max_len=32)
    dec, _ = decode_step(params, caches, inp[:, S - 1], pos[:, S - 1 : S], cfg)
    np.testing.assert_allclose(dec, logits[:, -1], rtol=2e-4, atol=2e-4)


def test_moe_einsum_scatter_equivalent():
    cfg_e = FAMILIES["moe"].replace(capacity_factor=16.0, moe_impl="einsum")
    cfg_s = cfg_e.replace(moe_impl="scatter")
    params = init_params(jax.random.PRNGKey(1), cfg_e)
    inp, pos = _inputs(cfg_e)
    le, _ = forward(params, inp, pos, cfg_e, mode="score")
    ls, _ = forward(params, inp, pos, cfg_s, mode="score")
    np.testing.assert_allclose(le, ls, rtol=1e-4, atol=1e-4)


def test_moe_aux_loss_positive_and_bounded():
    cfg = FAMILIES["moe"]
    params = init_params(jax.random.PRNGKey(1), cfg)
    inp, pos = _inputs(cfg)
    _, aux = forward(params, inp, pos, cfg, mode="score")
    assert float(aux) >= 1.0 - 1e-3  # Switch loss lower bound at balance
    assert float(aux) < cfg.n_experts * 3  # sanity upper bound


def test_ssd_chunk_invariance():
    """SSD output must not depend on the chunk size."""
    cfg8 = FAMILIES["ssm"]
    cfg4 = cfg8.replace(ssm_chunk=4)
    params = init_params(jax.random.PRNGKey(1), cfg8)
    inp, pos = _inputs(cfg8)
    l8, _ = forward(params, inp, pos, cfg8, mode="score")
    l4, _ = forward(params, inp, pos, cfg4, mode="score")
    np.testing.assert_allclose(l8, l4, rtol=2e-4, atol=2e-4)


def test_q_chunk_invariance():
    """Chunked attention must not depend on the chunk size."""
    cfg = FAMILIES["swa"]
    params = init_params(jax.random.PRNGKey(1), cfg)
    inp, pos = _inputs(cfg)
    a, _ = forward(params, inp, pos, cfg, mode="score")
    b, _ = forward(params, inp, pos, cfg.replace(q_chunk=7), mode="score")
    c, _ = forward(params, inp, pos, cfg.replace(q_chunk=64), mode="score")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(a, c, rtol=2e-4, atol=2e-4)


def test_param_axes_structure_matches_params():
    for name, cfg in FAMILIES.items():
        params = init_params(jax.random.PRNGKey(0), cfg)
        axes = param_axes(cfg)
        ps = jax.tree.structure(params)
        axs = jax.tree.structure(
            axes, is_leaf=lambda x: isinstance(x, tuple) and
            all(isinstance(e, (str, type(None))) for e in x))
        assert ps == axs, name
        # every axes tuple has one entry per param dim
        flat_p = jax.tree.leaves(params)
        flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple) and
                                 all(isinstance(e, (str, type(None))) for e in x))
        for p, a in zip(flat_p, flat_a):
            assert p.ndim == len(a), (name, p.shape, a)


def test_sliding_window_actually_limits_attention():
    """Tokens beyond the window must not influence the output."""
    cfg = tiny(window=4, n_layers=1)
    params = init_params(jax.random.PRNGKey(1), cfg)
    B, S = 1, 16
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    l1, _ = forward(params, toks, pos, cfg, mode="score")
    # perturb a token far outside the window of the last position
    toks2 = toks.at[0, 2].set((toks[0, 2] + 1) % cfg.vocab_size)
    l2, _ = forward(params, toks2, pos, cfg, mode="score")
    np.testing.assert_allclose(l1[0, -1], l2[0, -1], rtol=1e-5, atol=1e-5)
    # ...but it does influence positions inside its window
    assert not np.allclose(l1[0, 3], l2[0, 3])

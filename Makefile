# Tier-1 verification and common dev entry points.
# `make test` is the exact command CI runs; a collection error (e.g. a test
# module importing a missing optional dep) fails it immediately.

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-sharded lint bench bench-smoke chaos-smoke check-trajectory serve-example

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

# sharding suite under a forced 8-device CPU backend: mesh-sliced replicas,
# sharded KV pools, cross-slice spill/adopt (the flag must be set before
# jax first initializes, hence the dedicated target/CI job)
test-sharded:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q tests/test_serving_sharding.py

# cascade-lint: lock discipline, host-sync discipline, donation/recompile
# hazards over the whole tree; exits nonzero on any unsuppressed finding
lint:
	PYTHONPATH=$(PYTHONPATH) python -m repro.analysis src/repro

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run $(if $(ONLY),--only $(ONLY))

# exactly what CI's bench-smoke job runs: the serving perf path end-to-end
# on tiny configs (unified tick, paged KV + prefix reuse, speculative
# decode, multi-model cascade + bounded admission, SLO-class overload with
# KV preemption vs the shed-only FIFO baseline, quantized-vs-bf16 KV pool)
bench-smoke:
	BENCH_SMOKE=1 PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run \
		--only serve_prefix_reuse,serve_mixed_tick,serve_speculative,serve_multi_model,serve_overload,serve_kv_quant,serve_replica_scaling

# exactly what CI's chaos-smoke job runs: a seeded fault schedule (replica
# crash + KV migration, transient submit errors, slow ticks) over the
# serving path, asserting zero stranded requests and structured errors only
chaos-smoke:
	BENCH_SMOKE=1 PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run \
		--only serve_chaos

# diff the freshly produced BENCH_serve.json against BASELINE (default: the
# last committed copy, via `git show`); fails on p99 regressions beyond the
# noise band
check-trajectory:
	git show HEAD:BENCH_serve.json > /tmp/BENCH_serve.baseline.json
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.check_trajectory \
		/tmp/BENCH_serve.baseline.json BENCH_serve.json $(if $(BAND),--band $(BAND))

serve-example:
	PYTHONPATH=$(PYTHONPATH) python examples/serve_cluster.py

# Tier-1 verification and common dev entry points.
# `make test` is the exact command CI runs; a collection error (e.g. a test
# module importing a missing optional dep) fails it immediately.

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench serve-example

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run $(if $(ONLY),--only $(ONLY))

serve-example:
	PYTHONPATH=$(PYTHONPATH) python examples/serve_cluster.py

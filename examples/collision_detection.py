"""Real-time collision detection (paper §5.3): mot → ynet → detect → store.

Three-stage DFG over toy trajectory models; per-frame latency reported with
the platform-overhead share, mirroring Fig 11.

Run: PYTHONPATH=src python examples/collision_detection.py
"""
import statistics
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DFG, CascadeService, Vertex


def main() -> None:
    key = jax.random.PRNGKey(1)
    w_mot = jax.random.normal(key, (512, 64)) / 23.0
    w_ynet = jax.random.normal(key, (16, 48)) / 4.0

    @jax.jit
    def mot(frame):
        return jnp.tanh(frame @ w_mot)

    @jax.jit
    def ynet(tracks):
        return jnp.tanh(tracks @ w_ynet)

    def detect(preds):
        p = np.asarray(preds).reshape(-1, 24, 2)
        hits = 0
        for i in range(p.shape[0]):
            for j in range(i + 1, p.shape[0]):
                if (np.linalg.norm(p[i] - p[j], axis=-1) < 0.05).any():
                    hits += 1
        return hits

    mot(np.zeros((1, 512), np.float32)).block_until_ready()
    ynet(np.zeros((4, 16), np.float32)).block_until_ready()

    with tempfile.TemporaryDirectory() as d, \
         CascadeService(n_workers=5, log_dir=d) as svc:
        dfg = DFG(name="rcd")
        dfg.add_vertex(Vertex("mot", "/rcd/frames", shard_workers=(0, 1)))
        dfg.add_vertex(Vertex("ynet", "/rcd/tracks", shard_workers=(2, 3)))
        dfg.add_vertex(Vertex("detect", "/rcd/preds", shard_workers=(4,)))
        dfg.add_vertex(Vertex("store", "/rcd/out"))
        dfg.add_edge("mot", "ynet")
        dfg.add_edge("ynet", "detect")
        dfg.add_edge("detect", "store")

        done = threading.Event()
        stamps = {}

        def lam_mot(ctx, obj):
            stamps["m0"] = time.monotonic()
            mot(obj.payload["frame"]).block_until_ready()
            stamps["m1"] = time.monotonic()
            tracks = np.random.randn(obj.payload["agents"], 16).astype(np.float32)
            ctx.emit(obj.key.rsplit("/", 1)[-1], tracks, trigger=True)

        def lam_ynet(ctx, obj):
            stamps["y0"] = time.monotonic()
            preds = np.asarray(ynet(obj.payload))
            stamps["y1"] = time.monotonic()
            ctx.emit(obj.key.rsplit("/", 1)[-1], preds, trigger=True)

        def lam_detect(ctx, obj):
            stamps["d0"] = time.monotonic()
            hits = detect(obj.payload)
            stamps["d1"] = time.monotonic()
            ctx.emit(obj.key.rsplit("/", 1)[-1], np.int64(hits))
            done.set()

        svc.deploy(dfg, {"mot": lam_mot, "ynet": lam_ynet, "detect": lam_detect})

        frame = np.random.randn(1, 512).astype(np.float32)
        for agents in (5, 10, 15):
            e2e, overhead = [], []
            for i in range(25):
                done.clear()
                t0 = time.monotonic()
                svc.trigger_put(f"/rcd/frames/f{i}",
                                {"frame": frame, "agents": agents})
                assert done.wait(5)
                dt = (time.monotonic() - t0) * 1e3
                comp = ((stamps["m1"] - stamps["m0"]) + (stamps["y1"] - stamps["y0"])
                        + (stamps["d1"] - stamps["d0"])) * 1e3
                e2e.append(dt)
                overhead.append(max(0.0, dt - comp))
            print(f"agents={agents:2d}  e2e median {statistics.median(e2e):6.2f} ms  "
                  f"platform overhead {statistics.median(overhead):5.2f} ms")
        print("OK")


if __name__ == "__main__":
    main()

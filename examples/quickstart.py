"""Quickstart: the Cascade K/V store + lambda DFG in ~60 lines.

Builds a two-stage pipeline (uppercase → reverse → persistent store), puts
an object through it, and shows versioned + temporal reads — the paper's
§3.1 "porting an application is trivial" flow.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import tempfile
import time

from repro.core import DFG, CascadeService, Persistence, Vertex


def main() -> None:
    with tempfile.TemporaryDirectory() as logdir, \
         CascadeService(n_workers=3, log_dir=logdir) as svc:

        # 1. describe the DFG (could equally be DFG.from_json(...))
        dfg = DFG(name="quickstart")
        dfg.add_vertex(Vertex("upper", "/qs/upper"))
        dfg.add_vertex(Vertex("reverse", "/qs/reverse"))
        dfg.add_vertex(Vertex("out", "/qs/out",
                              persistence=Persistence.PERSISTENT, replication=2))
        dfg.add_edge("upper", "reverse")
        dfg.add_edge("reverse", "out")

        # 2. thin lambda wrappers using the SDK context
        def lam_upper(ctx, obj):
            ctx.emit(obj.key.rsplit("/", 1)[-1], obj.payload.upper(), trigger=True)

        def lam_reverse(ctx, obj):
            ctx.emit(obj.key.rsplit("/", 1)[-1], obj.payload[::-1])

        svc.deploy(dfg, {"upper": lam_upper, "reverse": lam_reverse})

        # 3. fire an object through the fast path
        svc.inject("quickstart", "greeting", b"hello cascade")
        time.sleep(0.05)
        out = svc.get("/qs/out/greeting")
        print(f"result: {out.payload!r} (version {out.version})")

        # 4. versions + temporal reads come for free on persistent pools
        for i in range(3):
            svc.put("/qs/out/greeting", f"edit-{i}".encode())
            time.sleep(0.002)
        latest = svc.get("/qs/out/greeting")
        first = svc.store.get_version("/qs/out/greeting", 0)
        asof = svc.store.get_time("/qs/out/greeting", out.timestamp_ns)
        print(f"latest:  {latest.payload!r} (v{latest.version})")
        print(f"v0:      {first.payload!r}")
        print(f"temporal as-of first write: {asof.payload!r}")
        assert asof.payload == out.payload
        print("OK")


if __name__ == "__main__":
    main()

"""Smart-farming pipeline (paper §5.2): filter → body-condition-score → store.

Two real (tiny) JAX models deployed as DLL-style lambdas; frames stream in
via trigger puts and land, scored, in a volatile pool.  Prints the Fig-10
style latency breakdown.

Run: PYTHONPATH=src python examples/smart_farming.py
"""
import statistics
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DFG, CascadeService, Vertex


def main() -> None:
    key = jax.random.PRNGKey(0)
    w_f1 = jax.random.normal(key, (768, 64)) / 28.0
    w_f2 = jax.random.normal(key, (64, 2)) / 8.0
    w_b1 = jax.random.normal(key, (768, 128)) / 28.0
    w_b2 = jax.random.normal(key, (128, 5)) / 12.0

    @jax.jit
    def filter_model(x):   # "is there a valid animal in frame?"
        return jnp.argmax(jnp.maximum(x @ w_f1, 0) @ w_f2, axis=-1)

    @jax.jit
    def bcs_model(x):      # body-condition score 0..4
        return jnp.argmax(jnp.maximum(x @ w_b1, 0) @ w_b2, axis=-1)

    frame = np.random.randn(1, 768).astype(np.float32)
    filter_model(frame).block_until_ready()
    bcs_model(frame).block_until_ready()

    with tempfile.TemporaryDirectory() as d, \
         CascadeService(n_workers=4, log_dir=d) as svc:
        dfg = DFG(name="sf")
        dfg.add_vertex(Vertex("filter", "/sf/detect_animal", shard_workers=(0,)))
        dfg.add_vertex(Vertex("bcs", "/sf/assess_bcs", shard_workers=(1, 2)))
        dfg.add_vertex(Vertex("store", "/sf/save_image", replication=2))
        dfg.add_edge("filter", "bcs")
        dfg.add_edge("bcs", "store")

        done = threading.Event()
        stamps: dict[str, float] = {}

        def lam_filter(ctx, obj):
            stamps["f0"] = time.monotonic()
            keep = int(filter_model(obj.payload)[0]) >= 0
            stamps["f1"] = time.monotonic()
            if keep:
                ctx.emit(obj.key.rsplit("/", 1)[-1], obj.payload, trigger=True)

        def lam_bcs(ctx, obj):
            stamps["b0"] = time.monotonic()
            score = int(bcs_model(obj.payload)[0])
            stamps["b1"] = time.monotonic()
            ctx.emit(obj.key.rsplit("/", 1)[-1],
                     {"score": score, "rfid": "cow-042"})
            done.set()

        svc.deploy(dfg, {"filter": lam_filter, "bcs": lam_bcs})

        e2e = []
        for i in range(50):
            done.clear()
            t0 = time.monotonic()
            svc.trigger_put(f"/sf/detect_animal/frame{i}", frame)
            assert done.wait(5)
            e2e.append((time.monotonic() - t0) * 1e3)
        compute = ((stamps["f1"] - stamps["f0"]) + (stamps["b1"] - stamps["b0"])) * 1e3
        med = statistics.median(e2e)
        print(f"frames: 50   e2e median: {med:.2f} ms   "
              f"model compute (last frame): {compute:.2f} ms   "
              f"forwarding share: {max(0.0, med - compute) / med:.0%}")
        result = svc.get(f"/sf/save_image/frame49")
        print(f"stored record: {result.payload}")
        print("OK")


if __name__ == "__main__":
    main()

"""End-to-end driver: a multi-tenant ServeNode on the Cascade fast path.

One node — one shared worker set, one store, one KV device store — hosts TWO
models side by side: a paged attention model ("light") and a dense SSM model
("heavy"), each under its own ``/serve/<model>`` pools.  Three serving
patterns are exercised:

1. FIFO session affinity on the light deployment: every turn of a chat
   session lands on the same replica, in order, so the replica's prefix trie
   serves warm turns from cached KV blocks.
2. Cascade escalation (CascadeServe): requests go to the light model first;
   when the gate trips — mean decode logprob below a threshold, read from
   the per-token scores the engine surfaced in-dispatch — the request is
   escalated via an internal trigger_put into the heavy deployment's pool.
3. Bounded admission (MultiTASC++): the light tier's per-replica queues get
   a watermark; an overload burst is redirected to less-loaded siblings and
   then shed with a structured reason — tail latency stays bounded, and the
   cascade fails shed requests over to the heavy tier so nothing is dropped.

Run: PYTHONPATH=src python examples/serve_cluster.py
"""
import statistics

import numpy as np

import jax

from repro.configs.registry import get_config
from repro.core.pools import DispatchPolicy
from repro.models import init_params
from repro.serving.cluster import CascadeGate, CascadeRoute, ServeNode


def main() -> None:
    light_cfg = get_config("gemma2-9b", smoke=True)
    heavy_cfg = get_config("mamba2-1.3b", smoke=True)
    light_params = init_params(jax.random.PRNGKey(0), light_cfg)
    heavy_params = init_params(jax.random.PRNGKey(1), heavy_cfg)
    rng = np.random.default_rng(0)

    with ServeNode(n_workers=2) as node:
        light = node.deploy("light", light_cfg, light_params, n_replicas=2,
                            n_slots=4, max_len=64,
                            policy=DispatchPolicy.FIFO)
        heavy = node.deploy("heavy", heavy_cfg, heavy_params, n_replicas=2,
                            n_slots=4, max_len=64)
        assert light.paged and not heavy.paged

        # ---- 1. FIFO chat sessions on the light model: affinity + prefix
        # reuse (each turn's prompt extends the session's full history)
        sessions, turns = ["alice", "bob", "carol"], 3
        history = {s: rng.integers(0, light_cfg.vocab_size,
                                   (8,)).astype(np.int32) for s in sessions}
        for t in range(turns):
            for s in sessions:
                light.submit(s, f"{s}-t{t}", history[s], max_new_tokens=6)
            node.run_until_drained()
            for s in sessions:
                reply = light.result(f"{s}-t{t}")
                new = rng.integers(0, light_cfg.vocab_size,
                                   (6,)).astype(np.int32)
                history[s] = np.concatenate(
                    [history[s], reply.astype(np.int32), new])
        st = light.stats()
        for s in sessions:
            replicas = {light.routed[f"{s}-t{t}"] for t in range(turns)}
            assert len(replicas) == 1, "FIFO must pin a session to one replica"
        print(f"[light/FIFO] {st['requests']} turns over "
              f"{st['n_replicas']} replicas "
              f"(per replica: {st['per_replica_requests']})")
        print(f"             prefix reuse: {st['prefix_hit_tokens']} of "
              f"{st['prompt_tokens']} prompt tokens from cached blocks")
        assert st["prefix_hit_tokens"] > 0, "warm turns must hit the trie"
        assert st["host_syncs"] == st["ticks"]   # paged invariant

        # ---- 2. cascade escalation: calibrate the gate on the light
        # model's own confidence, then route — uncertain answers re-run on
        # the heavy model, confident ones never touch it
        probe_scores = []
        probe = lambda req: probe_scores.append(req.mean_logprob())
        light.on_done.append(probe)
        for i in range(8):
            light.submit("cal", f"cal{i}",
                         rng.integers(0, light_cfg.vocab_size,
                                      (8,)).astype(np.int32),
                         max_new_tokens=6)
        node.run_until_drained()
        light.on_done.remove(probe)
        gate = CascadeGate("logprob",
                           threshold=statistics.median(probe_scores))
        route = CascadeRoute(light, heavy, gate)
        n = 12
        for i in range(n):
            route.submit(f"u{i % 4}", f"r{i}",
                         rng.integers(0, light_cfg.vocab_size,
                                      (int(rng.integers(4, 12)),))
                         .astype(np.int32), max_new_tokens=6)
        node.run_until_drained()
        rs = route.stats()
        print(f"[cascade]    {rs['escalated']}/{rs['requests']} escalated "
              f"(rate {rs['escalation_rate']:.2f}, gate "
              f"mean-logprob < {rs['threshold']:.3f})")
        assert all(route.result(f"r{i}") is not None for i in range(n))
        hs = heavy.stats()
        assert hs["host_syncs"] == hs["decode_ticks"] + hs["prefill_batches"]

        # ---- 3. bounded admission: watermark the light tier, overload it,
        # watch shed/redirect keep the queues bounded while the cascade
        # fails shed requests over to the heavy tier
        light.watermark = 6
        for i in range(24):
            route.submit(f"burst{i % 3}", f"b{i}",
                         rng.integers(0, light_cfg.vocab_size,
                                      (8,)).astype(np.int32),
                         max_new_tokens=4)
        node.run_until_drained()
        ls = light.stats()
        print(f"[overload]   shed={ls['shed']} redirected={ls['redirected']} "
              f"(watermark {light.watermark}); all "
              f"{sum(route.result(f'b{i}') is not None for i in range(24))}"
              f"/24 answered")
        assert all(len(route.result(f"b{i}")) == 4 for i in range(24)), \
            "a shed request must fail over to the heavy tier, not vanish"
        print("OK")


if __name__ == "__main__":
    main()

"""End-to-end driver: a multi-replica LM serving cluster on the Cascade
fast path.

Requests enter as ``trigger_put``s on ``/serve/<model>/req/<session>/<id>``
and flow store → dispatcher → upcall thread → engine replica; responses are
``put`` back into ``/serve/<model>/out`` where the client reads them.  Both
dispatch policies are exercised:

- FIFO — every turn of a chat session lands on the same replica, in order
  (KV/session locality);
- ROUND_ROBIN — independent requests spread evenly over the replicas.

Run: PYTHONPATH=src python examples/serve_cluster.py
"""
import numpy as np

import jax

from repro.configs.registry import get_config
from repro.core.pools import DispatchPolicy
from repro.models import init_params
from repro.serving.cluster import ServeCluster


def main() -> None:
    cfg = get_config("gemma2-9b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # ---- FIFO: three chat sessions, four turns each, pinned per replica.
    # Each turn's prompt extends the session's full history, so the replica's
    # prefix trie (paged KV) lets warm turns skip the cached prefix blocks.
    with ServeCluster(cfg, params, n_replicas=2, n_slots=4, max_len=64,
                      policy=DispatchPolicy.FIFO) as cluster:
        sessions, turns = ["alice", "bob", "carol"], 4
        history = {s: rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
                   for s in sessions}
        for t in range(turns):
            for s in sessions:
                cluster.submit(s, f"{s}-t{t}", history[s], max_new_tokens=6)
            cluster.run_until_drained()
            for s in sessions:
                reply = cluster.result(f"{s}-t{t}")
                new = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
                history[s] = np.concatenate(
                    [history[s], reply.astype(np.int32), new])
        st = cluster.stats()
        print(f"[FIFO] {st['requests']} requests over "
              f"{st['n_replicas']} replicas "
              f"(per replica: {st['per_replica_requests']})")
        for s in sessions:
            replicas = {cluster.routed[f"{s}-t{t}"] for t in range(turns)}
            toks = cluster.result(f"{s}-t{turns-1}")
            print(f"  session {s}: replica {sorted(replicas)}, "
                  f"last turn → {toks.tolist()}")
            assert len(replicas) == 1, "FIFO must pin a session to one replica"
        print(f"       prefix reuse: {st['prefix_hit_tokens']} of "
              f"{st['prompt_tokens']} prompt tokens served from cached "
              f"blocks ({st['prefix_hits']} warm turns)")
        assert st["prefix_hit_tokens"] > 0, "warm turns must hit the trie"
        assert st["host_syncs"] == st["ticks"]   # one sync per unified tick

    # ---- ROUND_ROBIN: independent requests, load spread evenly
    with ServeCluster(cfg, params, n_replicas=2, n_slots=4, max_len=64,
                      policy=DispatchPolicy.ROUND_ROBIN) as cluster:
        n = 12
        for i in range(n):
            prompt = rng.integers(0, cfg.vocab_size,
                                  (int(rng.integers(4, 12)),))
            cluster.submit("load", f"r{i}", prompt.astype(np.int32),
                           max_new_tokens=6)
        cluster.run_until_drained()
        st = cluster.stats()
        print(f"[RR]   {st['requests']} requests, per replica "
              f"{st['per_replica_requests']}")
        print(f"       TTFT p50 {st['ttft_p50_s']*1e3:.1f} ms  "
              f"p99 {st['ttft_p99_s']*1e3:.1f} ms (incl. jit compile)")
        print(f"       TPOT p50 {st['tpot_p50_s']*1e3:.1f} ms  "
              f"p99 {st['tpot_p99_s']*1e3:.1f} ms")
        print(f"       host syncs {st['host_syncs']} = unified ticks "
              f"{st['ticks']} ({st['prefill_chunks']} prefill chunks packed)")
        assert st["per_replica_requests"] == [n // 2, n // 2]
        assert all(cluster.result(f"r{i}") is not None for i in range(n))
        assert st["host_syncs"] == st["ticks"]
    print("OK")


if __name__ == "__main__":
    main()

"""End-to-end driver: serve a small LM with batched requests on the Cascade
fast path (the paper's hosting model applied to token serving).

A reduced gemma2-family model is hosted by a ServeEngine (continuous
batching, KV slots); requests are routed by the Cascade dispatch policies
(FIFO pins a session to a replica; RR load-balances).  Reports TTFT / TPOT.

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import statistics

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core.pools import DispatchPolicy
from repro.models import init_params
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import Request, Scheduler


def main() -> None:
    cfg = get_config("gemma2-9b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, n_slots=4, max_len=64,
                         scheduler=Scheduler(policy=DispatchPolicy.FIFO,
                                             n_replicas=1))
    rng = np.random.default_rng(0)
    n_requests = 10
    for i in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size, size=(int(rng.integers(4, 12)),))
        engine.submit(Request(request_id=f"req-{i}",
                              session_key=f"user-{i % 3}",
                              prompt=prompt.astype(np.int32),
                              max_new_tokens=8))
    engine.run_until_drained()

    s = engine.stats
    print(f"requests: {n_requests}   prefills: {s.prefills}   "
          f"tokens out: {s.tokens_out}   engine ticks: {s.ticks}")
    print(f"TTFT  median: {statistics.median(s.ttft_s)*1e3:.1f} ms "
          f"(includes first-call jit compile)")
    print(f"TPOT  median: {statistics.median(s.tpot_s)*1e3:.1f} ms/token "
          f"across batched decode")
    assert s.prefills == n_requests
    assert s.tokens_out >= n_requests * 8
    print("OK")


if __name__ == "__main__":
    main()

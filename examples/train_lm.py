"""End-to-end driver: train a ~100M-param LM for a few hundred steps with the
full substrate — synthetic data pipeline, AdamW, remat, fault-tolerant loop
with Cascade-persistent checkpoints, straggler monitor.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200]
(~100M params; a few hundred steps takes a while on 1 CPU core — use
--steps 30 for a quick look.)
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, synthetic_batch
from repro.training.ft import FaultTolerantLoop, StepMonitor
from repro.training.optimizer import get_optimizer
from repro.training.train import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: 8L × d512 × ffn2048, 32k vocab
    cfg = ModelConfig(name="lm100m", family="dense", n_layers=8, d_model=512,
                      n_heads=8, n_kv_heads=4, d_ff=2048, vocab_size=32_000,
                      dtype="float32", q_chunk=128)
    print(f"params: {cfg.param_count()/1e6:.0f}M")

    opt = get_optimizer("adamw", lr=3e-4, warmup_steps=20)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    dcfg = DataConfig(batch=args.batch, seq_len=args.seq)

    def batches():
        i = 0
        while True:
            yield {k: jnp.asarray(v) for k, v in synthetic_batch(cfg, dcfg, i).items()}
            i += 1

    losses = []

    def on_metrics(step_i, metrics, dt):
        losses.append(float(metrics["loss"]))
        if step_i % 10 == 0 or step_i <= 3:
            print(f"step {step_i:4d}  loss {losses[-1]:.3f}  "
                  f"grad_norm {float(metrics['grad_norm']):.2f}  {dt*1e3:.0f} ms")

    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(os.path.join(d, "ckpt.log"))
        loop = FaultTolerantLoop(step, state, ckpt=ckpt, ckpt_every=50,
                                 monitor=StepMonitor(),
                                 on_straggler=lambda s: print(f"straggler @ {s}"))
        loop.run(batches(), args.steps, metrics_cb=on_metrics)
        print(f"final loss: {losses[-1]:.3f} (start {losses[0]:.3f})")
        print(f"checkpointed through step {ckpt.latest_step()}")
        assert losses[-1] < losses[0]
        ckpt.close()
    print("OK")


if __name__ == "__main__":
    main()

"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis is pure
    data parallelism over DCN/ICI — checkpoint/elastic ops work at pod
    granularity (training/ft.py)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_devices: int | None = None, model: int = 1):
    """Small mesh over the real local devices (tests/examples)."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))

"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis is pure
    data parallelism over DCN/ICI — checkpoint/elastic ops work at pod
    granularity (training/ft.py)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_devices: int | None = None, model: int = 1):
    """Small mesh over the real local devices (tests/examples).

    ``model`` must divide the device count: a (n // model, model) mesh over
    a non-divisible count would silently use only (n // model) * model
    devices and strand the rest — surfaced as an error instead."""
    n = n_devices or len(jax.devices())
    if model <= 0 or n % model != 0:
        used = (n // model) * model if model > 0 else 0
        raise ValueError(
            f"model={model} does not divide n_devices={n}: a "
            f"({max(n // model, 0)}, {model}) mesh would use {used} "
            f"device(s) and strand {n - used}")
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_slices(n_slices: int, devices_per_slice: int, *, devices=None):
    """Carve a device list into ``n_slices`` DISJOINT (data=1, model=d)
    meshes — one per serving replica, so each replica's params and KV pool
    collocate on its own slice (no two replicas share a device).

    ``devices`` defaults to all local devices; the allocation is a plain
    prefix split, so callers that manage a free pool (serving.ServeNode)
    pass exactly the devices they own."""
    import numpy as np
    from jax.sharding import Mesh

    devs = list(jax.devices()) if devices is None else list(devices)
    if n_slices <= 0 or devices_per_slice <= 0:
        raise ValueError(f"need positive n_slices={n_slices} and "
                         f"devices_per_slice={devices_per_slice}")
    need = n_slices * devices_per_slice
    if need > len(devs):
        raise ValueError(
            f"{n_slices} slice(s) x {devices_per_slice} device(s) needs "
            f"{need} devices but only {len(devs)} are available")
    out = []
    for s in range(n_slices):
        sl = devs[s * devices_per_slice:(s + 1) * devices_per_slice]
        out.append(Mesh(np.array(sl, dtype=object)
                        .reshape(1, devices_per_slice), ("data", "model")))
    return out

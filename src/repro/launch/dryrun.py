"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh, prove memory fit, and extract roofline terms.

MUST set the placeholder device count before ANY jax import (jax locks the
device count on first init) — hence the first two lines.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import subprocess
import sys
import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCH_IDS, SHAPES, Cell, all_cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_shardings, make_rules, opt_state_axes,
                                   tree_shardings)
from repro.models import (cache_axes, decode_step, forward, init_decode_caches,
                          init_params, param_axes, prefill)
from repro.models.config import ModelConfig
from repro.training.optimizer import get_optimizer
from repro.training.train import TrainState, make_train_step

# ----------------------------------------------------------- hardware model
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip (TPU v5e class)
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per chip (per-link figure per assignment)

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z]+\d+(?:_\d+)?)\[([\d,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8_e4m3": 1, "f8_e5m2": 1, "s4": 1, "u4": 1}


def _nbytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUP_RE = re.compile(r"replica_groups=\[\d+,(\d+)\]")


def parse_collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device bytes moved by every collective in compiled HLO text.

    Post-optimization HLO annotates only RESULT types, so we parse those and
    apply a per-op ring-transfer model (g = replica group size):
      all-reduce        ≈ 2·result·(g-1)/g   (reduce-scatter + all-gather ring)
      all-gather        ≈ result·(g-1)/g     (result is the gathered size)
      reduce-scatter    ≈ result·(g-1)      (operand = result·g, ring (g-1)/g)
      all-to-all        ≈ result·(g-1)/g
      collective-permute≈ result             (point-to-point)
    """
    out: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*", s)
        if m is None:
            continue
        rest = s[m.end():]
        opm = re.search(r"\b(" + "|".join(_COLLECTIVES) + r")\(", rest)
        if opm is None:
            continue
        op = opm.group(1)
        result_part = rest[:opm.start()]
        rbytes = sum(_nbytes(dt, dims)
                     for dt, dims in _SHAPE_RE.findall(result_part))
        gm = _GROUP_RE.search(s)
        g = int(gm.group(1)) if gm else 2
        ring = (g - 1) / g
        if op == "all-reduce":
            moved = 2 * rbytes * ring
        elif op == "all-gather":
            moved = rbytes * ring
        elif op == "reduce-scatter":
            moved = rbytes * (g - 1)
        elif op == "all-to-all":
            moved = rbytes * ring
        else:  # collective-permute
            moved = rbytes
        out[op] += int(moved)
    return out


# ----------------------------------------------------------- input specs
def input_specs(arch_id: str, shape_name: str, *, multi_pod: bool = False,
                cfg: ModelConfig | None = None) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a cell (weak-type
    correct, shardable, no allocation)."""
    cfg = cfg or get_config(arch_id)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    tok_dt = jnp.int32
    emb_dt = jnp.dtype(cfg.dtype)

    if shape.kind == "train":
        inputs = (jax.ShapeDtypeStruct((B, S, cfg.d_model), emb_dt)
                  if cfg.input_mode == "embeds"
                  else jax.ShapeDtypeStruct((B, S), tok_dt))
        return {
            "inputs": inputs,
            "targets": jax.ShapeDtypeStruct((B, S), tok_dt),
            "positions": jax.ShapeDtypeStruct((B, S), tok_dt),
            "mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
        }
    if shape.kind == "prefill":
        inputs = (jax.ShapeDtypeStruct((B, S, cfg.d_model), emb_dt)
                  if cfg.input_mode == "embeds"
                  else jax.ShapeDtypeStruct((B, S), tok_dt))
        return {"inputs": inputs,
                "positions": jax.ShapeDtypeStruct((B, S), tok_dt)}
    # decode: one new token against a seq_len cache
    inputs = (jax.ShapeDtypeStruct((B, 1, cfg.d_model), emb_dt)
              if cfg.input_mode == "embeds"
              else jax.ShapeDtypeStruct((B,), tok_dt))
    return {"inputs": inputs,
            "positions": jax.ShapeDtypeStruct((B, 1), tok_dt)}


# ----------------------------------------------------------- cell lowering
def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
               overrides: dict | None = None):
    overrides = dict(overrides or {})
    rule_overrides = overrides.pop("_rules", None)   # sharding-rule overrides
    grad_accum = int(overrides.pop("_grad_accum", 1))
    cfg = get_config(arch_id)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(cfg, mesh, batch=shape.global_batch)
    if rule_overrides:
        for k, v in rule_overrides.items():
            rules[k] = tuple(v) if isinstance(v, list) else v

    p_axes = param_axes(cfg)
    p_shard = tree_shardings(p_axes, mesh, rules)
    params_shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                                   jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = input_specs(arch_id, shape_name, cfg=cfg)
    b_shard = batch_shardings(specs, mesh, rules)

    if shape.kind == "train":
        from repro.launch.sharding import _is_axes_leaf, leaf_spec
        opt = get_optimizer(cfg.optimizer)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        o_axes = opt_state_axes(opt.name, p_axes)
        o_shard = jax.tree.map(
            lambda a: NamedSharding(mesh, leaf_spec(a, rules)), o_axes,
            is_leaf=_is_axes_leaf)
        state_shapes = TrainState(params=params_shapes, opt_state=opt_shapes)
        state_shard = TrainState(params=p_shard, opt_state=o_shard)
        step_fn = make_train_step(cfg, opt, grad_accum=grad_accum)
        jitted = jax.jit(step_fn, in_shardings=(state_shard, b_shard),
                         out_shardings=(state_shard, None),
                         donate_argnums=(0,))
        with mesh:
            lowered = jitted.lower(state_shapes, specs)
        return lowered, cfg, mesh

    if shape.kind == "prefill":
        def prefill_fn(params, inputs, positions):
            return prefill(params, inputs, positions, cfg, max_len=shape.seq_len)

        jitted = jax.jit(prefill_fn,
                         in_shardings=(p_shard, b_shard["inputs"],
                                       b_shard["positions"]))
        with mesh:
            lowered = jitted.lower(params_shapes, specs["inputs"],
                                   specs["positions"])
        return lowered, cfg, mesh

    # decode
    cache_shapes = jax.eval_shape(
        lambda: init_decode_caches(cfg, shape.global_batch, shape.seq_len))
    c_shard = tree_shardings(cache_axes(cfg), mesh, rules)

    def serve_step(params, caches, inputs, positions):
        return decode_step(params, caches, inputs, positions, cfg)

    jitted = jax.jit(serve_step,
                     in_shardings=(p_shard, c_shard, b_shard["inputs"],
                                   b_shard["positions"]),
                     out_shardings=(None, c_shard),
                     donate_argnums=(1,))
    with mesh:
        lowered = jitted.lower(params_shapes, cache_shapes, specs["inputs"],
                               specs["positions"])
    return lowered, cfg, mesh


def model_flops(cfg: ModelConfig, shape) -> float:
    """6·N_active·D reference FLOPs for the cell (decode: D = batch tokens)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch      # one token per sequence


def _measure(arch_id: str, shape_name: str, *, multi_pod: bool,
             overrides: dict | None) -> dict:
    """Lower+compile one variant; return raw per-device costs."""
    t0 = time.time()
    lowered, cfg, mesh = lower_cell(arch_id, shape_name, multi_pod=multi_pod,
                                    overrides=overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = parse_collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
        "mem": mem,
        "chips": int(mesh.devices.size),
        "t_lower": t_lower,
        "t_compile": t_compile,
        "cfg": cfg,
    }


def _attn_chunk_topup(cfg: ModelConfig, shape, mesh) -> float:
    """Analytic per-chip attention FLOPs hidden by the q-chunk inner scan.

    The chunked-attention scan body (one q-chunk vs full K) is counted once
    by cost_analysis, i.e. 1/n_chunks of the attention einsum FLOPs; this
    returns the missing (n_chunks-1)/n_chunks share.  Train steps pay the
    attention ~4× (fwd + remat recompute + bwd dq/dk·dv), prefill 1×.
    """
    S = shape.seq_len
    nc = -(-S // cfg.q_chunk)
    if shape.kind == "decode" or nc <= 1:
        return 0.0
    n_attn = sum(sum(1 for s in seg.pattern if s.kind != "mamba") * seg.repeat
                 for seg in cfg.layout())
    if n_attn == 0:
        return 0.0
    # QKᵀ + PV einsums, unmasked (the impl masks but computes full blocks)
    per_layer = 4.0 * shape.global_batch * S * S * cfg.n_heads * cfg.head_dim
    mult = 4.0 if (shape.kind == "train" and cfg.remat) else \
        (3.0 if shape.kind == "train" else 1.0)
    total = per_layer * n_attn * mult * (nc - 1) / nc
    # per-chip divisor: batch over data(+pod); heads over model when sharded
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    div = 1
    if shape.global_batch % max(1, axes.get("data", 1)) == 0:
        div *= axes.get("data", 1)
        if "pod" in axes and shape.global_batch % (axes["pod"] * axes["data"]) == 0:
            div *= axes["pod"]
    if cfg.n_heads % max(1, axes.get("model", 1)) == 0:
        div *= axes.get("model", 1)
    return total / div


def corrected_costs(arch_id: str, shape_name: str, *, multi_pod: bool,
                    overrides: dict | None) -> dict:
    """Scan-aware costs: XLA cost_analysis counts while-loop bodies ONCE, so
    we lower repeat=1 and repeat=2 UNROLLED ladder variants per segment and
    scale the per-body diff by the true trip count.  The inner q-chunk
    attention scan is topped up analytically (_attn_chunk_topup)."""
    cfg_overrides = {k: v for k, v in (overrides or {}).items()
                     if not k.startswith("_")}
    base_cfg = get_config(arch_id)
    if cfg_overrides:
        base_cfg = base_cfg.replace(**cfg_overrides)
    segs = base_cfg.layout()
    ones = tuple(1 for _ in segs)
    shape = SHAPES[shape_name]

    ov = dict(overrides or {})
    ov.pop("_grad_accum", None)   # roofline terms measured at accum=1
    ov["layout_repeats"] = ones
    ov["scan_unroll"] = True       # unrolled bodies are visible to cost_analysis
    base = _measure(arch_id, shape_name, multi_pod=multi_pod, overrides=ov)
    flops = base["flops"]
    nbytes = base["bytes"]
    coll = dict(base["coll"])
    for i, seg in enumerate(segs):
        if seg.repeat <= 1:
            continue
        reps = list(ones)
        reps[i] = 2
        ov2 = dict(ov, layout_repeats=tuple(reps))
        two = _measure(arch_id, shape_name, multi_pod=multi_pod, overrides=ov2)
        mult = seg.repeat - 1
        flops += mult * (two["flops"] - base["flops"])
        nbytes += mult * (two["bytes"] - base["bytes"])
        for k in coll:
            coll[k] += mult * (two["coll"][k] - base["coll"][k])
    mesh = make_production_mesh(multi_pod=multi_pod)
    flops += _attn_chunk_topup(base_cfg, shape, mesh)
    return {"flops": flops, "bytes": nbytes,
            "coll": {k: max(0.0, v) for k, v in coll.items()}}


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             out_dir: str, overrides: dict | None = None,
             tag: str = "", calibrate: bool = True) -> dict:
    full = _measure(arch_id, shape_name, multi_pod=multi_pod,
                    overrides=overrides)
    cfg, mem, n_chips = full["cfg"], full["mem"], full["chips"]
    shape = SHAPES[shape_name]
    mf = model_flops(cfg, shape)

    if calibrate and not multi_pod:
        corr = corrected_costs(arch_id, shape_name, multi_pod=multi_pod,
                               overrides=overrides)
        flops, bytes_accessed = corr["flops"], corr["bytes"]
        coll = corr["coll"]
    else:
        flops, bytes_accessed, coll = full["flops"], full["bytes"], full["coll"]
    coll_bytes = float(sum(coll.values()))

    result = {
        "arch": arch_id, "shape": shape_name, "multi_pod": multi_pod,
        "tag": tag, "chips": n_chips, "calibrated": calibrate and not multi_pod,
        "seconds": {"lower": round(full["t_lower"], 1),
                    "compile": round(full["t_compile"], 1)},
        "memory_analysis": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "cost_analysis": {"flops": flops, "bytes_accessed": bytes_accessed,
                          "raw_flops_uncorrected": full["flops"]},
        "collective_bytes": coll,
        "roofline": {
            # cost_analysis is per-device post-SPMD; terms are per-chip step time
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_accessed / HBM_BW,
            "collective_s": coll_bytes / ICI_BW,
            "model_flops_global": mf,
            "useful_flops_ratio": (mf / n_chips) / flops if flops else 0.0,
        },
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: result["roofline"][k])
    result["roofline"]["dominant"] = dom
    os.makedirs(out_dir, exist_ok=True)
    suffix = ("_" + tag if tag else "") + ("_multipod" if multi_pod else "")
    fname = f"{arch_id}__{shape_name}{suffix}.json".replace("/", "_")
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=2)
    return result


# ----------------------------------------------------------------- CLI
def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every cell in subprocesses")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field=value overrides (e.g. moe_impl=scatter)")
    args = ap.parse_args()

    if args.all:
        failures = []
        jobs = []
        size_rank = {a: get_config(a).param_count() for a in ARCH_IDS}
        kind_rank = {"decode": 0, "prefill": 1, "train": 2}
        for cell in all_cells():
            if cell.skipped:
                print(f"SKIP {cell.name}: {cell.skip_reason}", flush=True)
                continue
            meshes = [False, True] if args.both_meshes else [args.multi_pod]
            for mp in meshes:
                jobs.append((cell, mp))
        # cheapest first: decode < prefill < train, small models first,
        # single-pod (with calibration) before multi-pod
        jobs.sort(key=lambda j: (kind_rank[j[0].shape.kind],
                                 size_rank[j[0].arch_id], j[1]))
        for cell, mp in jobs:
            suffix = ("_" + args.tag if args.tag else "") + ("_multipod" if mp else "")
            fname = f"{cell.arch_id}__{cell.shape.name}{suffix}.json"
            if os.path.exists(os.path.join(args.out, fname)):
                print(f"HAVE {cell.name} multi_pod={mp}", flush=True)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", cell.arch_id, "--shape", cell.shape.name,
                   "--out", args.out]
            if mp:
                cmd.append("--multi-pod")
            for ov in args.override:
                cmd += ["--override", ov]
            if args.tag:
                cmd += ["--tag", args.tag]
            print(f"RUN  {cell.name} multi_pod={mp}", flush=True)
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                failures.append((cell.name, mp, r.stderr[-2000:]))
                print(f"FAIL {cell.name}: {r.stderr[-500:]}", flush=True)
            else:
                last = r.stdout.strip().splitlines()[-1] if r.stdout.strip() else "ok"
                print(f"  [{time.time()-t0:5.0f}s] {last}", flush=True)
        if failures:
            print(f"\n{len(failures)} FAILURES")
            for name, mp, err in failures:
                print(f"--- {name} mp={mp}\n{err}\n")
            sys.exit(1)
        print("\nALL CELLS PASS")
        return

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v
    res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   out_dir=args.out, overrides=overrides or None, tag=args.tag)
    r = res["roofline"]
    print(f"{args.arch}@{args.shape} mp={args.multi_pod} "
          f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
          f"collective={r['collective_s']:.4f}s dominant={r['dominant']} "
          f"useful={r['useful_flops_ratio']:.2f} "
          f"temp={res['memory_analysis']['temp_bytes']/2**30:.2f}GiB")


if __name__ == "__main__":
    main()

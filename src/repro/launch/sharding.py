"""Logical-axis sharding rules → NamedShardings (MaxText-style, with
per-config divisibility fallbacks and per-leaf mesh-axis dedup).

Every param/cache leaf carries a tuple of logical axis names (see
models/*.py `*_axes()`).  ``make_rules`` resolves names to mesh axes for a
given (config, mesh, shape); ``leaf_spec`` assigns mesh axes to a leaf's
dims in PRIORITY order, skipping mesh axes already used by that leaf —
so e.g. llama4's 40 heads (not divisible by model=16) fall back to sharding
the attention weights' embed dim instead of replicating them.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

# Leaf-dim assignment priority: most valuable shardings first.
_PRIORITY = ("expert", "vocab", "ffn", "heads", "kv_heads", "ssm_heads",
             "cache_seq", "cache_batch", "batch", "seq", "embed", "layers")


def _div(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


def make_rules(cfg: ModelConfig, mesh: Mesh, *, batch: int | None = None,
               fsdp: bool = False, seq_shard_cache: bool | None = None) -> dict:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = axes.get("model", 1)
    data = axes.get("data", 1)
    pod = axes.get("pod", 1)

    # batch axes: largest prefix of (pod, data) that divides the batch
    batch_axes: tuple[str, ...] = ()
    if batch is not None:
        if "pod" in axes and _div(batch, pod * data):
            batch_axes = ("pod", "data")
        elif _div(batch, data):
            batch_axes = ("data",)
    long_ctx = seq_shard_cache if seq_shard_cache is not None else (batch == 1)

    rules: dict[str, Any] = {
        "vocab": "model" if _div(cfg.vocab_size, model) else None,
        "embed": ("data" if fsdp and _div(cfg.d_model, data) else None),
        "heads": "model" if _div(cfg.n_heads, model) else None,
        "kv_heads": "model" if _div(cfg.n_kv_heads, model) else None,
        "ssm_heads": "model" if cfg.ssm_state and _div(cfg.ssm_heads, model) else None,
        "ffn": "model",
        "expert": ("data" if cfg.n_experts and _div(cfg.n_experts, data) else
                   ("model" if cfg.n_experts and _div(cfg.n_experts, model) else None)),
        "layers": None,
        "batch": batch_axes or None,
        "seq": None,
        "cache_batch": batch_axes or None,
        "cache_seq": ("data" if long_ctx else None),
        None: None,
    }
    return rules


def leaf_spec(axes_tuple: tuple, rules: dict) -> P:
    """Resolve one leaf's logical axes with priority + per-leaf dedup."""
    n = len(axes_tuple)
    resolved: list[Any] = [None] * n
    used: set[str] = set()
    order = sorted(range(n), key=lambda i: _PRIORITY.index(axes_tuple[i])
                   if axes_tuple[i] in _PRIORITY else len(_PRIORITY))
    for i in order:
        name = axes_tuple[i]
        target = rules.get(name)
        if target is None:
            continue
        targets = target if isinstance(target, tuple) else (target,)
        free = tuple(t for t in targets if t not in used)
        if not free:
            continue
        resolved[i] = free if len(free) > 1 else free[0]
        used.update(free)
    return P(*resolved)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def tree_shardings(axes_tree, mesh: Mesh, rules: dict):
    """Map an axes tree (from param_axes/cache_axes) to NamedShardings."""
    return jax.tree.map(
        lambda a: NamedSharding(mesh, leaf_spec(a, rules)),
        axes_tree, is_leaf=_is_axes_leaf)


def param_shardings(cfg: ModelConfig, mesh: Mesh, **rule_kw):
    """NamedShardings for a model's param tree on ``mesh`` (serving-side
    install: ``jax.device_put(params, param_shardings(cfg, mesh))``)."""
    from repro.models import param_axes

    return tree_shardings(param_axes(cfg), mesh, make_rules(cfg, mesh,
                                                            **rule_kw))


def kv_pool_shardings(cfg: ModelConfig, mesh: Mesh, *,
                      kv_dtype: str | None = None):
    """NamedShardings for the serving engines' paged KV block pool.

    K/V leaves are (layers, num_blocks, block_size, kv_heads, head_dim):
    the kv_heads dim shards over 'model' (when divisible — same rule as
    the attention weights), block/slot dims replicate because block tables
    are host-side and every device scatters any (block, slot).  Quantized
    pools' f32 scale leaves (layers, num_blocks, block_size, kv_heads)
    follow the same split, so the whole tree spills/adopts/donates with
    per-leaf exact-match shardings."""
    from repro.models import paged_pool_axes

    return tree_shardings(paged_pool_axes(cfg, kv_dtype=kv_dtype), mesh,
                          make_rules(cfg, mesh))


def batch_shardings(batch_tree_shapes: dict, mesh: Mesh, rules: dict):
    """Shardings for a data batch: leading dim = batch, rest replicated."""
    b = rules.get("batch")

    def spec(x):
        nd = len(x.shape)
        return NamedSharding(mesh, P(*((b,) + (None,) * (nd - 1))) if b else P())

    return {k: spec(v) for k, v in batch_tree_shapes.items()}


# ---------------------------------------------------------- optimizer state
def opt_state_axes(opt_name: str, param_axes_tree):
    """Axes tree for OptState mirroring training/optimizer.py structures."""
    from repro.training.optimizer import OptState

    if opt_name == "adamw":
        mu = param_axes_tree
        nu = param_axes_tree
    elif opt_name == "adafactor":
        mu = jax.tree.map(lambda a: (), param_axes_tree, is_leaf=_is_axes_leaf)

        def nu_axes(a):
            if len(a) >= 2:
                return {"row": tuple(a[:-1]), "col": tuple(a[:-2]) + (a[-1],)}
            return {"full": tuple(a)}

        nu = jax.tree.map(nu_axes, param_axes_tree, is_leaf=_is_axes_leaf)
    else:
        raise ValueError(opt_name)
    return OptState(step=(), mu=mu, nu=nu)

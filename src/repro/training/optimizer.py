"""Optimizers as pure functions over param pytrees (no optax dependency).

- ``adamw``     — bf16-friendly AdamW; moments in f32, params updated in their
                  own dtype (no separate fp32 master copy: documented choice,
                  halves optimizer memory at 1000-node scale).
- ``adafactor`` — factored second moment (row/col statistics) for the 400B
                  MoE config where full Adam moments cannot fit the pod.

State trees mirror the param tree leaf-for-leaf so the same logical sharding
axes apply (ZeRO-style sharding falls out of the axis rules).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any          # first moment (adamw) or None-like zeros (adafactor)
    nu: Any          # second moment (adamw) / factored stats (adafactor)


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]
    name: str = "opt"


def _global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          warmup_steps: int = 100) -> Optimizer:
    def schedule(step):
        warm = jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))
        return lr * warm

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(zeros, params),
                        nu=jax.tree.map(zeros, params))

    def update(params, state, grads):
        step = state.step + 1
        lr_t = schedule(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            step_ = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * step_).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, OptState(step=step, mu=new_m, nu=new_v)

    return Optimizer(init=init, update=update, name="adamw")


def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              weight_decay: float = 0.0, warmup_steps: int = 100) -> Optimizer:
    """Factored 2nd-moment Adafactor (no momentum): O(rows+cols) state for
    matrices — the memory-fit optimizer for llama4-maverick-400b."""

    def factored(p):
        return p.ndim >= 2

    def init(params):
        def nu_init(p):
            if factored(p):
                return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"full": jnp.zeros(p.shape, jnp.float32)}
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params),
                        nu=jax.tree.map(nu_init, params))

    def update(params, state, grads):
        step = state.step + 1
        warm = jnp.minimum(1.0, step.astype(jnp.float32) / max(1, warmup_steps))
        lr_t = lr * warm
        rho = 1.0 - step.astype(jnp.float32) ** (-decay)

        def upd(p, g, nu):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(p):
                row = rho * nu["row"] + (1 - rho) * jnp.mean(g2, axis=-1)
                col = rho * nu["col"] + (1 - rho) * jnp.mean(g2, axis=-2)
                rmean = jnp.mean(row, axis=-1, keepdims=True)
                vhat = (row / jnp.maximum(rmean, eps))[..., None] * col[..., None, :]
                new_nu = {"row": row, "col": col}
            else:
                vhat = rho * nu["full"] + (1 - rho) * g2
                new_nu = {"full": vhat}
            u = g / jnp.sqrt(jnp.maximum(vhat, eps))
            # update clipping (RMS<=1) as in the paper
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms)
            newp = p.astype(jnp.float32) - lr_t * (u + weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), new_nu

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_nu = tdef.flatten_up_to(state.nu)
        out = [upd(p, g, nu) for p, g, nu in zip(flat_p, flat_g, flat_nu)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_nu = tdef.unflatten([o[1] for o in out])
        return new_p, OptState(step=step, mu=state.mu, nu=new_nu)

    return Optimizer(init=init, update=update, name="adafactor")


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(f"unknown optimizer {name}")

"""Data pipeline: deterministic synthetic token/embedding streams, sharded
host loading, and a Cascade-pool-backed shuffle buffer.

At 1000-node scale each host feeds only its addressable shard of the global
batch; ``ShardedBatcher`` produces exactly the per-host slice (by host id)
and ``jax.make_array_from_process_local_data``-style assembly is left to the
launcher.  On this single-process container the global batch is materialized
directly with the target NamedSharding.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def synthetic_batch(cfg: ModelConfig, dcfg: DataConfig, step: int) -> dict:
    """Deterministic per-step batch: a reproducible fake-corpus stream.

    Tokens follow a skewed Zipf-ish distribution so the softmax/loss path
    sees realistic logits; targets are inputs shifted by one (causal LM).
    """
    rng = np.random.default_rng(dcfg.seed * 1_000_003 + step)
    B, S = dcfg.batch, dcfg.seq_len
    lo, hi = dcfg.host_id * B // dcfg.n_hosts, (dcfg.host_id + 1) * B // dcfg.n_hosts
    nb = hi - lo
    if cfg.input_mode == "embeds":
        x = rng.standard_normal((nb, S, cfg.d_model), dtype=np.float32)
        inputs = x.astype(np.float32)
        targets = rng.integers(0, cfg.vocab_size, (nb, S), dtype=np.int64)
    else:
        # Zipf over the vocab, clipped
        z = rng.zipf(1.3, size=(nb, S + 1)).astype(np.int64)
        toks = np.minimum(z, cfg.vocab_size - 1)
        inputs, targets = toks[:, :-1], toks[:, 1:]
    positions = np.broadcast_to(np.arange(S, dtype=np.int32), (nb, S))
    mask = np.ones((nb, S), np.float32)
    return {
        "inputs": inputs if cfg.input_mode == "embeds" else inputs.astype(np.int32),
        "targets": targets.astype(np.int32),
        "positions": positions.copy(),
        "mask": mask,
    }


class ShardedBatcher:
    """Iterator of per-host batches with optional device placement."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig,
                 sharding: jax.sharding.Sharding | None = None) -> None:
        self.cfg, self.dcfg, self.sharding = cfg, dcfg, sharding
        self.step = 0

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        batch = synthetic_batch(self.cfg, self.dcfg, self.step)
        self.step += 1
        if self.sharding is not None:
            batch = {k: jax.device_put(v, self.sharding) if v.ndim == 2 else v
                     for k, v in batch.items()}
        return batch

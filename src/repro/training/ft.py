"""Fault tolerance & elasticity for long-running multi-pod jobs.

Three mechanisms, designed for 1000+ node operation and exercised (at
reduced scale) by tests and the examples:

1. **Checkpoint/restart** — `FaultTolerantLoop` snapshots train state into
   the Cascade persistent pool every `ckpt_every` steps (async write-back;
   the log's stable-prefix rule guarantees a restart never reads a torn
   checkpoint).  On construction it auto-restores the newest stable step, so
   a killed job resumes exactly where the log is stable — the multi-pod
   contract is "any pod can die; the job loses at most ckpt_every steps".

2. **Straggler mitigation** — `StepMonitor` keeps a rolling step-time
   distribution; a step slower than `threshold ×` the rolling median marks
   the step (and at pod scale, the slowest participating host, reported by
   the launcher) as a straggler.  The loop reacts by (a) recording it, and
   (b) invoking an optional callback — on a real pod the callback remaps the
   round-robin data-feeding order away from the slow host (the same
   round-robin machinery the Cascade dispatcher uses) or triggers elastic
   eviction after `evict_after` consecutive flags.

3. **Elastic scaling** — `elastic_reshard` moves a param/opt pytree onto a
   different mesh by recomputing every leaf's NamedSharding under the new
   mesh and `device_put`-ing (ICI/DCN collective moves, no host round-trip:
   the fast-path discipline applied to re-scaling).  Pods can be added or
   removed between steps; the train step is re-jitted against the new mesh.
"""
from __future__ import annotations

import statistics
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from .checkpoint import CheckpointManager


@dataclass
class StepMonitor:
    window: int = 32
    threshold: float = 2.0
    times: deque = field(default_factory=lambda: deque(maxlen=128))
    stragglers: list[int] = field(default_factory=list)

    def observe(self, step: int, dt_s: float) -> bool:
        self.times.append(dt_s)
        if len(self.times) < 8:
            return False
        med = statistics.median(self.times)
        if dt_s > self.threshold * med:
            self.stragglers.append(step)
            return True
        return False

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0


class FaultTolerantLoop:
    """Wraps a jitted train step with checkpoint/restart + straggler watch."""

    def __init__(self, train_step, state, *, ckpt: CheckpointManager,
                 ckpt_every: int = 50, monitor: StepMonitor | None = None,
                 on_straggler: Callable[[int], None] | None = None) -> None:
        self.train_step = train_step
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.monitor = monitor or StepMonitor()
        self.on_straggler = on_straggler
        self.step = 0
        self.state = state
        # restart path: resume from the newest stable checkpoint if present
        latest = ckpt.latest_step()
        if latest is not None:
            self.step, self.state = ckpt.restore(state)

    def run(self, batches, n_steps: int, *, metrics_cb=None) -> Any:
        it = iter(batches)
        target = self.step + n_steps
        while self.step < target:
            batch = next(it)
            t0 = time.monotonic()
            self.state, metrics = self.train_step(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            self.step += 1
            if self.monitor.observe(self.step, dt) and self.on_straggler:
                self.on_straggler(self.step)
            if metrics_cb:
                metrics_cb(self.step, metrics, dt)
            if self.step % self.ckpt_every == 0:
                self.ckpt.save(self.step, self.state, wait=False)
        # final stable checkpoint
        self.ckpt.save(self.step, self.state, wait=True)
        return self.state


def elastic_reshard(tree, new_mesh, spec_fn) -> Any:
    """Move a pytree to a new mesh.  ``spec_fn(path_leaf) -> PartitionSpec``
    (usually launch.sharding.make_sharding_fn(new_mesh, rules, axes_tree))."""
    from jax.sharding import NamedSharding

    flat, tdef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        spec = spec_fn(path, leaf)
        out.append(jax.device_put(leaf, NamedSharding(new_mesh, spec)))
    return tdef.unflatten(out)

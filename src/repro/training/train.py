"""Training step builder: CE loss, microbatch grad accumulation, clipping.

``make_train_step(cfg, opt)`` returns a pure ``train_step(state, batch)``
suitable for jit/lower — the dry-run lowers exactly this function.

Memory notes for the roofline: remat is applied per scanned layer (see
lm._run_segment); the loss materializes (B,S,V) logits once in f32 — a
chunked-loss variant (`loss_chunk` config) is available as a §Perf knob for
huge-vocab archs.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.models.config import ModelConfig

from .optimizer import Optimizer, OptState, clip_by_global_norm


class TrainState(NamedTuple):
    params: Any
    opt_state: OptState


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """logits (B,S,V) f32; targets (B,S) int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        logits, aux = forward(params, batch["inputs"], batch["positions"], cfg,
                              mode="train")
        ce = cross_entropy(logits.astype(jnp.float32), batch["targets"],
                           batch.get("mask"))
        loss = ce + cfg.router_aux_coef * aux
        return loss, {"ce": ce, "aux_loss": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: Optimizer, *,
                    grad_accum: int = 1, max_grad_norm: float = 1.0):
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        if grad_accum > 1:
            # microbatch over the leading batch axis
            def split(x):
                b = x.shape[0]
                return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_sum, l_sum = carry
                (loss, metrics), grads = grad_fn(state.params, mb)
                g_sum = jax.tree.map(jnp.add, g_sum, grads)
                return (g_sum, l_sum + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            (g_sum, loss_sum), metrics = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, g_sum)
            loss = loss_sum / grad_accum
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grad_fn(state.params, batch)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        new_params, new_opt = opt.update(state.params, state.opt_state, grads)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       step=new_opt.step)
        return TrainState(new_params, new_opt), metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, opt: Optimizer) -> TrainState:
    from repro.models import init_params
    params = init_params(key, cfg)
    return TrainState(params=params, opt_state=opt.init(params))

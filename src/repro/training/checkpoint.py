"""Checkpointing THROUGH the Cascade persistent store (§3.2/§3.6 applied).

A checkpoint is a put of every param/opt leaf into a persistent object pool:
versions are free (the log keeps every step's checkpoint with backpointer
chains), temporal restore is free ("give me the checkpoint as of T"), and
the write-back thread batches leaf flushes exactly like any other persisted
put.  This is the dog-fooding the paper argues for — the platform's own
storage layer is the training system's durability layer.

Leaf encoding: raw little-endian bytes + a JSON meta record (shape, dtype,
tree structure) under ``<prefix>/__meta__``.
"""
from __future__ import annotations

import json
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.log import PersistentLog


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(_path_str(p) for p in path)
        out.append((name, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, log_path: str, prefix: str = "/ckpt") -> None:
        self.log = PersistentLog(log_path)
        self.prefix = prefix

    def save(self, step: int, tree: Any, *, wait: bool = True) -> None:
        leaves = _flatten_with_paths(tree)
        meta = {"step": step, "leaves": []}
        for name, leaf in leaves:
            arr = np.asarray(leaf)
            meta["leaves"].append({"name": name, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
            self.log.append(f"{self.prefix}/{name}", arr.tobytes(), wait_stable=False)
        self.log.append(f"{self.prefix}/__meta__", json.dumps(meta).encode(),
                        wait_stable=wait)

    def latest_step(self) -> int | None:
        m = self.log.latest(f"{self.prefix}/__meta__")
        return json.loads(m.payload)["step"] if m else None

    def restore(self, like: Any, *, at_time_ns: int | None = None) -> tuple[int, Any]:
        """Restore into the structure of ``like``.  ``at_time_ns`` uses the
        temporal index for time-travel restore (stable-prefix semantics)."""
        get = (lambda k: self.log.get_time(k, at_time_ns)) if at_time_ns \
            else self.log.latest
        meta_obj = get(f"{self.prefix}/__meta__")
        if meta_obj is None:
            raise FileNotFoundError("no checkpoint found")
        meta = json.loads(meta_obj.payload)
        by_name = {l["name"]: l for l in meta["leaves"]}
        flat, tdef = jax.tree_util.tree_flatten(like)
        names = [n for n, _ in _flatten_with_paths(like)]
        out = []
        for name, leaf in zip(names, flat):
            rec = by_name[name]
            obj = get(f"{self.prefix}/{name}")
            arr = np.frombuffer(obj.payload, dtype=np.dtype(rec["dtype"]))
            arr = arr.reshape(rec["shape"])
            out.append(jnp.asarray(arr, dtype=jnp.result_type(leaf.dtype)))
        return meta["step"], tdef.unflatten(out)

    def close(self) -> None:
        self.log.close()

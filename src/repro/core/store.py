"""The sharded, versioned K/V object store (§3.2) spanning worker nodes.

A ``Worker`` models one Cascade node: an in-memory volatile store (seqlock
cells + version chains), per-pool persistent logs, an LRU for secondarily
accessed objects, and the fast-path machinery (dispatcher + upcall pool).

``CascadeStore`` is the service-wide store: it owns the pool registry and the
pool→shard maps, and implements the three put flavors:

- ``trigger_put`` — deliver the object to ONE member of the home shard (round
  robin for RR pools, emulating the paper's random P2P choice
  deterministically; key-hash for FIFO pools so same-key/session objects keep
  one node and stay ordered) and dispatch upcalls there.  Nothing is stored
  (§3.2).
- ``put`` on a volatile pool — atomic multicast: deliver to ALL members of
  the home shard in sequence order so replicas stay identical; upcalls are
  dispatched on the round-robin-selected processing member (§3.5).
- ``put`` on a persistent pool — additionally append to every member's
  persistent log and acknowledge once durable everywhere (the paper's Paxos
  acknowledges after all replicas persist).

``get`` goes to a uniformly-chosen member of the home shard (replicas hold
identical state) and reads through the seqlock without locks.  Versioned and
temporal gets are served by the version chains / persistent logs.
"""
from __future__ import annotations

import os
import random
import threading
from dataclasses import dataclass, field
from typing import Any, Iterable

from .dispatcher import Dispatcher, LambdaHandle, UpcallEvent, UpcallThreadPool
from .log import PersistentLog
from .objects import INVALID_VERSION, CascadeObject, monotonic_ns
from .placement import LRUCache, RoundRobin, ShardMap, build_shard_map
from .pools import DispatchPolicy, Persistence, PoolRegistry, PoolSpec
from .versioning import VersionChain


class Worker:
    """One Cascade node: storage + fast path."""

    def __init__(self, worker_id: int, *, n_upcall_threads: int = 2,
                 lru_bytes: int = 64 << 20, log_dir: str | None = None) -> None:
        self.worker_id = worker_id
        self.volatile: dict[str, VersionChain] = {}
        self._volatile_lock = threading.Lock()
        self.logs: dict[str, PersistentLog] = {}
        self._logs_lock = threading.Lock()
        self.lru = LRUCache(lru_bytes)
        self.upcalls = UpcallThreadPool(n_upcall_threads, name=f"w{worker_id}-upcall")
        self.dispatcher = Dispatcher(self.upcalls)
        self._log_dir = log_dir
        self.stored_objects = 0

    # -- storage -----------------------------------------------------------
    def _chain(self, key: str) -> VersionChain:
        chain = self.volatile.get(key)
        if chain is None:
            with self._volatile_lock:
                chain = self.volatile.setdefault(key, VersionChain())
        return chain

    def store(self, obj: CascadeObject, version: int) -> CascadeObject:
        stamped = self._chain(obj.key).append(obj, version)
        self.stored_objects += 1
        return stamped

    def persist_async(self, pool: PoolSpec, obj: CascadeObject):
        """Queue the record; returns (stamped obj, this record's stability
        event) so the caller can overlap replicas' disk I/O and then await
        exactly its own records."""
        log = self.logs.get(pool.path)
        if log is None:
            with self._logs_lock:  # two first-puts must not double-open the file
                log = self.logs.get(pool.path)
                if log is None:
                    base = self._log_dir or os.path.join(".cascade_logs",
                                                         f"w{self.worker_id}")
                    fname = pool.path.strip("/").replace("/", "_") + ".log"
                    log = self.logs[pool.path] = PersistentLog(
                        os.path.join(base, fname))
        payload = obj.payload
        if not isinstance(payload, (bytes, bytearray)):
            payload = _to_bytes(payload)
        return log.append_nowait(obj.key, bytes(payload),
                                 ts_ns=obj.timestamp_ns or None)

    def load_latest(self, key: str) -> CascadeObject | None:
        chain = self.volatile.get(key)
        return chain.latest() if chain else None

    def close(self) -> None:
        self.upcalls.stop()
        for log in self.logs.values():
            log.close()


def _to_bytes(payload: Any) -> bytes:
    import numpy as np

    arr = np.asarray(payload)
    return arr.tobytes()


@dataclass
class PutReceipt:
    obj: CascadeObject
    events: list[UpcallEvent] = field(default_factory=list)
    processing_worker: int = -1

    def wait(self, timeout: float | None = 10.0) -> list[Any]:
        out = []
        for ev in self.events:
            if not ev.completion.wait(timeout):
                raise TimeoutError(f"upcall {ev.handle.name} did not complete")
            if ev.error is not None:
                raise ev.error
            out.append(ev.result)
        return out


class CascadeStore:
    """Service-wide sharded store over a set of workers."""

    def __init__(self, workers: Iterable[Worker]) -> None:
        self.workers: dict[int, Worker] = {w.worker_id: w for w in workers}
        self.pools = PoolRegistry()
        self._shard_maps: dict[str, ShardMap] = {}
        self._sequencers: dict[tuple[str, int], threading.Lock] = {}
        self._versions: dict[tuple[str, int], int] = {}
        self._rr = RoundRobin()
        self._meta_lock = threading.Lock()
        # fault-injection seam (serving.faults.FaultInjector.store_hook):
        # called with the key at trigger_put ENTRY; a raising hook models a
        # transient send failure the CALLER retries (nothing was counted,
        # nothing dispatched).  None in production.
        self.fault_hook = None

    # -- pool management -----------------------------------------------------
    def create_pool(self, spec: PoolSpec, worker_ids: list[int] | None = None) -> PoolSpec:
        ids = worker_ids if worker_ids is not None else sorted(self.workers)
        self.pools.create(spec)
        with self._meta_lock:  # remove_pool deletes from _shard_maps under it
            self._shard_maps[spec.path] = build_shard_map(
                spec.path, ids, spec.replication)
        return spec

    def _route(self, key: str) -> tuple[PoolSpec, tuple[int, ...]]:
        spec = self.pools.lookup(key)
        if spec is None:
            raise KeyError(f"no pool owns key {key!r}")
        members = self._shard_maps[spec.path].members(spec, key)
        return spec, members

    def remove_pool(self, path: str) -> None:
        """Tear a pool down: registry entry, shard map, shard sequencers and
        version counters, every member's stored objects under the pool's
        prefix, and any open persistent-log handles (the on-disk log FILE is
        left in place — persistent pools are durable by definition, and a
        re-created pool resumes its log the way a restarted node would).
        Lambdas registered on the pool's prefix must be unregistered by
        their owner first (``unregister_lambda``) — the store cannot know
        which handles belong to the departing service."""
        spec = self.pools.remove(path)
        with self._meta_lock:
            self._shard_maps.pop(path, None)
            for k in [k for k in self._sequencers if k[0] == path]:
                del self._sequencers[k]
            for k in [k for k in self._versions if k[0] == path]:
                del self._versions[k]
        for w in self.workers.values():
            with w._volatile_lock:
                for key in [k for k in w.volatile if spec.owns(k)]:
                    del w.volatile[key]
            with w._logs_lock:
                log = w.logs.pop(path, None)
            if log is not None:
                log.close()

    def register_lambda(self, handle: LambdaHandle, worker_ids: list[int] | None = None) -> None:
        """Bind a lambda to a path prefix on the given (default: all owning)
        workers — in the paper the DFG determines which shard hosts each
        lambda; here the caller passes the stage's shard members."""
        targets = worker_ids if worker_ids is not None else list(self.workers)
        for wid in targets:
            self.workers[wid].dispatcher.register(handle)

    def unregister_lambda(self, handle: LambdaHandle,
                          worker_ids: list[int] | None = None) -> None:
        """Unbind a lambda from its prefix (deployment teardown): later puts
        to the prefix no longer upcall it.  Events already enqueued still
        run — teardown should drain first."""
        targets = worker_ids if worker_ids is not None else list(self.workers)
        for wid in targets:
            self.workers[wid].dispatcher.unregister(handle)

    # -- puts ------------------------------------------------------------------
    def _next_version(self, pool: PoolSpec, shard: int) -> tuple[int, threading.Lock]:
        k = (pool.path, shard)
        with self._meta_lock:
            lock = self._sequencers.setdefault(k, threading.Lock())
        return k, lock

    def trigger_put(self, key: str, payload: Any, *, principal: str = "") -> PutReceipt:
        """P2P send to one member + upcall; nothing stored, nothing replicated.

        Member selection follows the pool's dispatch policy, mirroring the
        dispatcher's queue selection (§3.3) one level up: ROUND_ROBIN spreads
        trigger-puts across the home shard, FIFO picks the member by the
        pool's key hash so same-key (or, with ``affinity_shard_hash``,
        same-session) objects always land on the same node, in order.
        """
        if self.fault_hook is not None:
            self.fault_hook(key)
        spec, members = self._route(key)
        if not spec.can_write(principal):
            raise PermissionError(f"{principal!r} cannot write {spec.path}")
        if spec.dispatch is DispatchPolicy.FIFO:
            # The low bits of the hash already chose the home shard
            # (h % n_shards); pick the member from the HIGH bits so the two
            # moduli are decorrelated — otherwise gcd(n_shards, replication)
            # > 1 leaves whole member subsets permanently unreachable.
            h = spec.shard_hash(key)
            n_shards = len(self._shard_maps[spec.path].shards)
            target = members[(h // max(1, n_shards)) % len(members)]
        else:
            target = self._rr.pick(("trig", spec.path), members)
        obj = CascadeObject(key=key, payload=payload, version=INVALID_VERSION,
                            timestamp_ns=monotonic_ns())
        events = self.workers[target].dispatcher.dispatch(obj)
        return PutReceipt(obj=obj, events=events, processing_worker=target)

    def put(self, key: str, payload: Any, *, principal: str = "") -> PutReceipt:
        """Volatile/persistent put: replicate to the full home shard."""
        spec, members = self._route(key)
        if not spec.can_write(principal):
            raise PermissionError(f"{principal!r} cannot write {spec.path}")
        if spec.persistence is Persistence.TRANSIENT:
            return self.trigger_put(key, payload, principal=principal)
        shard_idx = self._shard_maps[spec.path].home_shard(spec, key)
        vkey, seq_lock = self._next_version(spec, shard_idx)
        obj = CascadeObject(key=key, payload=payload, timestamp_ns=monotonic_ns())
        with seq_lock:  # atomic multicast: identical order at every replica
            version = self._versions.get(vkey, -1) + 1
            # lint: guarded-by(seq_lock) per-(pool,shard) sequencer, not _meta_lock, serializes writers of this vkey
            self._versions[vkey] = version
            stamped = None
            for wid in members:
                stamped = self.workers[wid].store(obj, version)
        if spec.persistence is Persistence.PERSISTENT:
            # All replicas persist before the put is acknowledged (§3.2).
            # Appends are issued without waiting so the members' write-back
            # threads overlap their disk I/O, then stability is awaited for
            # THIS put's record on EVERY member's log — not just the last
            # one's, and not the whole queue (concurrent puts stay
            # independent).
            pending = [self.workers[wid].persist_async(spec, obj)[1]
                       for wid in members]
            for done in pending:
                if not done.wait(10.0):
                    raise TimeoutError(
                        "persistent put did not stabilize on all replicas")
        # Round-robin processing member (§3.5); replicas all HOLD the data,
        # exactly one dispatches the upcall for this object.
        proc = self._rr.pick(("proc", spec.path, shard_idx), members)
        events = self.workers[proc].dispatcher.dispatch(stamped)
        return PutReceipt(obj=stamped, events=events, processing_worker=proc)

    # -- gets ------------------------------------------------------------------
    def get(self, key: str, *, principal: str = "") -> CascadeObject | None:
        """Linearizable read from a random home-shard member (states are
        identical, so any member may answer)."""
        spec, members = self._route(key)
        if not spec.can_read(principal):
            raise PermissionError(f"{principal!r} cannot read {spec.path}")
        w = self.workers[random.choice(members)]
        obj = w.load_latest(key)
        if obj is not None:
            w.lru.put(key, obj, obj.nbytes())
        return obj

    def get_version(self, key: str, version: int) -> CascadeObject | None:
        _, members = self._route(key)
        chain = self.workers[random.choice(members)].volatile.get(key)
        return chain.at_version(version) if chain else None

    def get_time(self, key: str, ts_ns: int) -> CascadeObject | None:
        """Temporal get (persistent pools): resolved via the member's log so
        the stable-prefix rule applies."""
        spec, members = self._route(key)
        w = self.workers[random.choice(members)]
        if spec.persistence is Persistence.PERSISTENT and spec.path in w.logs:
            return w.logs[spec.path].get_time(key, ts_ns)
        chain = w.volatile.get(key)
        return chain.at_time(ts_ns) if chain else None

    def time_range(self, key: str, lo_ns: int, hi_ns: int) -> list[CascadeObject]:
        spec, members = self._route(key)
        w = self.workers[random.choice(members)]
        if spec.persistence is Persistence.PERSISTENT and spec.path in w.logs:
            return w.logs[spec.path].time_range(key, lo_ns, hi_ns)
        chain = w.volatile.get(key)
        return chain.time_range(lo_ns, hi_ns) if chain else []

    def close(self) -> None:
        for w in self.workers.values():
            w.close()


class SpillPool:
    """Host-side parking lot for preempted KV (ROADMAP item 2's missing
    piece: before this, spilled KV could only re-home IMMEDIATELY on a
    failover sibling — ``PagedCacheManager.spill_device`` had nowhere to
    park).

    Entries are opaque to the pool (the engine parks
    ``kvcache.SpilledKV`` host copies pulled through its one sync site);
    capacity is accounted in KV BLOCKS because that is the unit the device
    pool frees and the unit a resume re-acquires.  When ``store`` is given,
    each parked entry is also published as a Cascade object under
    ``prefix/<request_id>`` on the store's volatile pool — so a sibling
    replica (same node, shared store) can unpark a session that was
    preempted on a replica that later died, and observers can watch spill
    traffic like any other pool.  The store has no per-key delete, so
    unpark/discard/evict write a ``None`` TOMBSTONE version; readers of the
    pool must treat a ``None`` payload as absent (``unpark`` does).

    Bounded: parking beyond ``capacity_blocks`` evicts the OLDEST parked
    entries first (their sessions fall back to prompt replay — a
    correctness-preserving downgrade, exactly the failover fallback), and a
    single entry larger than the whole pool is refused (``park`` → False,
    caller replays).  Driver-thread-only by design, like the allocator it
    shadows: every park/unpark happens inside an engine tick on the
    deployment's driver, so there is no lock to take.
    """

    def __init__(self, *, capacity_blocks: int = 256,
                 store: "CascadeStore | None" = None,
                 prefix: str = "/spill") -> None:
        self.capacity_blocks = capacity_blocks
        self.store = store
        self.prefix = prefix.rstrip("/")
        self._entries: dict[str, tuple[Any, int]] = {}  # rid -> (entry, blocks)
        self.blocks = 0          # gauge: blocks currently parked
        self.parked = 0          # counters, cumulative
        self.unparked = 0
        self.evicted = 0

    def _publish(self, request_id: str, entry: Any) -> None:
        if self.store is not None:
            self.store.put(f"{self.prefix}/{request_id}", entry)

    def park(self, request_id: str, entry: Any, n_blocks: int) -> bool:
        """Park a spilled session's KV; False when it can never fit (the
        caller falls back to prompt replay).  Evicts oldest-first to make
        room — evicted sessions also degrade to replay on resume."""
        if n_blocks > self.capacity_blocks:
            return False
        self.discard(request_id)  # re-park replaces (failover double-spill)
        while self.blocks + n_blocks > self.capacity_blocks:
            old_rid, (_, old_blocks) = next(iter(self._entries.items()))
            del self._entries[old_rid]
            self.blocks -= old_blocks
            self.evicted += 1
            self._publish(old_rid, None)
        self._entries[request_id] = (entry, n_blocks)
        self.blocks += n_blocks
        self.parked += 1
        self._publish(request_id, entry)
        return True

    def unpark(self, request_id: str) -> Any | None:
        """Take a parked entry out (resume path); None when absent/evicted.
        Falls back to the store copy when another replica parked it (this
        pool instance never saw the park but the object is on the shared
        pool) — tombstones read as absent."""
        got = self._entries.pop(request_id, None)
        if got is not None:
            entry, n_blocks = got
            self.blocks -= n_blocks
            self.unparked += 1
            self._publish(request_id, None)
            return entry
        if self.store is not None:
            obj = self.store.get(f"{self.prefix}/{request_id}")
            if obj is not None and obj.payload is not None:
                self.unparked += 1
                self._publish(request_id, None)
                return obj.payload
        return None

    def discard(self, request_id: str) -> None:
        """Drop a parked entry without resuming (request completed via
        replay, expired, or failed)."""
        got = self._entries.pop(request_id, None)
        if got is not None:
            self.blocks -= got[1]
            self._publish(request_id, None)

    def has(self, request_id: str) -> bool:
        return request_id in self._entries

    def stats(self) -> dict[str, int]:
        return {"spill_pool_blocks": self.blocks,
                "spill_pool_parked": self.parked,
                "spill_pool_unparked": self.unparked,
                "spill_pool_evicted": self.evicted}

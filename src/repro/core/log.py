"""Persistent versioned log (§3.2 persistent pools, §3.6 optimizations).

Faithful to the paper's three accelerations:

1. **Memory-mapped log files** — reads go through an ``mmap`` view of the
   log file, giving a simplified read path (no seek/read syscalls per get).
2. **Asynchronous write-back** — a write-back thread flushes opportunistically
   *batched* updates: while a put is only acknowledged as *stable* once its
   bytes are durable, many queued records are written with a single
   ``write``+``flush`` pair, exactly the paper's ad-hoc mini-batching.
3. **Backpointer chains** — each record stores the file offset of the
   previous record *of the same key*, so version-range queries walk the chain
   backwards without scanning; a temporally-sorted secondary index maps time
   windows to version windows.

Stable-prefix rule: temporal reads whose window extends past the stability
frontier ("into the future") block until the frontier covers them, so a
window can never silently omit a version (§3.6).

Record layout (little-endian):
    u32 magic | u64 version | u64 prev_offset | i64 timestamp_ns
    u32 keylen | u32 payloadlen | key bytes | payload bytes
"""
from __future__ import annotations

import mmap
import os
import struct
import threading
from dataclasses import dataclass
from typing import Iterator

from .objects import INVALID_VERSION, CascadeObject, monotonic_ns
from .versioning import VersionChain

_MAGIC = 0xCA5CADE0
_HEADER = struct.Struct("<IQQqII")
_NO_PREV = 0xFFFFFFFFFFFFFFFF


@dataclass
class _PendingRecord:
    key: str
    payload: bytes
    version: int
    timestamp_ns: int
    done: threading.Event


class PersistentLog:
    """One shard member's persisted log for a persistent pool."""

    def __init__(self, path: str, flush_interval_s: float = 0.0005) -> None:
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._file = open(path, "ab+")
        self._file.seek(0, os.SEEK_END)
        self._tail = self._file.tell()          # next write offset
        self._stable_frontier_ns = monotonic_ns()
        self._stable_version = INVALID_VERSION
        # In-memory metadata cached for all active objects (§3.6): per-key
        # chain of (version, ts, file offset) plus the latest payload.
        self._chains: dict[str, VersionChain] = {}
        self._offsets: dict[tuple[str, int], int] = {}
        self._last_offset: dict[str, int] = {}
        self._next_version = 0
        self._meta_lock = threading.Lock()
        # Write-back machinery.
        self._queue: list[_PendingRecord] = []
        self._queue_lock = threading.Lock()
        self._queue_cv = threading.Condition(self._queue_lock)
        self._flush_interval_s = flush_interval_s
        self._pending = 0                       # queued or mid-flush records
        self._pending_zero = threading.Event()
        self._pending_zero.set()
        self._closed = False
        self._mmap: mmap.mmap | None = None
        self._mmap_size = 0
        self.flush_batches = 0
        self.flushed_records = 0
        self._writer = threading.Thread(target=self._write_back_loop, daemon=True)
        self._writer.start()
        if self._tail:
            self._recover()

    # ------------------------------------------------------------- put path
    def append(self, key: str, payload: bytes, *, wait_stable: bool = True,
               ts_ns: int | None = None) -> CascadeObject:
        """Log a new version of ``key``.  Returns the stamped object.

        In-memory state is updated atomically first (Derecho-style: the
        in-memory copy and backpointer metadata update need no disk I/O),
        then the record is queued for the write-back thread; if
        ``wait_stable`` the call returns only after the bytes are durable —
        this is the paper's persistent-put acknowledgement point.
        """
        obj, done = self.append_nowait(key, payload, ts_ns=ts_ns)
        if wait_stable:
            done.wait()
        return obj

    def append_nowait(self, key: str, payload: bytes, *, ts_ns: int | None = None
                      ) -> tuple[CascadeObject, threading.Event]:
        """Queue a record and return (stamped object, its OWN stability
        event), so a caller can await this record's durability without
        waiting for the whole queue to drain (other writers' records).

        Version stamping and enqueueing happen under ONE critical section:
        otherwise a preempted writer could enqueue a higher version first,
        writing the log out of version order and regressing the stability
        frontier.  (The write-back thread never holds _queue_cv while taking
        _meta_lock, so this nesting cannot deadlock.)
        """
        with self._meta_lock:
            version = self._next_version
            self._next_version += 1
            chain = self._chains.get(key)
            if chain is None:
                chain = self._chains[key] = VersionChain()
            obj = chain.append(CascadeObject(key=key, payload=payload), version,
                               ts_ns=ts_ns)
            rec = _PendingRecord(key, payload, version, obj.timestamp_ns,
                                 threading.Event())
            with self._queue_cv:
                self._queue.append(rec)
                self._pending += 1
                self._pending_zero.clear()
                self._queue_cv.notify()
        return obj, rec.done

    def _write_back_loop(self) -> None:
        while True:
            with self._queue_cv:
                while not self._queue and not self._closed:
                    self._queue_cv.wait(timeout=self._flush_interval_s)
                batch, self._queue = self._queue, []
                if self._closed and not batch:
                    return
            if not batch:
                continue
            # Opportunistic batching: one write+flush for the whole backlog.
            buf = bytearray()
            offsets: list[int] = []
            base = self._tail
            with self._meta_lock:  # _last_offset is shared with get()/_recover
                for rec in batch:
                    off = base + len(buf)
                    offsets.append(off)
                    prev = self._last_offset.get(rec.key, _NO_PREV)
                    kb = rec.key.encode()
                    buf += _HEADER.pack(_MAGIC, rec.version, prev,
                                        rec.timestamp_ns,
                                        len(kb), len(rec.payload))
                    buf += kb
                    buf += rec.payload
                    self._last_offset[rec.key] = off
            self._file.write(buf)
            self._file.flush()
            os.fsync(self._file.fileno())
            self._tail = base + len(buf)
            with self._meta_lock:
                for rec, off in zip(batch, offsets):
                    self._offsets[(rec.key, rec.version)] = off
                self._stable_version = batch[-1].version
                self._stable_frontier_ns = batch[-1].timestamp_ns
            self.flush_batches += 1
            self.flushed_records += len(batch)
            for rec in batch:
                rec.done.set()
            with self._queue_cv:
                self._pending -= len(batch)
                if self._pending == 0:
                    self._pending_zero.set()

    # ------------------------------------------------------------- get path
    def _view(self) -> mmap.mmap:
        """(Re-)mmap the log file if it has grown — the read path (§3.6)."""
        size = self._tail
        if self._mmap is None or self._mmap_size < size:
            if self._mmap is not None:
                self._mmap.close()
            self._mmap = mmap.mmap(self._file.fileno(), size, access=mmap.ACCESS_READ)
            self._mmap_size = size
        return self._mmap

    def _read_at(self, offset: int) -> tuple[CascadeObject, int]:
        m = self._view()
        magic, version, prev, ts, klen, plen = _HEADER.unpack_from(m, offset)
        if magic != _MAGIC:
            raise IOError(f"corrupt log record at {offset}")
        ko = offset + _HEADER.size
        key = bytes(m[ko : ko + klen]).decode()
        payload = bytes(m[ko + klen : ko + klen + plen])
        prev_off = -1 if prev == _NO_PREV else prev
        return (
            CascadeObject(key=key, payload=payload, version=version,
                          timestamp_ns=ts, previous_version=prev_off),
            prev_off,
        )

    def latest(self, key: str) -> CascadeObject | None:
        chain = self._chains.get(key)
        return chain.latest() if chain else None

    def get_version(self, key: str, version: int) -> CascadeObject | None:
        chain = self._chains.get(key)
        return chain.at_version(version) if chain else None

    def get_time(self, key: str, ts_ns: int, *, timeout_s: float = 5.0) -> CascadeObject | None:
        """Temporal get.  Blocks while ts_ns is past the stability frontier."""
        self.wait_stable(ts_ns, timeout_s=timeout_s)
        chain = self._chains.get(key)
        return chain.at_time(ts_ns) if chain else None

    def flush(self, timeout_s: float = 5.0) -> None:
        """Block until every queued record is durable."""
        if not self._pending_zero.wait(timeout_s):
            raise TimeoutError("write-back did not drain")

    def version_range_from_disk(self, key: str, lo: int, hi: int) -> list[CascadeObject]:
        """Range query answered from the *log file* by walking backpointers."""
        self.flush()
        off = self._last_offset.get(key)
        if off is None:
            return []
        # Skip forward-of-range by jumping down the chain.
        out: list[CascadeObject] = []
        cur = off
        while cur != -1 and cur != _NO_PREV:
            obj, prev = self._read_at(cur)
            if obj.version < lo:
                break
            if obj.version <= hi:
                out.append(obj)
            cur = prev
        out.reverse()
        return out

    def time_range(self, key: str, lo_ns: int, hi_ns: int, *, timeout_s: float = 5.0) -> list[CascadeObject]:
        """Map the time window to a version window, then range-query (§3.6)."""
        self.wait_stable(hi_ns, timeout_s=timeout_s)
        chain = self._chains.get(key)
        if chain is None:
            return []
        objs = chain.time_range(lo_ns, hi_ns)
        if not objs:
            return []
        return self.version_range_from_disk(key, objs[0].version, objs[-1].version)

    def wait_stable(self, ts_ns: int, *, timeout_s: float = 5.0) -> None:
        """Block until the stability frontier passes ``ts_ns`` (§3.6)."""
        deadline = monotonic_ns() + int(timeout_s * 1e9)
        while self._stable_frontier_ns < ts_ns:
            with self._queue_lock:
                backlog = bool(self._queue)
            if not backlog and monotonic_ns() >= ts_ns:
                # Nothing pending and wall clock passed the window: frontier
                # advances to 'now' (no version can be stamped before it).
                with self._meta_lock:
                    self._stable_frontier_ns = max(self._stable_frontier_ns, ts_ns)
                return
            if monotonic_ns() > deadline:
                raise TimeoutError("stability frontier did not advance")
            threading.Event().wait(0.0002)

    # ------------------------------------------------------------- recovery
    def _recover(self) -> None:
        """Rebuild in-memory metadata by a single forward scan (restart)."""
        off = 0
        records: list[tuple[int, CascadeObject]] = []
        while off < self._tail:
            obj, _ = self._read_at(off)
            records.append((off, obj))
            off += _HEADER.size + len(obj.key.encode()) + len(obj.payload)
        with self._meta_lock:
            for off, obj in records:
                chain = self._chains.get(obj.key)
                if chain is None:
                    chain = self._chains[obj.key] = VersionChain()
                chain.append(CascadeObject(key=obj.key, payload=obj.payload), obj.version)
                self._offsets[(obj.key, obj.version)] = off
                self._last_offset[obj.key] = off
                self._next_version = max(self._next_version, obj.version + 1)
                self._stable_version = obj.version

    def keys(self) -> Iterator[str]:
        return iter(list(self._chains.keys()))

    def close(self) -> None:
        with self._queue_cv:
            self._closed = True
            self._queue_cv.notify()
        self._writer.join(timeout=5)
        if self._mmap is not None:
            self._mmap.close()
            self._mmap = None
        self._file.close()

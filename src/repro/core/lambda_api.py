"""The lambda API (§3.1): how hosted application logic talks to Cascade.

A lambda is a callable ``fn(ctx, obj) -> result``.  The wrapper a developer
writes has two responsibilities (paper): provide an upcallable function, and
use the SDK to read inputs / write outputs.  ``CascadeContext`` is that SDK:
get/put/trigger_put against the service store plus ``emit`` which forwards a
result along the DFG edge(s) — the idiom every staged application uses.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from .dfg import DFG, Vertex
from .dispatcher import LambdaHandle
from .objects import CascadeObject
from .pools import DispatchPolicy
from .store import CascadeStore, PutReceipt

LambdaFn = Callable[["CascadeContext", CascadeObject], Any]


@dataclass
class CascadeContext:
    store: CascadeStore
    dfg: DFG | None = None
    vertex: Vertex | None = None
    worker_id: int = -1

    # -- SDK surface ---------------------------------------------------------
    def get(self, key: str) -> CascadeObject | None:
        return self.store.get(key)

    def get_time(self, key: str, ts_ns: int) -> CascadeObject | None:
        return self.store.get_time(key, ts_ns)

    def put(self, key: str, payload: Any) -> PutReceipt:
        return self.store.put(key, payload)

    def trigger_put(self, key: str, payload: Any) -> PutReceipt:
        return self.store.trigger_put(key, payload)

    def emit(self, suffix: str, payload: Any, *, trigger: bool = False) -> list[PutReceipt]:
        """Forward a result to every successor stage of this vertex."""
        if self.dfg is None or self.vertex is None:
            raise RuntimeError("emit() requires a DFG-bound lambda")
        receipts = []
        for nxt in self.dfg.successors(self.vertex.name):
            key = f"{nxt.prefix}/{suffix}".replace("//", "/")
            if trigger:
                receipts.append(self.store.trigger_put(key, payload))
            else:
                receipts.append(self.store.put(key, payload))
        return receipts


def wrap_lambda(name: str, fn: LambdaFn, ctx: CascadeContext, vertex: Vertex) -> LambdaHandle:
    """Produce the upcallable the dispatcher invokes (thin wrapper, §3.1)."""
    bound_ctx = CascadeContext(store=ctx.store, dfg=ctx.dfg, vertex=vertex,
                               worker_id=ctx.worker_id)

    def upcall(obj: CascadeObject, _event) -> Any:
        return fn(bound_ctx, obj)

    return LambdaHandle(name=name, prefix=vertex.prefix, fn=upcall,
                        dispatch=vertex.dispatch)

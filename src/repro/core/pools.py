"""Object pools (§3.2): path prefix + persistence + replication + sharding.

Objects are managed in pools identified by a path prefix.  Each pool carries
an access-control policy, a replication factor, persistence properties, and a
sharding policy.  Cascade offers three persistence levels:

- ``TRANSIENT``  — trigger-put targets: the object initiates a lambda and
  vanishes (never stored, never replicated);
- ``VOLATILE``   — the latest version of each key is retained in memory on
  every member of the key's home shard;
- ``PERSISTENT`` — every version is retained in memory metadata *and* logged
  to persistent storage with backpointer chains + a temporal index.
"""
from __future__ import annotations

import enum
import zlib
from dataclasses import dataclass, field
from typing import Callable

from .trie import split_path


class Persistence(enum.Enum):
    TRANSIENT = "transient"
    VOLATILE = "volatile"
    PERSISTENT = "persistent"


class DispatchPolicy(enum.Enum):
    """Upcall dispatch (§3.3): round-robin load balancing, or FIFO-by-key
    (objects sharing a key always run on the same upcall thread)."""

    ROUND_ROBIN = "rr"
    FIFO = "fifo"


def default_shard_hash(key: str) -> int:
    """Deterministic key→shard hash (§3.5). crc32 is stable across runs —
    required so that home shards survive restarts (unlike ``hash()``)."""
    return zlib.crc32(key.encode())


def affinity_shard_hash(key: str, depth: int = 2) -> int:
    """Customized grouping hash (§3.2: 'a hashing scheme that can be
    customized to group objects so that related objects will always be
    hosted on the same nodes').  Hashes only the first ``depth`` path
    components below the pool, so e.g. all objects of one camera/session
    share a home shard."""
    comps = split_path(key)
    return zlib.crc32("/".join(comps[:depth]).encode())


@dataclass(frozen=True)
class PoolSpec:
    path: str                               # pool path prefix, e.g. "/sf/detect_animal"
    persistence: Persistence = Persistence.VOLATILE
    replication: int = 1                    # shard size (number of members)
    shard_hash: Callable[[str], int] = default_shard_hash
    dispatch: DispatchPolicy = DispatchPolicy.ROUND_ROBIN
    # device-store placement (used by devstore): logical axes for payload
    # sharding; None = replicate within the home slice.
    device_axes: tuple[str | None, ...] | None = None
    readers: frozenset[str] = frozenset()   # access-control policy (empty = open)
    writers: frozenset[str] = frozenset()

    def __post_init__(self) -> None:
        if not self.path.startswith("/"):
            raise ValueError(f"pool path must be absolute, got {self.path!r}")
        if self.replication < 1:
            raise ValueError("replication factor must be >= 1")

    def owns(self, key: str) -> bool:
        pc = split_path(self.path)
        kc = split_path(key)
        return kc[: len(pc)] == pc

    def can_read(self, principal: str) -> bool:
        return not self.readers or principal in self.readers

    def can_write(self, principal: str) -> bool:
        return not self.writers or principal in self.writers


@dataclass
class PoolRegistry:
    """Pool lookup by longest path-prefix.  Although pool paths permit a
    hierarchical organization, any given object resides in a single pool —
    the deepest registered prefix wins (§3.2)."""

    _pools: dict[str, PoolSpec] = field(default_factory=dict)

    def create(self, spec: PoolSpec) -> PoolSpec:
        if spec.path in self._pools:
            raise ValueError(f"pool {spec.path} already exists")
        self._pools[spec.path] = spec
        return spec

    def remove(self, path: str) -> PoolSpec:
        """Drop a pool from the registry (deployment teardown).  Returns the
        removed spec so callers can clean up per-pool state (shard maps,
        sequencers, stored keys) keyed off it."""
        spec = self._pools.pop(path, None)
        if spec is None:
            raise KeyError(f"no pool registered at {path!r}")
        return spec

    def __contains__(self, path: str) -> bool:
        return path in self._pools

    def lookup(self, key: str) -> PoolSpec | None:
        """Deepest pool whose path is a prefix of ``key``."""
        comps = split_path(key)
        for depth in range(len(comps), 0, -1):
            p = "/" + "/".join(comps[:depth])
            spec = self._pools.get(p)
            if spec is not None:
                return spec
        return None

    def get(self, path: str) -> PoolSpec:
        return self._pools[path]

    def __iter__(self):
        return iter(self._pools.values())

    def __len__(self) -> int:
        return len(self._pools)

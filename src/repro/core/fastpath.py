"""The device fast path (§3.3–3.4), re-expressed for XLA/TPU.

Cascade's fast path makes the *handoff between pipeline stages* cost almost
nothing compared to the stage compute.  On an RDMA cluster that means DLL
upcalls in the server address space and zero-copy buffers; on TPU the three
rungs of the paper's latency/isolation ladder map to:

1. **Fused stages** ("DLL lambda in the Cascade address space"): consecutive
   collocated stages are compiled into ONE XLA program with donated input
   buffers — the handoff disappears entirely; no host round trip, no copy.
2. **Jit-chained stages** ("containerized lambda + shared-memory IPC"): each
   stage is its own compiled program, but activations stay **on device**
   between stages; the host only sequences dispatches (references, not data).
3. **Cross-slice handoff** ("trigger put over RDMA to the next-hop node"):
   when stages live on disjoint mesh slices, the activation is moved
   device-to-device by resharding (``jax.device_put`` with the destination
   ``NamedSharding`` — ICI collective-permute), never via host memory.

The anti-pattern — the broker path in ``baseline.py`` — fetches the tensor
to the host, serializes it, queues the bytes, deserializes, and re-uploads at
every hop; that is the Kafka/Flink/EventHub handoff the paper measures
against, and it is the baseline our benchmarks compare with.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

StageFn = Callable[..., Any]


@dataclass(frozen=True)
class Stage:
    """One DFG vertex's compute, with optional placement."""

    name: str
    fn: StageFn
    out_sharding: jax.sharding.Sharding | None = None  # stage's home slice


def fuse_stages(stages: Sequence[Stage], *, donate: bool = True) -> Callable[..., Any]:
    """Rung 1: one jitted program for the whole chain; inputs donated so XLA
    may overwrite them in place (the zero-copy discipline of §3.4)."""

    def chained(x, *extra):
        for st in stages:
            x = st.fn(x, *extra)
        return x

    donate_argnums = (0,) if donate else ()
    return jax.jit(chained, donate_argnums=donate_argnums)


def chain_stages(stages: Sequence[Stage]) -> Callable[..., Any]:
    """Rung 2: per-stage jit; activations remain device-resident between
    stages and move by resharding when a stage declares a different slice."""

    jitted = [
        jax.jit(st.fn, out_shardings=st.out_sharding, donate_argnums=(0,))
        if st.out_sharding is not None
        else jax.jit(st.fn, donate_argnums=(0,))
        for st in stages
    ]

    def run(x, *extra):
        for st, f in zip(stages, jitted):
            x = f(x, *extra)
        return x

    return run


def handoff(x: jax.Array, dst: jax.sharding.Sharding) -> jax.Array:
    """Rung 3: explicit cross-slice move (≙ RDMA trigger put to next hop)."""
    return jax.device_put(x, dst)


def broker_hop(x: jax.Array) -> jax.Array:
    """The measured anti-pattern: host round-trip + serialize + copy.

    Mirrors what a Kafka/gRPC handoff does to a tensor: device→host DMA,
    a marshalling copy into a byte buffer, an unmarshalling copy out of it,
    and host→device DMA.  Used by baselines/benchmarks only.
    """
    import numpy as np

    host = np.asarray(x)              # device -> host
    wire = host.tobytes()             # marshalling copy (Kryo-style)
    back = np.frombuffer(wire, dtype=host.dtype).reshape(host.shape).copy()
    return jnp.asarray(back)          # host -> device


# ---------------------------------------------------------------------------
# Collocation-aware pipeline builder: the piece the serving engine uses.
# ---------------------------------------------------------------------------

@dataclass
class FastPathPipeline:
    """Compile a DFG chain into the fastest legal execution plan.

    Adjacent stages that share a placement (same sharding or both None) are
    fused into a single program; placement changes insert a device-to-device
    handoff.  This is exactly the paper's scheduling rule: run lambdas where
    their data lives, and only move the (small) activation objects.
    """

    stages: Sequence[Stage]

    def build(self, *, donate_input: bool = False) -> Callable[..., Any]:
        """Compile the plan.  Zero-copy donation discipline (§3.4, rung 1):
        every group after the first consumes an intermediate activation that
        only the pipeline references, so its input buffer is always donated
        and XLA may overwrite it in place.  The FIRST group consumes the
        caller's own array, which must not be invalidated behind the caller's
        back — it is donated only when the caller opts in via
        ``donate_input=True``.
        """
        groups: list[list[Stage]] = []
        for st in self.stages:
            if groups and _same_place(groups[-1][-1], st):
                groups[-1].append(st)
            else:
                groups.append([st])
        compiled: list[tuple[Callable[..., Any], jax.sharding.Sharding | None]] = []
        for gi, g in enumerate(groups):
            fn = fuse_stages(g, donate=donate_input if gi == 0 else True)
            compiled.append((fn, g[0].out_sharding))

        def run(x, *extra):
            for fn, place in compiled:
                if place is not None and getattr(x, "sharding", None) != place:
                    x = handoff(x, place)
                x = fn(x, *extra)
            return x

        return run


def _same_place(a: Stage, b: Stage) -> bool:
    return a.out_sharding == b.out_sharding

"""Cascade core: the paper's contribution as a composable library.

Layers (paper §3): pools + sharded versioned K/V store, persistent logs with
backpointer chains and temporal indexing, the trie/dispatcher/upcall fast
path, DFG + lambda API, and the device-side fast path (stage fusion and
zero-copy handoffs) for XLA/TPU.
"""
from .baseline import Broker, BrokerPipeline
from .devstore import DeviceStore
from .dfg import DFG, Vertex
from .dispatcher import Dispatcher, LambdaHandle, UpcallEvent, UpcallThreadPool
from .fastpath import FastPathPipeline, Stage, broker_hop, chain_stages, fuse_stages, handoff
from .lambda_api import CascadeContext, wrap_lambda
from .log import PersistentLog
from .objects import INVALID_VERSION, CascadeObject
from .placement import LRUCache, RoundRobin, ShardMap, build_shard_map
from .pools import DispatchPolicy, Persistence, PoolRegistry, PoolSpec, affinity_shard_hash, default_shard_hash
from .service import CascadeService
from .store import CascadeStore, PutReceipt, Worker
from .trie import PathTrie
from .versioning import SeqlockCell, VersionChain

__all__ = [
    "Broker", "BrokerPipeline", "DeviceStore", "DFG", "Vertex", "Dispatcher",
    "LambdaHandle", "UpcallEvent", "UpcallThreadPool", "FastPathPipeline",
    "Stage", "broker_hop", "chain_stages", "fuse_stages", "handoff",
    "CascadeContext", "wrap_lambda", "PersistentLog", "INVALID_VERSION",
    "CascadeObject", "LRUCache", "RoundRobin", "ShardMap", "build_shard_map",
    "DispatchPolicy", "Persistence", "PoolRegistry", "PoolSpec",
    "affinity_shard_hash", "default_shard_hash", "CascadeService",
    "CascadeStore", "PutReceipt", "Worker", "PathTrie", "SeqlockCell",
    "VersionChain",
]

"""Lock-free versioned object cells and per-key version chains (§3.2, §3.6).

The paper avoids a get/put lock with two atomic version numbers per object:

    put:  v_a += 1 ; write data ; v_b = v_a
    get:  read v_b ; read data ; re-read v_a ; retry if v_a != v_b

CPython guarantees that attribute loads/stores of ints are atomic w.r.t. the
GIL, so the seqlock below is a faithful functional port: a get that races a
put observes ``v_a != v_b`` and retries, and torn payload reads are detected
exactly as in the paper.  ``VersionChain`` keeps the backpointer-linked
version history used by the persistent pools' range/temporal queries.
"""
from __future__ import annotations

import bisect
import threading
from typing import Any, Iterator

from .objects import INVALID_VERSION, CascadeObject, monotonic_ns


class SeqlockCell:
    """One key's current value, readable without locks while puts proceed."""

    __slots__ = ("_va", "_vb", "_obj")

    def __init__(self) -> None:
        self._va = 0
        self._vb = 0
        self._obj: CascadeObject | None = None

    def store(self, obj: CascadeObject) -> None:
        # Writers are serialized upstream (Cascade runs puts on a single
        # system thread per shard member); gets run on other threads.
        self._va += 1
        self._obj = obj
        self._vb = self._va

    def load(self) -> CascadeObject | None:
        while True:
            vb = self._vb
            obj = self._obj
            va = self._va
            if va == vb:
                return obj
            # torn read: a put was in flight — reissue (paper §3.2)


class VersionChain:
    """All versions of one key, linked by backpointers, temporally indexed.

    ``versions`` is append-only and sorted by construction (versions are
    assigned monotonically per shard), so version/time range queries are a
    bisect + walk over the backpointer chain — the same data structures the
    paper describes for its persisted log (§3.6), held here in memory for the
    volatile store as well.
    """

    __slots__ = ("_objs", "_versions", "_timestamps", "_cell", "lock")

    def __init__(self) -> None:
        self._objs: list[CascadeObject] = []
        self._versions: list[int] = []
        self._timestamps: list[int] = []
        self._cell = SeqlockCell()
        self.lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._objs)

    @property
    def latest_version(self) -> int:
        return self._versions[-1] if self._versions else INVALID_VERSION

    def append(self, obj: CascadeObject, version: int,
               ts_ns: int | None = None) -> CascadeObject:
        """Version the object, link the backpointer, publish via seqlock.

        ``ts_ns``: the platform timestamp assigned at put time; replicas must
        all record the same one so temporal gets agree across members."""
        with self.lock:
            prev = self.latest_version
            stamped = obj.with_version(
                version, prev,
                ts_ns=(obj.timestamp_ns or monotonic_ns()) if ts_ns is None else ts_ns)
            self._objs.append(stamped)
            self._versions.append(version)
            self._timestamps.append(stamped.timestamp_ns)
            self._cell.store(stamped)
            return stamped

    def latest(self) -> CascadeObject | None:
        return self._cell.load()

    def at_version(self, version: int) -> CascadeObject | None:
        """Newest version ≤ ``version`` (paper: versioned get)."""
        i = bisect.bisect_right(self._versions, version)
        return self._objs[i - 1] if i else None

    def at_time(self, ts_ns: int) -> CascadeObject | None:
        """Temporal get: newest version with timestamp ≤ ``ts_ns`` (§3.6)."""
        i = bisect.bisect_right(self._timestamps, ts_ns)
        return self._objs[i - 1] if i else None

    def version_range(self, lo: int, hi: int) -> list[CascadeObject]:
        """Versions in [lo, hi], extracted by walking the backpointer chain."""
        i = bisect.bisect_right(self._versions, hi)
        if i == 0:
            return []
        out: list[CascadeObject] = []
        # Walk backpointers from the newest in-range version (paper §3.6:
        # "scanning the linked version chain to extract a series of pointers").
        idx = i - 1
        by_version = {v: j for j, v in enumerate(self._versions)}
        cur = self._objs[idx]
        while cur is not None and cur.version >= lo:
            out.append(cur)
            pv = cur.previous_version
            cur = self._objs[by_version[pv]] if pv in by_version else None
        out.reverse()
        return out

    def time_range(self, lo_ns: int, hi_ns: int) -> list[CascadeObject]:
        """Temporal range query: map the time window to a version window (§3.6)."""
        lo_i = bisect.bisect_left(self._timestamps, lo_ns)
        hi_i = bisect.bisect_right(self._timestamps, hi_ns)
        if lo_i >= hi_i:
            return []
        return self.version_range(self._versions[lo_i], self._versions[hi_i - 1])

    def __iter__(self) -> Iterator[CascadeObject]:
        return iter(list(self._objs))

"""The fast-path dispatcher (§3.3, Fig 2).

Design points taken directly from the paper:

- the dispatcher observes the stream of K/V updates (①), its *only* role is
  trie prefix matching (②) and enqueueing an upcall event holding a pair of
  references to the object and the matched lambda (③) — it never runs user
  code (direct upcalls from the system thread "could disrupt the entire
  system"; a fork-per-event dispatcher "thrashes");
- a small, fixed pool of upcall threads, each with **its own event queue**,
  dequeues and calls the lambda (④);
- round-robin enqueueing by default; lambdas configured FIFO get a queue
  picked by the key hash of the object so same-key objects stay ordered on
  one thread (e.g. frames from one camera).

Queue-depth introspection: each queue tracks how many events are outstanding
on it (enqueued but not yet *finished* — the event a thread is currently
running still counts).  ``Dispatcher.queue_depths`` exposes the vector, so
admission-control layers (e.g. the serving node's bounded per-replica queues)
can observe backlog building up behind a slow lambda and shed or redirect
before the tail latency does it for them.
"""
from __future__ import annotations

import queue
import threading
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable

from .objects import CascadeObject, monotonic_ns
from .pools import DispatchPolicy
from .trie import PathTrie

UpcallFn = Callable[[CascadeObject, "UpcallEvent"], Any]


@dataclass(frozen=True)
class LambdaHandle:
    name: str
    prefix: str
    fn: UpcallFn
    dispatch: DispatchPolicy = DispatchPolicy.ROUND_ROBIN
    # FIFO queue pick hash; None = crc32 over the full key.  Mirrors the
    # store-level trigger-put member pick: pools with an affinity hash (e.g.
    # ``affinity_shard_hash`` over a session prefix) can group related keys
    # onto ONE upcall queue even when the worker runs several, instead of
    # only same-key objects sharing a queue.
    queue_hash: Callable[[str], int] | None = None


@dataclass
class UpcallEvent:
    """A (object-ref, lambda-ref) pair — shared pointers in the paper."""

    obj: CascadeObject
    handle: LambdaHandle
    enqueued_ns: int = 0
    dequeued_ns: int = 0
    done_ns: int = 0
    result: Any = None
    error: BaseException | None = None
    completion: threading.Event = field(default_factory=threading.Event)


_STOP = object()


class UpcallThreadPool:
    """Fixed pool; each thread loops over its own queue (Fig 2 right side)."""

    def __init__(self, n_threads: int = 4, name: str = "upcall") -> None:
        self.queues: list[queue.SimpleQueue] = [queue.SimpleQueue() for _ in range(n_threads)]
        # outstanding events per queue: incremented at submit, decremented
        # only after the lambda RETURNS, so a blocked upcall thread shows up
        # as depth (1 running + k queued), which is exactly the backlog an
        # admission watermark needs to see.  Also tracked per handle NAME,
        # so a multi-tenant consumer can watermark against ITS OWN in-flight
        # events rather than every tenant's traffic on the shared worker.
        self._depths = [0] * n_threads
        self._handle_depths: dict[str, int] = {}
        # lambda exceptions per queue: the upcall thread CONTAINS a raising
        # lambda (the error rides on the event for any waiter; the thread
        # keeps serving), and this counts the containments so operators can
        # see a poisoned lambda instead of silently losing its events.
        self._errors = [0] * n_threads
        self._depth_lock = threading.Lock()
        self._threads = [
            threading.Thread(target=self._loop, args=(q, i), daemon=True, name=f"{name}-{i}")
            for i, q in enumerate(self.queues)
        ]
        for t in self._threads:
            t.start()

    def __len__(self) -> int:
        return len(self.queues)

    def _loop(self, q: queue.SimpleQueue, idx: int) -> None:
        while True:
            ev = q.get()
            if ev is _STOP:
                return
            ev.dequeued_ns = monotonic_ns()
            try:
                ev.result = ev.handle.fn(ev.obj, ev)
            except BaseException as e:  # surfaced to the waiter, not swallowed
                ev.error = e
            ev.done_ns = monotonic_ns()
            with self._depth_lock:
                if ev.error is not None:
                    self._errors[idx] += 1
                self._depths[idx] -= 1
                name = ev.handle.name
                left = self._handle_depths.get(name, 0) - 1
                if left > 0:
                    self._handle_depths[name] = left
                else:
                    self._handle_depths.pop(name, None)
            ev.completion.set()

    def submit(self, ev: UpcallEvent, queue_index: int) -> None:
        ev.enqueued_ns = monotonic_ns()
        idx = queue_index % len(self.queues)
        with self._depth_lock:
            self._depths[idx] += 1
            name = ev.handle.name
            self._handle_depths[name] = self._handle_depths.get(name, 0) + 1
        self.queues[idx].put(ev)

    def depths(self) -> list[int]:
        """Outstanding (queued + in-flight) events per queue."""
        with self._depth_lock:
            return list(self._depths)

    def depth(self) -> int:
        """Total outstanding events across all queues."""
        with self._depth_lock:
            return sum(self._depths)

    def depth_for(self, handle_name: str) -> int:
        """Outstanding events for ONE lambda handle (by name)."""
        with self._depth_lock:
            return self._handle_depths.get(handle_name, 0)

    def errors(self) -> list[int]:
        """Contained lambda exceptions per queue."""
        with self._depth_lock:
            return list(self._errors)

    def stop(self) -> None:
        for q in self.queues:
            q.put(_STOP)
        for t in self._threads:
            t.join(timeout=5)


class Dispatcher:
    """Trie match → pick queue → enqueue.  Runs on the caller (system) thread;
    the cost it adds to the critical path is exactly steps ②+③."""

    def __init__(self, pool: UpcallThreadPool) -> None:
        self._trie: PathTrie[LambdaHandle] = PathTrie()
        self._pool = pool
        self._rr = 0
        self._lock = threading.Lock()
        self.dispatched = 0

    def register(self, handle: LambdaHandle) -> None:
        self._trie.insert(handle.prefix, handle)

    def unregister(self, handle: LambdaHandle) -> bool:
        return self._trie.remove(handle.prefix, handle)

    def match(self, key: str) -> list[LambdaHandle]:
        return self._trie.match(key)

    def queue_depths(self) -> list[int]:
        """Per-upcall-queue outstanding event counts (queued + running).
        This is the dispatcher's contribution to a node's backlog; consumers
        add their own post-upcall queues (e.g. an engine's scheduler)."""
        return self._pool.depths()

    def queue_depth(self, handle_name: str | None = None) -> int:
        """Outstanding upcall events on this worker — all of them, or only
        those bound for one lambda (by handle name), so a multi-tenant
        admission layer can watermark against its own traffic."""
        if handle_name is not None:
            return self._pool.depth_for(handle_name)
        return self._pool.depth()

    def stats(self) -> dict[str, Any]:
        """Dispatch/containment counters: ``dispatched`` events total,
        ``upcall_errors`` (lambda exceptions the pool contained — the event
        carries the error, the thread survives) and their per-queue split."""
        errors = self._pool.errors()
        with self._lock:
            dispatched = self.dispatched
        return {
            "dispatched": dispatched,
            "upcall_errors": sum(errors),
            "upcall_errors_per_queue": errors,
        }

    def dispatch(self, obj: CascadeObject) -> list[UpcallEvent]:
        """One incoming object may match multiple prefixes → multiple events.
        Only references are enqueued; the payload is never copied."""
        events: list[UpcallEvent] = []
        for handle in self._trie.match(obj.key):
            ev = UpcallEvent(obj=obj, handle=handle)
            if handle.dispatch is DispatchPolicy.FIFO:
                qi = (handle.queue_hash(obj.key) if handle.queue_hash
                      else zlib.crc32(obj.key.encode()))
            else:
                with self._lock:
                    qi = self._rr
                    self._rr += 1
            self._pool.submit(ev, qi)
            events.append(ev)
        if events:
            with self._lock:  # dispatch() is called from concurrent putters
                self.dispatched += len(events)
        return events

"""Device-resident object store: pools placed on mesh slices (§3.2 + §3.5).

The host-side ``CascadeStore`` moves references and small metadata; tensors
live here.  Each pool maps to a placement policy: a ``PartitionSpec`` over
the mesh (``device_axes`` on the PoolSpec) — replication inside the home
slice is the volatile-put multicast; `None` axes replicate, named axes shard.

Versioning is functional: a put installs a new array as the latest version
and retains up to ``keep_versions`` predecessors (the volatile pools of the
paper keep only the latest; persistent pools keep the chain — for arrays the
chain also backs time-travel debugging and checkpoint export).

Values may be single arrays or pytrees of arrays (e.g. a serving replica's
whole paged-KV block pool): placement, the zero-copy donate fast path, and
byte accounting are all tree-aware.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .objects import monotonic_ns
from .placement import LRUCache
from .pools import Persistence, PoolRegistry, PoolSpec


@dataclass
class _DevEntry:
    versions: OrderedDict[int, jax.Array] = field(default_factory=OrderedDict)
    timestamps: dict[int, int] = field(default_factory=dict)
    latest: int = -1


def _tree_nbytes(value: Any) -> int:
    return sum(int(getattr(leaf, "nbytes", 0))
               for leaf in jax.tree.leaves(value))


def _is_sharding(x) -> bool:
    return isinstance(x, jax.sharding.Sharding)


def _tree_placed(value: Any, dst: Any) -> bool:
    """True iff every leaf is already a device array resident where ``dst``
    would put it (exact sharding match, or same single-device placement).
    ``dst`` is a single sharding applied to every leaf, or a pytree of
    per-leaf shardings (a registered sharded-pool policy) congruent with
    ``value``."""
    leaves = jax.tree.leaves(value)
    if not leaves:
        return False
    dst_leaves = jax.tree.leaves(dst, is_leaf=_is_sharding)
    if len(dst_leaves) == 1:
        dst_leaves = dst_leaves * len(leaves)
    elif len(dst_leaves) != len(leaves):
        return False
    for leaf, d in zip(leaves, dst_leaves):
        if not isinstance(leaf, jax.Array):
            return False
        if leaf.sharding == d:
            continue
        # the device-set fallback is only sound when one device is involved
        # (layouts cannot differ there); multi-device needs the exact match
        if not (len(d.device_set) == 1
                and set(leaf.devices()) == set(d.device_set)):
            return False
    return True


class DeviceStore:
    def __init__(self, mesh: Mesh, *, keep_versions: int = 2,
                 lru_bytes: int = 1 << 30) -> None:
        self.mesh = mesh
        self.pools = PoolRegistry()
        self.keep_versions = keep_versions
        self.lru = LRUCache(lru_bytes)
        self._entries: dict[str, _DevEntry] = {}
        self._shardings: dict[str, Any] = {}
        self._lock = threading.Lock()
        # donate-path accounting: hits are zero-copy reference installs,
        # misses are donate=True puts that still had to device_put (sharding
        # mismatch) — the copy-free claim of the serving fast path is
        # asserted on these
        self.donate_hits = 0
        self.donate_misses = 0

    def create_pool(self, spec: PoolSpec) -> PoolSpec:
        return self.pools.create(spec)

    def register_sharding(self, key: str, sharding: Any) -> None:
        """Pin a per-key placement policy: a single sharding, or a pytree of
        per-leaf shardings congruent with the values put under ``key`` (a
        serving replica's sharded KV pool registers its leaf tree here, so
        the donate exact-match check — and the copy fallback — see the
        slice's NamedShardings instead of the store's default mesh)."""
        with self._lock:
            self._shardings[key] = sharding

    def sharding_for(self, key: str):
        reg = self._shardings.get(key)
        if reg is not None:
            return reg
        spec = self.pools.lookup(key)
        axes = spec.device_axes if spec and spec.device_axes else ()
        return NamedSharding(self.mesh, P(*axes))

    # -- puts -----------------------------------------------------------------
    def put(self, key: str, value: Any, *, donate: bool = False) -> jax.Array:
        """Place `value` according to the pool policy and version it.

        ``donate``: if the value is already a device array with the right
        sharding, install the reference without any copy (fast-path put).
        """
        spec = self.pools.lookup(key)
        if spec is None:
            raise KeyError(f"no device pool owns {key!r}")
        dst = self.sharding_for(key)
        if donate and _tree_placed(value, dst):
            arr = value
            with self._lock:
                self.donate_hits += 1
        else:
            arr = jax.device_put(value, dst)
            if donate:
                with self._lock:
                    self.donate_misses += 1
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                e = self._entries[key] = _DevEntry()
            v = e.latest + 1
            e.versions[v] = arr
            e.timestamps[v] = monotonic_ns()
            e.latest = v
            keep = len(e.versions) if spec.persistence is Persistence.PERSISTENT \
                else self.keep_versions
            while len(e.versions) > keep:
                e.versions.popitem(last=False)
        return arr

    # -- gets -----------------------------------------------------------------
    def get(self, key: str, version: int | None = None) -> jax.Array | None:
        e = self._entries.get(key)
        if e is None:
            return None
        if version is None:
            arr = e.versions.get(e.latest)
        else:
            # newest retained version <= requested
            cand = [v for v in e.versions if v <= version]
            arr = e.versions[max(cand)] if cand else None
        if arr is not None:
            self.lru.put(key, arr, _tree_nbytes(arr))
        return arr

    def get_time(self, key: str, ts_ns: int) -> jax.Array | None:
        e = self._entries.get(key)
        if e is None:
            return None
        cand = [v for v, t in e.timestamps.items() if t <= ts_ns and v in e.versions]
        return e.versions[max(cand)] if cand else None

    def remove_prefix(self, prefix: str) -> int:
        """Drop every entry at or under the PATH ``prefix`` (deployment
        teardown: a departing model's KV pools release their device memory
        the moment the last reference dies).  Matching is per path
        component — ``/kv/light`` removes ``/kv/light/replica0/pool`` but
        never ``/kv/light2/...`` — so tenants with common name prefixes
        cannot tear each other down.  Returns the number of keys removed.
        The pool spec itself stays registered — pools are cheap and other
        deployments may share the same root (e.g. ``/kv``)."""
        prefix = prefix.rstrip("/")
        removed = 0
        with self._lock:
            for key in [k for k in self._entries
                        if k == prefix or k.startswith(prefix + "/")]:
                del self._entries[key]
                removed += 1
            for key in [k for k in self._shardings
                        if k == prefix or k.startswith(prefix + "/")]:
                del self._shardings[key]
        return removed

    def latest_version(self, key: str) -> int:
        e = self._entries.get(key)
        return e.latest if e else -1

    def keys(self) -> list[str]:
        return list(self._entries.keys())

    def nbytes(self) -> int:
        total = 0
        for e in self._entries.values():
            for arr in e.versions.values():
                total += _tree_nbytes(arr)
        return total

    # -- export for checkpointing ------------------------------------------------
    def snapshot(self, prefix: str) -> dict[str, np.ndarray]:
        """Host-materialize the latest version of every key under prefix."""
        out = {}
        for key in self.keys():
            if key.startswith(prefix):
                arr = self.get(key)
                if arr is not None:
                    out[key] = jax.tree.map(np.asarray, arr)
        return out

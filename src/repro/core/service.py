"""CascadeService: a running deployment — workers + store + DFG apps (§3.1-3.3).

``CascadeService.deploy(dfg, lambdas)`` performs the paper's "porting an
existing ML application is trivial" flow: upload the DFG (JSON or object),
then register a thin wrapper per lambda.  Pools and shard maps are created
from the DFG vertices, and each vertex's lambda is bound on the workers that
back its shard — this is the data/compute collocation: the lambda runs where
the pool's objects (and the stage's model weights) live.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

from .dfg import DFG, Vertex
from .lambda_api import CascadeContext, LambdaFn, wrap_lambda
from .store import CascadeStore, PutReceipt, Worker


@dataclass
class DeployedApp:
    dfg: DFG
    handles: dict[str, Any] = field(default_factory=dict)


class CascadeService:
    def __init__(self, n_workers: int = 3, *, n_upcall_threads: int = 2,
                 log_dir: str | None = None) -> None:
        self.workers = [
            Worker(i, n_upcall_threads=n_upcall_threads,
                   log_dir=f"{log_dir}/w{i}" if log_dir else None)
            for i in range(n_workers)
        ]
        self.store = CascadeStore(self.workers)
        self.apps: dict[str, DeployedApp] = {}

    # -- deployment ------------------------------------------------------------
    def deploy(self, dfg: DFG | str, lambdas: dict[str, LambdaFn]) -> DeployedApp:
        if isinstance(dfg, str):
            dfg = DFG.from_json(dfg)
        dfg.validate()
        missing = set(dfg.vertices) - set(lambdas) - {v.name for v in dfg.sinks()
                                                      if v.name not in lambdas}
        app = DeployedApp(dfg=dfg)
        for v in dfg.topo_order():
            workers = list(v.shard_workers) if v.shard_workers is not None else None
            self.store.create_pool(v.pool_spec(), workers)
            fn = lambdas.get(v.name)
            if fn is None:
                continue  # storage-only vertex (no-op sink)
            ctx = CascadeContext(store=self.store, dfg=dfg, vertex=v)
            handle = wrap_lambda(v.name, fn, ctx, v)
            self.store.register_lambda(handle, workers)
            app.handles[v.name] = handle
        self.apps[dfg.name] = app
        return app

    # -- client API --------------------------------------------------------------
    def put(self, key: str, payload: Any) -> PutReceipt:
        return self.store.put(key, payload)

    def trigger_put(self, key: str, payload: Any) -> PutReceipt:
        return self.store.trigger_put(key, payload)

    def get(self, key: str):
        return self.store.get(key)

    def inject(self, dfg_name: str, suffix: str, payload: Any,
               *, trigger: bool = True) -> list[PutReceipt]:
        """Feed an object into every source vertex of a deployed app."""
        app = self.apps[dfg_name]
        receipts = []
        for v in app.dfg.sources():
            key = f"{v.prefix}/{suffix}".replace("//", "/")
            if trigger:
                receipts.append(self.store.trigger_put(key, payload))
            else:
                receipts.append(self.store.put(key, payload))
        return receipts

    def close(self) -> None:
        self.store.close()

    def __enter__(self) -> "CascadeService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

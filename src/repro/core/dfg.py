"""Data-flow graphs of ML stages (§3.1).

An application is a DFG: vertices are lambdas bound to path prefixes, edges
are the object flows between them.  A JSON file describing the DFG is
uploaded to Cascade; here ``DFG.from_json`` accepts exactly that shape:

    {
      "name": "smart_farming",
      "vertices": [
        {"name": "filter", "prefix": "/sf/detect_animal",
         "pool": {"persistence": "volatile", "replication": 1},
         "dispatch": "rr", "shard_workers": [0]},
        ...
      ],
      "edges": [["filter", "bcs"], ["bcs", "store"]]
    }
"""
from __future__ import annotations

import json
from bisect import insort
from dataclasses import dataclass, field
from typing import Iterable

from .pools import DispatchPolicy, Persistence, PoolSpec

_PERSISTENCE = {p.value: p for p in Persistence}
_DISPATCH = {"rr": DispatchPolicy.ROUND_ROBIN, "fifo": DispatchPolicy.FIFO}


@dataclass(frozen=True)
class Vertex:
    name: str
    prefix: str
    persistence: Persistence = Persistence.VOLATILE
    replication: int = 1
    dispatch: DispatchPolicy = DispatchPolicy.ROUND_ROBIN
    shard_workers: tuple[int, ...] | None = None  # None = all workers

    def pool_spec(self) -> PoolSpec:
        return PoolSpec(path=self.prefix, persistence=self.persistence,
                        replication=self.replication, dispatch=self.dispatch)


@dataclass
class DFG:
    name: str
    vertices: dict[str, Vertex] = field(default_factory=dict)
    edges: list[tuple[str, str]] = field(default_factory=list)

    def add_vertex(self, v: Vertex) -> Vertex:
        if v.name in self.vertices:
            raise ValueError(f"duplicate vertex {v.name}")
        self.vertices[v.name] = v
        return v

    def add_edge(self, src: str, dst: str) -> None:
        for n in (src, dst):
            if n not in self.vertices:
                raise ValueError(f"unknown vertex {n}")
        self.edges.append((src, dst))

    def successors(self, name: str) -> list[Vertex]:
        return [self.vertices[d] for s, d in self.edges if s == name]

    def sources(self) -> list[Vertex]:
        has_in = {d for _, d in self.edges}
        return [v for v in self.vertices.values() if v.name not in has_in]

    def sinks(self) -> list[Vertex]:
        has_out = {s for s, _ in self.edges}
        return [v for v in self.vertices.values() if v.name not in has_out]

    def validate(self) -> None:
        # prefixes must be unique and acyclic flow
        prefixes = [v.prefix for v in self.vertices.values()]
        if len(set(prefixes)) != len(prefixes):
            raise ValueError("vertex path prefixes must be unique")
        # Kahn's algorithm for cycle detection.
        indeg = {n: 0 for n in self.vertices}
        for _, d in self.edges:
            indeg[d] += 1
        frontier = [n for n, k in indeg.items() if k == 0]
        seen = 0
        while frontier:
            n = frontier.pop()
            seen += 1
            for _, d in [(s, d) for s, d in self.edges if s == n]:
                indeg[d] -= 1
                if indeg[d] == 0:
                    frontier.append(d)
        if seen != len(self.vertices):
            raise ValueError(f"DFG {self.name} has a cycle")

    def topo_order(self) -> list[Vertex]:
        """Deterministic Kahn order: the frontier is kept sorted, so vertices
        with equal indegree come out lexicographically regardless of the
        order vertices/edges were added (deployments must be reproducible)."""
        self.validate()
        order: list[Vertex] = []
        indeg = {n: 0 for n in self.vertices}
        for _, d in self.edges:
            indeg[d] += 1
        frontier = sorted(n for n, k in indeg.items() if k == 0)
        while frontier:
            n = frontier.pop(0)
            order.append(self.vertices[n])
            for s, d in self.edges:
                if s == n:
                    indeg[d] -= 1
                    if indeg[d] == 0:
                        insort(frontier, d)
        return order

    # -- JSON round trip -----------------------------------------------------
    @classmethod
    def from_json(cls, text: str) -> "DFG":
        doc = json.loads(text)
        dfg = cls(name=doc["name"])
        for v in doc.get("vertices", []):
            dfg.add_vertex(Vertex(
                name=v["name"],
                prefix=v["prefix"],
                persistence=_PERSISTENCE[v.get("pool", {}).get("persistence", "volatile")],
                replication=int(v.get("pool", {}).get("replication", 1)),
                dispatch=_DISPATCH[v.get("dispatch", "rr")],
                shard_workers=tuple(v["shard_workers"]) if v.get("shard_workers") else None,
            ))
        for s, d in doc.get("edges", []):
            dfg.add_edge(s, d)
        dfg.validate()
        return dfg

    def to_json(self) -> str:
        return json.dumps({
            "name": self.name,
            "vertices": [
                {
                    "name": v.name,
                    "prefix": v.prefix,
                    "pool": {"persistence": v.persistence.value, "replication": v.replication},
                    "dispatch": "fifo" if v.dispatch is DispatchPolicy.FIFO else "rr",
                    **({"shard_workers": list(v.shard_workers)} if v.shard_workers else {}),
                }
                for v in self.vertices.values()
            ],
            "edges": [list(e) for e in self.edges],
        }, indent=2)

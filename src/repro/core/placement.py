"""Placement: key→home-shard mapping, node selection, and the LRU cache (§3.5).

Cascade maps keys to shards with a deterministic hash; within a shard, a
round-robin policy picks the member that processes each matching object, so
tasks land on nodes that already hold the required model weights.  An LRU
cache retains secondarily-accessed objects: after a short warm-up all shard
members hold copies of systematically-required data.
"""
from __future__ import annotations

import itertools
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from .pools import PoolSpec


@dataclass(frozen=True)
class ShardMap:
    """Static membership: which workers back each shard of a pool."""

    pool: str
    shards: tuple[tuple[int, ...], ...]  # shards[i] = worker ids of shard i

    def home_shard(self, spec: PoolSpec, key: str) -> int:
        return spec.shard_hash(key) % len(self.shards)

    def members(self, spec: PoolSpec, key: str) -> tuple[int, ...]:
        return self.shards[self.home_shard(spec, key)]


class RoundRobin:
    """Per-shard round-robin member selection (thread-safe)."""

    def __init__(self) -> None:
        self._counters: dict[Any, itertools.count] = {}
        self._lock = threading.Lock()

    def pick(self, group_key: Any, members: Sequence[int]) -> int:
        with self._lock:
            ctr = self._counters.get(group_key)
            if ctr is None:
                ctr = self._counters[group_key] = itertools.count()
            return members[next(ctr) % len(members)]


class LRUCache:
    """Byte-budgeted LRU of CascadeObjects (§3.5)."""

    def __init__(self, capacity_bytes: int) -> None:
        self.capacity_bytes = capacity_bytes
        self._items: OrderedDict[str, tuple[Any, int]] = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: str) -> Any | None:
        with self._lock:
            entry = self._items.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._items.move_to_end(key)
            self.hits += 1
            return entry[0]

    def put(self, key: str, value: Any, nbytes: int) -> None:
        with self._lock:
            old = self._items.pop(key, None)
            if old is not None:
                self._bytes -= old[1]
            self._items[key] = (value, nbytes)
            self._bytes += nbytes
            while self._bytes > self.capacity_bytes and len(self._items) > 1:
                _, (_, nb) = self._items.popitem(last=False)
                self._bytes -= nb

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._items

    def __len__(self) -> int:
        return len(self._items)

    @property
    def nbytes(self) -> int:
        return self._bytes


def build_shard_map(pool: str, worker_ids: Sequence[int], replication: int) -> ShardMap:
    """Partition workers into shards of ``replication`` members each.

    len(worker_ids) must be a multiple of replication; each worker serves
    exactly one shard of this pool (matching the paper's deployments where
    each stage's pool is backed by a dedicated shard of 1..5 servers).
    """
    ids = list(worker_ids)
    if replication > len(ids):
        raise ValueError(f"pool {pool}: replication {replication} > workers {len(ids)}")
    n_shards = len(ids) // replication
    shards = tuple(
        tuple(ids[i * replication : (i + 1) * replication]) for i in range(n_shards)
    )
    return ShardMap(pool=pool, shards=shards)

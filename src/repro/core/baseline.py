"""Broker-style pub/sub baseline — the comparison system (§1 Fig 1, §5.1).

The paper compares Cascade with Kafka-Direct/Flink-style interconnects whose
stage-to-stage handoff involves: a broker node, per-topic logs, serialization
into wire buffers, consumer polling, and lock contention between producer and
consumer threads.  This module implements that architecture faithfully *in
the same process* so the comparison isolates the data path (both systems pay
identical Python/JAX costs for the stage compute itself):

- ``Broker`` — central component with per-topic queues; every publish
  *serializes* the payload (marshalling copy), appends under a topic lock,
  and wakes consumers; consumers *poll* and deserialize (second copy).
- lock contention: producers and consumers contend on the same topic lock —
  the exact effect the paper identified in Kafka-Direct when publisher and
  subscriber run on different nodes.
- optional ``batch_linger_s``: the throughput-over-latency knob (Kafka's
  linger.ms); with a backlog, consumers drain mini-batches.

``BrokerPipeline`` runs a chain of stage fns with a broker hop between every
pair of stages — the no-op pipeline benchmark runs the identical lambdas on
this and on the Cascade fast path.
"""
from __future__ import annotations

import pickle
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Sequence

from .objects import monotonic_ns


class Topic:
    def __init__(self, name: str) -> None:
        self.name = name
        self.log: deque[tuple[int, bytes]] = deque()
        self.lock = threading.Lock()          # producer/consumer contention
        self.not_empty = threading.Condition(self.lock)
        self.next_offset = 0


class Broker:
    def __init__(self, *, batch_linger_s: float = 0.0) -> None:
        self.topics: dict[str, Topic] = {}
        self.batch_linger_s = batch_linger_s
        self._meta = threading.Lock()

    def topic(self, name: str) -> Topic:
        with self._meta:
            t = self.topics.get(name)
            if t is None:
                t = self.topics[name] = Topic(name)
            return t

    def publish(self, topic: str, payload: Any) -> int:
        wire = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)  # copy #1
        t = self.topic(topic)
        if self.batch_linger_s:
            time.sleep(self.batch_linger_s)   # intentional batching delay
        with t.not_empty:
            off = t.next_offset
            t.next_offset += 1
            t.log.append((off, wire))
            t.not_empty.notify_all()
        return off

    def poll(self, topic: str, *, timeout_s: float = 5.0, max_records: int = 64) -> list[Any]:
        t = self.topic(topic)
        deadline = time.monotonic() + timeout_s
        with t.not_empty:
            while not t.log:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return []
                t.not_empty.wait(remaining)
            batch = []
            while t.log and len(batch) < max_records:
                _, wire = t.log.popleft()
                batch.append(wire)
        return [pickle.loads(w) for w in batch]  # copy #2


@dataclass
class _StageWorker:
    broker: Broker
    in_topic: str
    out_topic: str | None
    fn: Callable[[Any], Any]

    def start(self) -> threading.Thread:
        th = threading.Thread(target=self._loop, daemon=True)
        th.start()
        return th

    def _loop(self) -> None:
        while True:
            for item in self.broker.poll(self.in_topic, timeout_s=0.25):
                if item is None:  # poison pill
                    return
                out = self.fn(item)
                if self.out_topic is not None:
                    self.broker.publish(self.out_topic, out)


class BrokerPipeline:
    """Chain of stages with broker handoffs (the measured anti-pattern)."""

    def __init__(self, stage_fns: Sequence[Callable[[Any], Any]],
                 *, batch_linger_s: float = 0.0) -> None:
        self.broker = Broker(batch_linger_s=batch_linger_s)
        self.n = len(stage_fns)
        self._threads = []
        for i, fn in enumerate(stage_fns):
            w = _StageWorker(
                broker=self.broker,
                in_topic=f"stage-{i}",
                out_topic=f"stage-{i + 1}" if i + 1 < self.n else "sink",
                fn=fn,
            )
            self._threads.append(w.start())

    def send(self, payload: Any) -> None:
        self.broker.publish("stage-0", payload)

    def recv(self, *, timeout_s: float = 10.0) -> Any:
        out = self.broker.poll("sink", timeout_s=timeout_s, max_records=1)
        if not out:
            raise TimeoutError("pipeline produced no output")
        return out[0]

    def roundtrip(self, payload: Any) -> tuple[Any, float]:
        t0 = monotonic_ns()
        self.send(payload)
        out = self.recv()
        return out, (monotonic_ns() - t0) / 1e3  # us

    def stop(self) -> None:
        for i in range(self.n):
            self.broker.publish(f"stage-{i}", None)

"""Cascade objects: keyed, versioned, timestamped payloads (§3.2).

A ``CascadeObject`` is the unit the K/V store moves: a key (a ``/`` path whose
first components name the object pool), a payload, a monotonically-increasing
per-key version, a platform-assigned timestamp, and a backpointer to the
previous version of the same key (§3.6 — backpointer chains accelerate
version/temporal range queries).

Payloads may be ``bytes``, numpy arrays, or JAX arrays; on the device fast
path objects carry device arrays and the host only moves *references* —
mirroring the paper's zero-copy discipline.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

INVALID_VERSION = -1


def monotonic_ns() -> int:
    return time.monotonic_ns()


@dataclass(frozen=True)
class CascadeObject:
    key: str
    payload: Any
    version: int = INVALID_VERSION
    timestamp_ns: int = 0
    previous_version: int = INVALID_VERSION  # backpointer (§3.6)
    meta: dict[str, Any] = field(default_factory=dict)

    @property
    def pool_path(self) -> str:
        """Pool prefix = first path component (pools may register deeper)."""
        comps = [c for c in self.key.split("/") if c]
        return "/" + comps[0] if comps else "/"

    def with_version(self, version: int, previous: int, ts_ns: int | None = None) -> "CascadeObject":
        if ts_ns is None:
            ts_ns = self.timestamp_ns or monotonic_ns()
        return CascadeObject(
            key=self.key,
            payload=self.payload,
            version=version,
            timestamp_ns=ts_ns,
            previous_version=previous,
            meta=self.meta,
        )

    def nbytes(self) -> int:
        p = self.payload
        if p is None:
            return 0
        if isinstance(p, (bytes, bytearray, memoryview)):
            return len(p)
        nb = getattr(p, "nbytes", None)
        if nb is not None:
            return int(nb)
        return len(repr(p))

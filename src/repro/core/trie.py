"""Path-prefix trie — Cascade Fig 2 step ②.

The dispatcher matches each incoming object key against the set of registered
lambda path prefixes.  The paper reports ~130 ns per depth level using a
ternary tree; we use a per-level dict trie (hash per component) which has the
same asymptotics and is the idiomatic Python equivalent.

Keys are ``/``-separated paths (``/pool/sub/key``).  A registered prefix
matches every key of which it is a path-component prefix, so one key may
match several prefixes at different depths (the paper: "one incoming object
could match multiple path prefixes and trigger multiple lambdas").
"""
from __future__ import annotations

from typing import Any, Generic, Iterator, TypeVar

T = TypeVar("T")


def split_path(path: str) -> list[str]:
    """Split a Cascade key path into components, ignoring empty segments."""
    return [c for c in path.split("/") if c]


class _Node(Generic[T]):
    __slots__ = ("children", "values")

    def __init__(self) -> None:
        self.children: dict[str, _Node[T]] = {}
        self.values: list[T] = []


class PathTrie(Generic[T]):
    """Maps path prefixes to lists of values (lambda handles)."""

    def __init__(self) -> None:
        self._root: _Node[T] = _Node()
        self._size = 0

    def __len__(self) -> int:
        return self._size

    def insert(self, prefix: str, value: T) -> None:
        node = self._root
        for comp in split_path(prefix):
            nxt = node.children.get(comp)
            if nxt is None:
                nxt = _Node()
                node.children[comp] = nxt
            node = nxt
        node.values.append(value)
        self._size += 1

    def remove(self, prefix: str, value: T) -> bool:
        node = self._root
        for comp in split_path(prefix):
            node = node.children.get(comp)  # type: ignore[assignment]
            if node is None:
                return False
        try:
            node.values.remove(value)
        except ValueError:
            return False
        self._size -= 1
        return True

    def match(self, key: str) -> list[T]:
        """All values registered at any prefix of ``key`` (shallow → deep)."""
        out: list[T] = []
        node = self._root
        if node.values:
            out.extend(node.values)
        for comp in split_path(key):
            node = node.children.get(comp)  # type: ignore[assignment]
            if node is None:
                break
            if node.values:
                out.extend(node.values)
        return out

    def longest_prefix(self, key: str) -> tuple[str, list[T]] | None:
        """The deepest registered prefix of ``key`` with its values."""
        node = self._root
        best: tuple[str, list[T]] | None = None
        comps: list[str] = []
        if node.values:
            best = ("/", list(node.values))
        for comp in split_path(key):
            node = node.children.get(comp)  # type: ignore[assignment]
            if node is None:
                break
            comps.append(comp)
            if node.values:
                best = ("/" + "/".join(comps), list(node.values))
        return best

    def iter_prefixes(self) -> Iterator[tuple[str, list[T]]]:
        stack: list[tuple[str, _Node[T]]] = [("", self._root)]
        while stack:
            path, node = stack.pop()
            if node.values:
                yield (path or "/", list(node.values))
            for comp, child in node.children.items():
                stack.append((f"{path}/{comp}", child))

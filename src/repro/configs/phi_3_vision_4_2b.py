"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend
[hf:microsoft/Phi-3-vision-128k-instruct; hf].

32L d_model=3072 32H (GQA kv=32 = MHA) d_ff=8192 vocab=32064.
Backbone only: the CLIP image tower is a stub — input_specs() supplies
precomputed patch+text embeddings (B,S,3072).  Untied LM head.
"""
from repro.models.config import ModelConfig

ARCH_ID = "phi-3-vision-4.2b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    input_mode="embeds", tie_embeddings=False,
    rope_theta=10_000.0,
    notes="CLIP frontend stubbed: patch/text embeddings in",
)

SMOKE = CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                       head_dim=16, d_ff=128, vocab_size=64, dtype="float32",
                       q_chunk=16)

"""Architecture registry + assigned input-shape cells.

Every assigned (arch × shape) pair is a ``Cell``; ``all_cells()`` enumerates
the full 40-cell baseline table.  ``long_500k`` is skipped (per assignment)
for pure full-attention archs — the skip is recorded, not silently dropped.
"""
from __future__ import annotations

from dataclasses import dataclass
from importlib import import_module

from repro.models.config import ModelConfig

_MODULES = {
    "musicgen-large": "musicgen_large",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "gemma3-4b": "gemma3_4b",
    "gemma2-9b": "gemma2_9b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "mamba2-1.3b": "mamba2_1_3b",
    "zamba2-2.7b": "zamba2_2_7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, *, smoke: bool = False) -> ModelConfig:
    mod = import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.SMOKE if smoke else mod.CONFIG


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}

# Archs with bounded-memory attention (SSM / hybrid / SWA / local:global) run
# long_500k; pure full-attention archs skip it (DESIGN.md §4).
LONG_CONTEXT_OK = frozenset({
    "mamba2-1.3b", "zamba2-2.7b", "h2o-danube-1.8b", "h2o-danube-3-4b",
    "gemma3-4b", "gemma2-9b",
})


@dataclass(frozen=True)
class Cell:
    arch_id: str
    shape: Shape
    skipped: bool = False
    skip_reason: str = ""

    @property
    def name(self) -> str:
        return f"{self.arch_id}@{self.shape.name}"


def all_cells() -> list[Cell]:
    cells = []
    for arch in ARCH_IDS:
        for shape in SHAPES.values():
            skip = shape.name == "long_500k" and arch not in LONG_CONTEXT_OK
            cells.append(Cell(
                arch_id=arch, shape=shape, skipped=skip,
                skip_reason="pure full-attention arch: 512k dense KV cache "
                            "excluded per assignment" if skip else ""))
    return cells

"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified].

48L d_model=2048 vocab=50280 (padded to 50288 = 16·3143 for TP sharding, the
same pad_vocab_size_multiple the reference implementation applies),
ssm_state=128, expand 2 → d_inner 4096, head_dim 64 → 64 SSD heads.
`long_500k` runs: decode state is O(1) in sequence length.
"""
from repro.models.config import ModelConfig

ARCH_ID = "mamba2-1.3b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=50288, tie_embeddings=True,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    conv_width=4,
    notes="vocab padded 50280→50288 (×16) for sharding",
)

SMOKE = CONFIG.replace(n_layers=4, d_model=64, vocab_size=256, ssm_state=16,
                       ssm_head_dim=16, ssm_chunk=8, dtype="float32")

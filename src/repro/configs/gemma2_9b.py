"""gemma2-9b [dense] — local+global alternating, logit softcaps
[arXiv:2408.00118; hf].

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.
Alternation: 1 local (window 4096) : 1 global; attention logit softcap 50,
final logit softcap 30; pre+post norms; scaled, tied embeddings; head_dim
256 (> d_model/heads, per the public config).
"""
from repro.models.config import ModelConfig

ARCH_ID = "gemma2-9b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000, tie_embeddings=True,
    window=4096, local_global_pattern=1,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_norm=True, embed_scale=True,
    rope_theta=10_000.0,
)

SMOKE = CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, vocab_size=256, window=8,
                       dtype="float32", q_chunk=16)

"""llama4-maverick-400b-a17b [moe] — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
Interleaved MoE (every 2nd layer, as in Maverick) + 1 shared expert lands
total params at ~398B with ~17B active — matching the name.  Trains with
Adafactor by default (Adam moments for 400B exceed a 256-chip pod's HBM).
"""
from repro.models.config import ModelConfig

ARCH_ID = "llama4-maverick-400b-a17b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048, tie_embeddings=False,
    n_experts=128, top_k=1, n_shared_experts=1, moe_d_ff=8192, moe_every=2,
    capacity_factor=1.25,
    optimizer="adafactor",
    rope_theta=500_000.0,
    notes="config tagged unverified upstream; moe_every=2 to land 400B/17B-active",
)

SMOKE = CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, vocab_size=256, n_experts=8,
                       moe_d_ff=32, dtype="float32", q_chunk=16)

"""h2o-danube-3-4b [dense] — llama+mistral mix, SWA
[arXiv:2401.16818; unverified].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.
Assignment says SWA; the public 3-series reportedly dropped SWA — we follow
the assignment (window 8192, noted unverified).
"""
from repro.models.config import ModelConfig

ARCH_ID = "h2o-danube-3-4b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
    d_ff=10240, vocab_size=32000, tie_embeddings=True,
    window=8192,
    rope_theta=500_000.0,
    notes="unverified upstream; SWA per assignment line",
)

SMOKE = CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, vocab_size=256, window=8,
                       dtype="float32", q_chunk=16)

"""musicgen-large [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=2048 32H (GQA kv=32 = MHA) d_ff=8192 vocab=2048.
Backbone only: the EnCodec frontend is a stub — input_specs() supplies
precomputed frame embeddings (B,S,2048); the 4-codebook output heads are
simplified to a single 2048-way head (backbone mandate).  Upstream MusicGen
uses an ungated GELU MLP; we use the framework's gated MLP at the same d_ff
(noted deviation, params +⅓ on the MLP block).
"""
from repro.models.config import ModelConfig

ARCH_ID = "musicgen-large"

CONFIG = ModelConfig(
    name=ARCH_ID, family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    input_mode="embeds", tie_embeddings=True,
    rope_theta=10_000.0,
    notes="frontend stubbed: frame embeddings in; single codebook head",
)

SMOKE = CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                       head_dim=16, d_ff=128, vocab_size=64, dtype="float32",
                       q_chunk=16)

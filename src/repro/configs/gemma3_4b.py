"""gemma3-4b [dense] — 5:1 local:global, 128k context
[hf:google/gemma-3-1b-pt; unverified].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
Pattern: 5 local (window 1024, RoPE θ=10k) then 1 global (θ=1M); 34 = 5×6+4
→ five full patterns + a 4-local remainder segment.  QK-norm (gemma3
replaces gemma2's logit softcap), pre+post norms, scaled embeddings, tied.
"""
from repro.models.config import ModelConfig

ARCH_ID = "gemma3-4b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144, tie_embeddings=True,
    window=1024, local_global_pattern=5,
    qk_norm=True, post_norm=True, embed_scale=True,
    rope_theta=1_000_000.0, rope_theta_local=10_000.0,
    notes="config tagged unverified upstream (hf points at 1b-pt); dims per assignment",
)

SMOKE = CONFIG.replace(n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, vocab_size=256, window=8,
                       dtype="float32", q_chunk=16)

"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; hf].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096.
"""
from repro.models.config import ModelConfig

ARCH_ID = "h2o-danube-1.8b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
    d_ff=6912, vocab_size=32000, tie_embeddings=False,
    window=4096,
    rope_theta=10_000.0,
)

SMOKE = CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                       head_dim=16, d_ff=128, vocab_size=256, window=8,
                       dtype="float32", q_chunk=16)

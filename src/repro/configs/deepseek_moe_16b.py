"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts
[arXiv:2401.06066; hf].

28L d_model=2048 16H (kv=16 = MHA) expert d_ff=1408 vocab=102400.
Layer 0 is a dense FFN (d_ff=10944) per the paper; layers 1-27 are MoE with
64 fine-grained routed experts (top-6) + 2 shared experts of the same 1408
hidden size.
"""
from repro.models.config import ModelConfig

ARCH_ID = "deepseek-moe-16b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944, vocab_size=102400, tie_embeddings=False,
    n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
    first_layer_dense=True, capacity_factor=1.25,
    rope_theta=10_000.0,
    notes="assignment lists d_ff=1408 (expert hidden); dense layer-0 uses 10944 per paper",
)

SMOKE = CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                       head_dim=16, d_ff=128, vocab_size=256, n_experts=8,
                       top_k=2, moe_d_ff=32, dtype="float32", q_chunk=16)

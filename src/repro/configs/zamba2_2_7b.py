"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf].

54L d_model=2560 32H (kv=32 = MHA) d_ff=10240 vocab=32000, ssm_state=64.
Layout: 9 × (6 mamba2 layers + 1 shared-attention application); the shared
transformer block (one parameter set, applied 9×) takes concat(hidden,
original embeddings) (2d) as input, per the Zamba design.  Per-application
LoRA deltas are omitted (noted simplification, DESIGN §4).  head_dim 160 =
2d/32.  `long_500k` runs: mamba state is O(1) and the 9 shared-attn caches
hold full context (sequence-sharded).
"""
from repro.models.config import ModelConfig

ARCH_ID = "zamba2-2.7b"

CONFIG = ModelConfig(
    name=ARCH_ID, family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=160,
    d_ff=10240, vocab_size=32000, tie_embeddings=True,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    conv_width=4, shared_attn_every=6,
    rope_theta=10_000.0,
    notes="shared-block LoRA deltas omitted; 54 = 9 groups of 6",
)

SMOKE = CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                       head_dim=32, d_ff=128, vocab_size=256, ssm_state=16,
                       ssm_head_dim=16, ssm_chunk=8, shared_attn_every=2,
                       dtype="float32", q_chunk=16)

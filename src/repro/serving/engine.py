"""The serving engine: Cascade hosting applied to LM inference.

One engine replica = one DFG vertex (a lambda bound to /serve/<name>) whose
"computation" is prefill+decode over a model whose weights live in the
replica's device store — data/compute collocation: requests (small objects)
move to the weights (the largest dependency), never the reverse (§2, §3.5).

Continuous batching: a fixed pool of KV slots; each engine tick decodes all
active slots in ONE jitted step (the fast path — no host round-trips between
stages), then admits waiting prefills into freed slots.

Fast-path discipline inside the tick:

- **Batched prefill admission** — requests admitted in the same tick are
  batched over contiguous same-shape runs (admission order preserved) and
  each run executes ONE jitted prefill with B=k (no padding, so the path is
  safe for ring caches and SSM state alike); each row is spliced into its
  KV slot device-side.
- **Masked decode** — sampling is fused into the jitted decode step and
  inactive slots are masked there, so garbage rows never leak into
  ``_last_tokens`` and the host sees a single ready-to-read token vector.
- **One device→host transfer per tick** — the decode step's new tokens are
  pulled once via ``np.asarray`` (``stats.host_syncs`` counts every pull;
  one per decode tick plus one per prefill group, never per slot).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, prefill
from repro.models.config import ModelConfig

from .kvcache import CacheManager
from .scheduler import Request, Scheduler


@dataclass
class EngineStats:
    ticks: int = 0
    tokens_out: int = 0
    prefills: int = 0
    prefill_batches: int = 0                       # jitted prefill dispatches
    decode_ticks: int = 0                          # ticks that ran a decode
    host_syncs: int = 0                            # device→host transfers
    ttft_s: list = field(default_factory=list)     # time to first token
    tpot_s: list = field(default_factory=list)     # time per output token


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 512, temperature: float = 0.0,
                 scheduler: Scheduler | None = None, replica_id: int = 0,
                 on_complete: Callable[[Request], None] | None = None,
                 seed_offset: int | None = None) -> None:
        self.cfg = cfg
        self.params = params
        self.cm = CacheManager(cfg, n_slots, max_len)
        self.scheduler = scheduler or Scheduler(n_replicas=1)
        self.replica_id = replica_id
        self.temperature = temperature
        self.on_complete = on_complete
        self.stats = EngineStats()
        self.live: dict[int, Request] = {}
        self._last_tokens = jnp.zeros((n_slots,), jnp.int32)
        # Sampling seed stream: one fresh seed per jitted dispatch, offset by
        # replica so same-tick prefill groups / decode steps / sibling
        # replicas never share a PRNG key.
        self._seed_base = (seed_offset if seed_offset is not None
                           else replica_id) * 1_000_003
        self._dispatches = 0

        temp = temperature

        def _sample(logits, seed):
            if temp <= 0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            key = jax.random.PRNGKey(seed)
            return jax.random.categorical(key, logits / temp).astype(jnp.int32)

        def _prefill_step(p, toks, pos, seed):
            logits, caches = prefill(p, toks, pos, cfg, max_len=max_len)
            return _sample(logits, seed), caches

        def _decode_tick(p, caches, toks, pos, active, seed):
            logits, new_caches = decode_step(p, caches, toks, pos, cfg)
            sampled = _sample(logits, seed)
            # masked decode: inactive slots keep their last token so stale
            # rows never feed garbage back into the next step
            return jnp.where(active, sampled, toks), new_caches

        self._prefill = jax.jit(_prefill_step)
        self._step = jax.jit(_decode_tick)

    # ------------------------------------------------------------- client
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    # ------------------------------------------------------------- engine
    def _next_seed(self) -> jnp.ndarray:
        self._dispatches += 1
        return jnp.int32(self._seed_base + self._dispatches)

    def _to_host(self, arr) -> np.ndarray:
        """THE device→host sync point; everything host-side reads through
        here so tests/benchmarks can assert the one-transfer-per-tick rule."""
        self.stats.host_syncs += 1
        return np.asarray(arr)

    @staticmethod
    def _norm_prompt(prompt) -> np.ndarray:
        """(S,) tokens or (S,d) embeds; squeeze a legacy leading batch dim."""
        p = np.asarray(prompt)
        if p.ndim >= 2 and p.shape[0] == 1:
            p = p[0]
        if np.issubdtype(p.dtype, np.integer):
            p = p.astype(np.int32)
        return p

    def _admit(self) -> None:
        free = self.cm.n_slots - self.cm.n_active
        reqs = self.scheduler.admit(self.replica_id, free)
        if not reqs:
            return
        # Batched multi-request prefill: batch CONTIGUOUS same-shape runs
        # (equal-length bucketing — no padding, so ring caches and SSM state
        # stay exact), one jitted prefill and ONE host pull per run.
        # Contiguity (not a shape→list dict) preserves admission order, so
        # a FIFO session's turns can never be prefilled out of order.
        groups: list[tuple[tuple, list[tuple[Request, np.ndarray]]]] = []
        for req in reqs:
            p = self._norm_prompt(req.prompt)
            if groups and groups[-1][0] == p.shape:
                groups[-1][1].append((req, p))
            else:
                groups.append((p.shape, [(req, p)]))
        for shape, group in groups:
            prompts = jnp.asarray(np.stack([p for _, p in group]))
            S = shape[0]
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                   (len(group), S))
            toks, group_caches = self._prefill(self.params, prompts, pos,
                                               self._next_seed())
            host_toks = self._to_host(toks)            # one sync per group
            self.stats.prefill_batches += 1
            now = time.monotonic()
            for row, (req, _) in enumerate(group):
                slot = self.cm.acquire(req.request_id)
                assert slot is not None
                self.cm.insert_prefill(slot, group_caches, S, row)
                tok = int(host_toks[row])
                req.slot = slot
                req.tokens.append(tok)
                req.first_token_s = now
                self.stats.ttft_s.append(now - req.arrived_s)
                self.stats.prefills += 1
                self.stats.tokens_out += 1
                self._last_tokens = self._last_tokens.at[slot].set(tok)
                if len(req.tokens) >= req.max_new_tokens:
                    self.cm.release(slot)              # done at first token
                    self._complete(req)
                else:
                    self.live[slot] = req

    def _complete(self, req: Request) -> None:
        req.done_s = time.monotonic()
        if self.on_complete is not None:
            self.on_complete(req)

    def tick(self) -> int:
        """One engine step: admit prefills, decode all active slots."""
        self._admit()
        if not self.live:
            self.stats.ticks += 1
            return 0
        t0 = time.monotonic()
        positions = self.cm.positions()[:, None]               # (B,1)
        active = self.cm.active_mask()
        new_toks, self.cm.caches = self._step(
            self.params, self.cm.caches, self._last_tokens, positions,
            active, self._next_seed())
        self._last_tokens = new_toks
        host_toks = self._to_host(new_toks)       # the ONE sync of this tick
        self.cm.advance()
        dt = time.monotonic() - t0
        done = []
        n_emitted = 0
        for slot, req in list(self.live.items()):
            req.tokens.append(int(host_toks[slot]))
            n_emitted += 1
            self.stats.tpot_s.append(dt)
            if len(req.tokens) >= req.max_new_tokens:
                done.append(slot)
        for slot in done:
            req = self.live.pop(slot)
            self.cm.release(slot)
            self._complete(req)
        self.stats.ticks += 1
        self.stats.decode_ticks += 1
        self.stats.tokens_out += n_emitted
        return n_emitted

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            pending = self.scheduler.pending(self.replica_id)
            if not pending and not self.live:
                return
            self.tick()
        raise TimeoutError("engine did not drain")

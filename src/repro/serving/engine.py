"""The serving engine: Cascade hosting applied to LM inference.

One engine replica = one DFG vertex (a lambda bound to /serve/<name>) whose
"computation" is prefill+decode over a model whose weights live in the
replica's device store — data/compute collocation: requests (small objects)
move to the weights (the largest dependency), never the reverse (§2, §3.5).

Unified token-budget tick (paged mode — the default for pure-attention token
models, see ``models.supports_paged``)
--------------------------------------------------------------------------
Every tick is ONE fixed-shape jitted mixed step.  The scheduler admits work
against a per-tick TOKEN budget: each active decode row costs 1 token, and
waiting prefills are split into chunks that fill the remainder — a long
prompt spreads over several ticks instead of stalling every decoding session
behind it (the head-of-line effect the paper's fast path exists to kill; the
inter-token stall is bounded by the chunk budget).  The admitted tokens are
packed into a single ragged batch — per-token absolute positions and request
row ids — and a ragged paged-attention step (kernels/decode_attention)
computes prefill chunks and decode rows in the SAME dispatch against the
shared block pool: all packed K/V is written before any packed token reads,
so intra-chunk causality, decode, and intra-batch prefix sharing (a
same-tick sibling attending to a chunk's just-written prefix blocks) are all
one causal mask.

Fast-path discipline of the unified tick:

- **Fixed shapes, one compile** — the packed batch is always exactly
  ``token_budget`` tokens and the block-table operand is always
  (n_slots, max_blocks), so the step compiles ONCE for the engine's
  lifetime: no per-prompt-length (or per-suffix-length) recompiles, no
  cold-turn TTFT tail from XLA.
- **Fused boundary sampling + scoring** — the head + sampler run inside the
  step on one gathered boundary token per slot (its decode token, or the
  final token of the chunk that completed its prompt), so the host never
  sees logits: only an (n_slots,) token vector plus an (n_slots, 2) score
  vector — log p(token) and the next-token distribution's entropy, computed
  from the same in-dispatch log-softmax.  Those per-token scores are what
  cascade gates (serving/cluster.CascadeRoute) read to decide light→heavy
  escalation; the engine already has them on device, so surfacing them
  costs no extra dispatch and no extra logits traffic.
- **One device→host sync per tick** — tokens and scores are pulled together
  in one blocking ``jax.device_get``; ``stats.host_syncs == stats.ticks``
  is THE invariant (``_to_host`` counts every sync point; an idle tick —
  nothing live, nothing admissible — dispatches nothing and does not count
  as a tick).

Speculative decoding (``spec_k > 0``, paged engines only — gated by
``models.supports_speculative`` exactly as paging is by ``supports_paged``):
a ``DraftSource`` (serving/draft) proposes up to ``spec_k`` draft tokens per
decode row — self-drafted from the request's own prompt+generated history,
or carried on the request by a cascade (the light deployment's generation).
The row packs ``[t_last, d_1, .., d_m]`` as m+1 consecutive tokens in the
SAME ragged dispatch (the kernel already treats a multi-token row like a
prefill chunk: K/V written first, causal mask per token), the head gathers
all m+1 boundary logits, and the in-dispatch acceptance rule
(``models.sampling.speculative_verify`` — Leviathan-style rejection
sampling) keeps the longest target-confirmed prefix plus one
correction/bonus token.  The host still sees ONE sync per tick, now
amortized over up to m+1 emitted tokens; KV written for rejected drafts is
rolled back by truncating the row's block table
(``kvcache.rollback_writes``).  Budget arithmetic: draft lanes are granted
LAST — after every live row's mandatory lane and all prefill chunk work has
packed (``_plan_drafts``) — so a k-token row can never oversubscribe the
fixed packed shape, never starves a sibling decode row, and never delays a
waiting prefill: speculation monetizes lanes that would have dispatched as
pads.  Greedy speculation emits the bit-identical stream of the
non-speculative engine; sampled speculation emits exactly the target
distribution (rejection sampling is lossless).

Prefix reuse: admission matches each prompt against the per-replica trie of
cached token blocks and prefills ONLY the suffix past the last matched block
(``stats.prefix_hit_tokens``).  Chunk-granularity trie commit
(kvcache.commit_prefill_progress) extends that to SAME-TICK sharing: two
same-prefix requests admitted in one tick share blocks instead of both
prefilling the prefix.

Dense mode (SSM/hybrid/embeds configs, ``supports_paged == False``) keeps
the phase-separated discipline: batched equal-length prefill admission (one
jitted prefill per contiguous same-shape run), masked fused decode+sample,
and ``host_syncs == decode_ticks + prefill_batches``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (decode_step, paged_mixed_step, prefill,
                          sample_with_scores, speculative_verify,
                          supports_paged, supports_speculative)
from repro.models.config import ModelConfig

from .draft import DraftSource, default_draft_source
from .faults import ReplicaCrashed
from .kvcache import CacheManager, PagedCacheManager, SpilledKV
from .scheduler import Request, Scheduler, virtual_deadline


@dataclass
class EngineStats:
    ticks: int = 0                 # dispatched steps (paged) / tick() calls (dense)
    tokens_out: int = 0
    prefills: int = 0
    prefill_batches: int = 0       # dense: jitted prefill dispatches (paged: 0)
    prefill_chunks: int = 0        # paged: prompt chunks packed into mixed steps
    decode_ticks: int = 0          # ticks that carried >= 1 decode row
    host_syncs: int = 0            # device→host transfers
    prompt_tokens: int = 0         # total prompt tokens seen
    prefill_tokens: int = 0        # tokens actually prefilled
    prefix_hit_tokens: int = 0     # tokens reused from cache
    prefix_hits: int = 0           # requests with a hit
    blocks_in_use: int = 0         # gauge, sampled per tick
    # speculative decoding (paged engines with spec_k > 0):
    spec_drafted: int = 0          # draft tokens packed for verification
    spec_accepted: int = 0         # drafts the target confirmed (kept)
    spec_rolled_back: int = 0      # rejected drafts whose KV was rolled back
    # fault tolerance (serving/faults, deployment failover):
    deadline_exceeded: int = 0     # requests expired at this replica
    spill_syncs: int = 0           # device→host KV spills (counted in
    #                                host_syncs too: a spilled — dead —
    #                                replica satisfies host_syncs == ticks
    #                                + spill_syncs; survivors keep the
    #                                strict host_syncs == ticks)
    spilled_sessions: int = 0      # live sessions spilled off this replica
    adopted_sessions: int = 0      # migrated sessions restored INTO this one
    # overload preemption (issue-queue scheduler, preempt=True):
    preemptions: int = 0           # in-flight victims evicted for a waiter
    spilled_blocks: int = 0        # KV blocks pulled host-side by spills
    resumes: int = 0               # preempted requests restored via adopt()
    #                                (replay-fallback resumes re-issue as
    #                                ordinary admissions and are NOT counted)
    ttft_s: list = field(default_factory=list)     # time to first token
    tpot_s: list = field(default_factory=list)     # time per output token
    # per-SLO-class queue wait (issued_s - arrived_s), recorded once at a
    # request's FIRST issue (a preempted request keeps its original wait)
    queue_wait_s: dict = field(default_factory=dict)   # slo -> [seconds]

    def spec_acceptance_rate(self) -> float:
        """Fraction of drafted tokens the target model confirmed."""
        return (self.spec_accepted / self.spec_drafted
                if self.spec_drafted else float("nan"))


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 512, temperature: float = 0.0,
                 scheduler: Scheduler | None = None, replica_id: int = 0,
                 on_complete: Callable[[Request], None] | None = None,
                 seed_offset: int | None = None, paged: bool | None = None,
                 block_size: int = 16, num_blocks: int | None = None,
                 prefix_cache: bool = True, devstore=None,
                 kv_key: str | None = None,
                 kv_dtype: str | None = None,
                 token_budget: int | None = None,
                 spec_k: int = 0,
                 draft_source: DraftSource | None = None,
                 spill_pool=None,
                 preempt: bool = False,
                 mesh=None) -> None:
        self.cfg = cfg
        self.paged = supports_paged(cfg) if paged is None else paged
        if self.paged and not supports_paged(cfg):
            raise ValueError(f"config {cfg.name} cannot use the paged cache")
        # Mesh slice (tensor-parallel replica): params install sharded over
        # the slice per the logical-axis rules, and the unified tick compiles
        # against the slice's mesh.  Paged-only: the dense slot cache has no
        # leaf-axis story, and every config we serve sharded is paged anyway.
        self.mesh = mesh
        if mesh is not None:
            if not self.paged:
                raise ValueError(
                    "mesh slices shard the paged block pool; the dense cache "
                    "path only runs single-device (pass paged=True or a "
                    "config with supports_paged)")
            from repro.launch.sharding import param_shardings
            params = jax.device_put(params, param_shardings(cfg, mesh))
        self.params = params
        self.spec_k = int(spec_k)
        if self.spec_k < 0:
            raise ValueError(f"spec_k={spec_k} must be >= 0")
        if self.spec_k and (not self.paged or not supports_speculative(cfg)):
            raise ValueError(
                f"config {cfg.name} cannot decode speculatively: multi-token "
                f"verify rows and KV rollback need the paged path "
                f"(supports_speculative)")
        self.draft_source = (draft_source if draft_source is not None
                             else (default_draft_source() if self.spec_k
                                   else None))
        if self.paged:
            self.cm: Any = PagedCacheManager(
                cfg, n_slots, max_len, block_size=block_size,
                num_blocks=num_blocks, prefix_cache=prefix_cache,
                devstore=devstore, kv_key=kv_key, kv_dtype=kv_dtype,
                mesh=mesh)
            self.token_budget = (token_budget if token_budget is not None
                                 else max(32, 2 * n_slots))
            if self.token_budget < n_slots:
                raise ValueError(
                    f"token_budget={self.token_budget} < n_slots={n_slots}: "
                    f"every live decode row costs one token per tick, so a "
                    f"smaller budget would starve decodes")
        else:
            from repro.kernels.decode_attention.quant import is_quantized
            if is_quantized(kv_dtype):
                raise ValueError(
                    f"kv_dtype={kv_dtype!r} quantizes paged KV blocks; the "
                    f"dense slot cache has no block pool to quantize")
            self.cm = CacheManager(cfg, n_slots, max_len)
            self.token_budget = None
        # Preemption (opt-in, paged only): under pressure the tick may evict
        # one in-flight victim with a strictly later virtual deadline than
        # the best waiting request, spilling its KV through the one sync
        # site into ``spill_pool`` (core.store.SpillPool; parked entries
        # restore via adopt(), with prompt replay when the pool evicted
        # them).  Off by default: a non-preempting engine's tick stream and
        # sync accounting are bit-identical to before this feature existed.
        self.preempt = bool(preempt)
        self.spill_pool = spill_pool
        if self.preempt and not self.paged:
            raise ValueError("preemption spills paged KV blocks; the dense "
                             "path has no per-request blocks to spill")
        self.scheduler = scheduler or Scheduler(n_replicas=1)
        self.replica_id = replica_id
        self.temperature = temperature
        self.on_complete = on_complete
        self.stats = EngineStats()
        self.live: dict[int, Request] = {}         # slot → decoding request
        self.prefilling: dict[int, Request] = {}   # slot → mid-prompt request
        # fault-tolerance state (serving/faults + deployment failover):
        # ``faults`` is an injector seam bound by ModelDeployment
        # .install_faults; ``crashed`` makes tick/submit raise
        # ReplicaCrashed (set by an injected crash or the deployment's
        # mark_down, BEFORE evacuation, so racing submits bounce to a
        # sibling instead of landing in a drained queue).
        self.faults = None
        self.crashed = False
        self.kv_recoverable = True
        if self.paged:
            # host-side last emitted token per slot: the mixed tick composes
            # its packed batch on host, so no device token vector is needed
            self._last_host = np.zeros((n_slots,), np.int64)
        else:
            self._last_tokens = jnp.zeros((n_slots,), jnp.int32)
        # Sampling seed stream: one fresh seed per jitted dispatch, offset by
        # replica so same-tick dispatches / sibling replicas never share a
        # PRNG key.
        self._seed_base = (seed_offset if seed_offset is not None
                           else replica_id) * 1_000_003
        self._dispatches = 0

        temp = temperature

        def _sample(logits, seed):
            """Sample + score in-dispatch (models.sampling): (tokens (B,),
            scores (B, 2)) with scores = [log p(token), entropy], both from
            the same log-softmax the sampler needs anyway — cascade gates
            get their confidence signal without the host seeing logits."""
            return sample_with_scores(logits, seed, temp)

        # Paged mode donates the pool operand: the step scatters into every
        # layer's pool leaf, and without donation XLA must copy the whole
        # global block pool ((num_blocks, block_size, K, D) per layer) on
        # every dispatch — at realistic pool sizes that copy negates the
        # paging win.  Each dispatch replaces ``cm.pools`` with the returned
        # tree and ``publish()`` re-installs the fresh leaves.  Discipline:
        # between a dispatch and its publish() the devstore's /kv entry
        # aliases the donated (deleted) buffers, so KV reads through the
        # store must come from the tick thread (the engine's one-driver
        # model), never concurrently from another thread.
        #
        # The sampler side is speculative_verify over (R, spec_k+1) gathered
        # boundary logits: with spec_k == 0 every row has draft_len 0 and
        # the verify degenerates to plain sampling at position 0, so ONE
        # code path (and one compiled program) serves both modes.
        if self.paged:
            def _mixed(p, pools, bt, toks, pos, rows, sample_idx,
                       draft_toks, draft_len, seed):
                logits, pools = paged_mixed_step(p, pools, bt, toks, pos,
                                                 rows, sample_idx, cfg)
                tok, n_acc, score = speculative_verify(logits, draft_toks,
                                                       draft_len, seed, temp)
                return tok, n_acc, score, pools

            if mesh is not None:
                # Pin output shardings: XLA's propagation is free to pick a
                # different layout for the donated pool output, which would
                # break the devstore's exact-match donate gate and turn every
                # publish into a cross-device copy.  Tokens/scores replicate
                # (tiny vectors, pulled host-side each tick anyway).
                from jax.sharding import NamedSharding, PartitionSpec
                rep = NamedSharding(mesh, PartitionSpec())
                self._mixed = jax.jit(
                    _mixed, donate_argnums=(1,),
                    out_shardings=(rep, rep, rep, self.cm.pool_shardings))
            else:
                self._mixed = jax.jit(_mixed, donate_argnums=(1,))
        else:
            def _prefill_step(p, toks, pos, seed):
                logits, caches = prefill(p, toks, pos, cfg, max_len=max_len)
                tok, score = _sample(logits, seed)
                return tok, score, caches

            def _decode_tick(p, caches, toks, pos, active, seed):
                logits, new_caches = decode_step(p, caches, toks, pos, cfg)
                sampled, score = _sample(logits, seed)
                # masked decode: inactive slots keep their last token so stale
                # rows never feed garbage back into the next step
                return jnp.where(active, sampled, toks), score, new_caches

            self._prefill = jax.jit(_prefill_step)
            self._step = jax.jit(_decode_tick)

    # ------------------------------------------------------------- client
    def submit(self, req: Request) -> None:
        """Enqueue a request, or reject it up front through the completion
        path (``req.error`` set, ``on_complete`` fired, nothing enqueued)
        when it could never be served: an oversized request must not blow up
        mid-admission, and one whose worst-case block demand exceeds what the
        pool can EVER provide must not park at the head of the queue
        forever."""
        if self.crashed:
            raise ReplicaCrashed(
                f"replica {self.replica_id} is marked down")
        if self.faults is not None:
            self.faults.on_submit()          # may raise InjectedFault
        if req.expired():
            self._deadline_error(req, "admission")
            return
        req.prompt = self._norm_prompt(req.prompt)   # normalize ONCE: every
        err = self._validate(req)                    # later pass is a no-op
        if err is not None:
            self._reject(req, err)
            return
        self.scheduler.submit(req)

    def _validate(self, req: Request) -> str | None:
        S = len(self._norm_prompt(req.prompt))
        if S > self.cm.max_len:
            return f"prompt of {S} tokens exceeds max_len={self.cm.max_len}"
        if self.paged:
            # the paged pool has no ring fallback: a decode that reaches
            # max_len has no block to write and would kill the whole tick.
            # A replayed request's prompt carries replay_offset already-
            # generated tokens folded in, which max_new_tokens still counts
            # — subtract them so accounting matches the uninterrupted run.
            S_eff = S - req.replay_offset
            if self.cm.written_max(S_eff, req.max_new_tokens) > self.cm.max_len:
                return (f"prompt of {S} tokens + {req.max_new_tokens} new "
                        f"tokens would write past max_len={self.cm.max_len}")
            # with the pool drained and the prefix cache fully evicted, at
            # most num_blocks-1 blocks exist (block 0 is the null block)
            cap = self.cm.num_blocks - 1
            need = self._block_cost(req)
            if need > cap:
                return (f"request needs up to {need} KV blocks but the pool "
                        f"can ever provide {cap} (raise num_blocks or lower "
                        f"max_new_tokens)")
        return None

    def _reject(self, req: Request, err: str) -> None:
        req.error = err
        self._complete(req)

    def _deadline_error(self, req: Request, stage: str) -> None:
        """Expire a request through the completion path with a STRUCTURED
        reason (stage = where the budget ran out); partial tokens are kept —
        a deadline is a latency bound, not a correctness failure."""
        now = time.monotonic()
        self.stats.deadline_exceeded += 1
        req.error = {"error": "deadline_exceeded", "stage": stage,
                     "deadline_s": req.deadline_s,
                     "elapsed_s": now - req.arrived_s,
                     "request_id": req.request_id}
        self._complete(req)

    def _sweep_deadlines(self) -> None:
        """Per-tick deadline enforcement over every stage a request can be
        parked in: queued (never admitted), mid-prefill, and decoding.
        Runs at tick entry so an expired request never consumes another
        dispatch; slots are released with exact accounting (a decoding
        slot's written blocks are finished/cached — its KV is valid — and
        a prefilling slot's refs are dropped, trie residency untouched)."""
        now = time.monotonic()
        for req in self.scheduler.pop_expired(self.replica_id, now):
            self._deadline_error(req, "queued")
        for slot, req in list(self.prefilling.items()):
            if req.expired(now):
                self.prefilling.pop(slot)
                self.cm.release(slot)
                self._deadline_error(req, "prefill")
        for slot, req in list(self.live.items()):
            if req.expired(now):
                self.live.pop(slot)
                self._release_slot(slot, req)
                self._deadline_error(req, "decode")

    # ------------------------------------------------------------- engine
    def _next_seed(self) -> jnp.ndarray:
        self._dispatches += 1
        return jnp.int32(self._seed_base + self._dispatches)

    # lint: sync-site(THE one per-tick device->host pull)
    def _to_host(self, arr):
        """THE device→host sync point; everything host-side reads through
        here so tests/benchmarks can assert the one-sync-per-tick rule.
        Accepts any pytree — a (tokens, scores) tuple, a single array, or a
        spilled KV block tree — pulled in ONE blocking ``jax.device_get``:
        still a single sync per call."""
        self.stats.host_syncs += 1
        return jax.tree.map(np.asarray, jax.device_get(arr))

    @staticmethod
    def _norm_prompt(prompt) -> np.ndarray:
        """(S,) tokens or (S,d) embeds; squeeze a legacy leading batch dim."""
        p = np.asarray(prompt)
        if p.ndim >= 2 and p.shape[0] == 1:
            p = p[0]
        if np.issubdtype(p.dtype, np.integer) and p.dtype != np.int32:
            p = p.astype(np.int32)
        return p

    def _block_cost(self, req: Request) -> int:
        """Worst-case block footprint of a request (reuse only shrinks it).
        Replayed requests subtract ``replay_offset``: the folded tokens
        would have been written as decode feedbacks anyway, so the replayed
        footprint equals the uninterrupted one — exact accounting across a
        failover."""
        S = len(self._norm_prompt(req.prompt)) - req.replay_offset
        return self.cm.block_cost(S, req.max_new_tokens)

    def idle(self) -> bool:
        return (self.scheduler.pending(self.replica_id) == 0
                and not self.live and not self.prefilling)

    def backlog(self) -> int:
        """Requests this replica currently holds: queued + mid-prefill +
        decoding.  The admission-control signal bounded per-replica queues
        (serving/cluster.ModelDeployment) compare against their watermark."""
        return (self.scheduler.pending(self.replica_id)
                + len(self.prefilling) + len(self.live))

    # ==================================================== dense admission
    def _admit_dense(self) -> None:
        # Sweep expired entries IMMEDIATELY before batch admission (the
        # tick-entry sweep is not enough when admission is driven outside
        # tick(), e.g. run loops calling _admit_dense directly): a dead head
        # must never consume a free slot or a prefill-budget lane, and must
        # error out as deadline_exceeded rather than be served late.
        for req in self.scheduler.pop_expired(self.replica_id):
            self._deadline_error(req, "queued")
        free = self.cm.n_slots - self.cm.n_active
        reqs = self.scheduler.admit(self.replica_id, free)
        if not reqs:
            return
        for req in reqs:
            self._record_issue(req)
        # Batched multi-request prefill: batch CONTIGUOUS same-shape runs
        # (equal-length bucketing — no padding, so ring caches and SSM state
        # stay exact), one jitted prefill and ONE host pull per run.
        # Contiguity (not a shape→list dict) preserves admission order, so
        # a FIFO session's turns can never be prefilled out of order.
        groups: list[tuple[tuple, list[tuple[Request, np.ndarray]]]] = []
        for req in reqs:
            p = self._norm_prompt(req.prompt)
            if groups and groups[-1][0] == p.shape:
                groups[-1][1].append((req, p))
            else:
                groups.append((p.shape, [(req, p)]))
        for shape, group in groups:
            prompts = jnp.asarray(np.stack([p for _, p in group]))
            S = shape[0]
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                   (len(group), S))
            toks, scores, group_caches = self._prefill(self.params, prompts,
                                                       pos, self._next_seed())
            host_toks, host_scores = self._to_host((toks, scores))
            self.stats.prefill_batches += 1           # one sync per group
            now = time.monotonic()
            for row, (req, p) in enumerate(group):
                slot = self.cm.acquire(req.request_id)
                assert slot is not None
                self.cm.insert_prefill(slot, group_caches, S, row)
                self.stats.prompt_tokens += S
                self.stats.prefill_tokens += S
                self._finish_admission(req, slot, int(host_toks[row]), now,
                                       host_scores[row])

    def _finish_admission(self, req: Request, slot: int, tok: int,
                          now: float, score) -> None:
        self._last_tokens = self._last_tokens.at[slot].set(tok)
        self._emit_first_token(req, slot, tok, now, score)

    def _emit_first_token(self, req: Request, slot: int, tok: int,
                          now: float, score) -> None:
        """First-token bookkeeping shared by BOTH admission paths (dense
        batched prefill, mixed tick's finished chunks), so TTFT/prefill
        accounting can never drift between them.  ``score`` is the (2,)
        [logprob, entropy] row the in-dispatch sampler computed for ``tok``.
        """
        req.slot = slot
        req.tokens.append(tok)
        req.scores.append(float(score[0]))
        req.entropies.append(float(score[1]))
        if req.first_token_s is None:
            # a replayed (failed-over) request keeps its ORIGINAL first-token
            # time: re-prefilling on the sibling is recovery, not a prefill
            # the client observed twice
            req.first_token_s = now
            self.stats.ttft_s.append(now - req.arrived_s)
        self.stats.prefills += 1
        self.stats.tokens_out += 1
        if len(req.tokens) >= req.max_new_tokens:
            self._release_slot(slot, req)              # done at first token
            self._complete(req)
        else:
            self.live[slot] = req

    def _release_slot(self, slot: int, req: Request) -> None:
        if self.paged:
            # a replayed request's first replay_offset tokens were folded
            # into the prompt; only the rest are "generated" here, so the
            # trie caches each written position exactly once
            gen = (req.tokens[req.replay_offset:] if req.replay_offset
                   else req.tokens)
            self.cm.finish(slot, gen)
        else:
            self.cm.release(slot)

    def _complete(self, req: Request) -> None:
        req.done_s = time.monotonic()
        if self.spill_pool is not None:
            # a preempted request reaching ANY terminal state (done, expired
            # in queue, rejected) must not leak its parked KV
            self.spill_pool.discard(req.request_id)
        if self.on_complete is not None:
            self.on_complete(req)

    def _record_issue(self, req: Request) -> None:
        """Queue-wait bookkeeping at FIRST issue (slot granted): a preempted
        request keeps its original issue time — its wait was observed once."""
        if req.issued_s is None:
            req.issued_s = time.monotonic()
            self.stats.queue_wait_s.setdefault(req.slo, []).append(
                req.issued_s - req.arrived_s)

    # ================================================== unified paged tick
    def _pack_chunk(self, slot: int, toks: np.ndarray, pos: np.ndarray,
                    rows: np.ndarray, sample_idx: np.ndarray, n: int,
                    finished: list[int]) -> int:
        """Pack the next prompt chunk of ``slot`` into lanes [n, n+take) —
        at most the budget remainder — and commit newly covered full blocks
        to the trie so same-tick later admissions can share them."""
        seq = self.cm.slots[slot]
        take = min(self.token_budget - n, len(seq.prompt) - seq.prefill_pos)
        if take <= 0:
            return n
        start = seq.prefill_pos
        toks[n:n + take] = seq.prompt[start:start + take]
        pos[n:n + take] = np.arange(start, start + take, dtype=np.int32)
        rows[n:n + take] = slot
        n += take
        self.stats.prefill_tokens += take
        self.stats.prefill_chunks += 1
        if self.cm.commit_prefill_progress(slot, start + take):
            sample_idx[slot] = n - 1       # boundary: the last prompt token
            finished.append(slot)
        return n

    def _admit_mixed(self, toks: np.ndarray, pos: np.ndarray,
                     rows: np.ndarray, sample_idx: np.ndarray, n: int,
                     finished: list[int]) -> int:
        """Admit queue heads one at a time while budget and slots remain;
        each admission immediately packs its first chunk, so the per-token
        budget — not a request count — bounds this tick's prefill work."""
        free = self.cm.n_slots - self.cm.n_active
        while n < self.token_budget and free > 0:
            req = self.scheduler.admit_one(
                self.replica_id, free_slots=free,
                free_blocks=self.cm.available_for_admission(),
                block_cost=self._block_cost,
                max_blocks=self.cm.num_blocks - 1)
            if req is None:
                break
            if self.spill_pool is not None and req.tokens:
                # resume path: a preempted request re-issuing.  Restore its
                # parked KV via adopt (the slot decodes again from the NEXT
                # tick — this tick packs nothing for it, so no lane math
                # changes); when the pool evicted the entry, fall through to
                # prompt replay below.
                parked = self.spill_pool.unpark(req.request_id)
                if parked is not None and self.adopt(req, parked):
                    self.stats.resumes += 1
                    self._record_issue(req)
                    free -= 1
                    continue
            if len(req.tokens) > req.replay_offset:
                # preempted emissions whose parked KV is gone (evicted, or
                # adopt couldn't place it): fold them into the prompt so
                # replay-prefill reproduces the stream exactly
                if not req.fold_for_replay():
                    self._reject(req, "cannot replay preempted request: "
                                      "embeds prompt")
                    continue
            err = self._validate(req)
            if err is not None:
                # unservable request enqueued behind submit()'s back (e.g.
                # straight into the scheduler): reject it through the
                # completion path, keep admitting
                self._reject(req, err)
                continue
            p = self._norm_prompt(req.prompt)
            slot = self.cm.acquire(req.request_id)
            seq = (self.cm.begin(slot, p, req.max_new_tokens)
                   if slot is not None else None)
            if seq is None:
                # slot/block accounting drift: put the head back and retry
                # next tick — admitting younger arrivals now would reorder a
                # FIFO session's turns
                self.scheduler.requeue(self.replica_id, req)
                break
            if req.replay_offset:
                # begin() reserved for the folded prompt as if every token
                # were fresh; the replayed footprint is the uninterrupted
                # request's (see _block_cost) — correct it so admission
                # headroom stays exact across a failover
                seq.reserve = self._block_cost(req)
            self._record_issue(req)
            free -= 1
            self.stats.prompt_tokens += len(p)
            self.stats.prefix_hit_tokens += seq.reused
            if seq.reused:
                self.stats.prefix_hits += 1
            self.prefilling[slot] = req
            n = self._pack_chunk(slot, toks, pos, rows, sample_idx, n,
                                 finished)
        return n

    def _plan_drafts(self, decode_slots: list[int], lanes_left: int
                     ) -> dict[int, list[int]]:
        """Per live slot, the draft tokens to verify this tick.

        Token-budget audit: a speculative row packs 1 + len(drafts) tokens,
        so the old "every decode row costs exactly one token" arithmetic
        would oversubscribe the fixed packed shape.  Draft lanes are
        therefore granted LAST, from ``lanes_left`` — the lanes still idle
        after every live row's mandatory token AND all prefill chunk work
        has packed — so a k-token row can never exceed token_budget, never
        starves a sibling decode row of its mandatory lane, and never
        delays a waiting prefill (TTFT sees exactly the budget the
        non-speculative tick would give it; speculation only monetizes
        lanes that would have been pads).  Drafts are further capped at
        max_new - generated - 1: a fully-accepted row emits drafts + one
        bonus token, so this cap keeps every emission within max_new AND
        every draft KV write within ``written_max`` (the admission
        block-budget rule — speculation never writes a position plain
        decode would not eventually write)."""
        plans: dict[int, list[int]] = {}
        if not self.spec_k:
            return plans
        for slot in decode_slots:
            if lanes_left <= 0:
                break
            req = self.live[slot]
            room = req.max_new_tokens - len(req.tokens) - 1
            m = min(self.spec_k, room, lanes_left)
            if m <= 0:
                continue

            def history(req=req):
                # built only if a source asks (the cascade draft never does)
                return np.concatenate([self._norm_prompt(req.prompt),
                                       np.asarray(req.tokens, np.int64)])

            drafts = self.draft_source.propose(req, history, m)[:m]
            # keep only a valid prefix: one out-of-vocab guess invalidates
            # everything the drafter chained after it
            valid: list[int] = []
            for t in drafts:
                if not 0 <= int(t) < self.cfg.vocab_size:
                    break
                valid.append(int(t))
            if valid:
                plans[slot] = valid
                lanes_left -= len(valid)
        return plans

    # ------------------------------------------------- preemption (opt-in)
    def _maybe_preempt(self) -> None:
        """Tick-entry pressure check: when the best waiting request (the one
        the next issue would pick) cannot issue for lack of slots/blocks,
        evict AT MOST ONE in-flight victim whose virtual deadline is
        strictly later — EDF applied across the issue boundary.  One victim
        per tick keeps the policy damped (no convoys of spills from a
        single burst) and bounds the extra sync cost at one spill/tick."""
        waiter = self.scheduler.best_waiting(self.replica_id)
        if waiter is None:
            return
        need = self._block_cost(waiter)
        if need > self.cm.num_blocks - 1:
            return                    # unservable: the rejection path's job
        if (self.cm.n_slots - self.cm.n_active > 0
                and need <= self.cm.available_for_admission()):
            return                    # will issue normally this tick
        w_vdl = virtual_deadline(waiter)
        victim_slot, victim, v_vdl = None, None, w_vdl
        for slot, req in list(self.prefilling.items()) + list(self.live.items()):
            if req.session_key == waiter.session_key:
                continue              # same session: waiter can't overtake
            vdl = virtual_deadline(req)
            if vdl > v_vdl:
                victim_slot, victim, v_vdl = slot, req, vdl
        if victim is not None:
            self.preempt_slot(victim_slot, victim)

    def preempt_slot(self, slot: int, req: Request) -> None:
        """Evict one in-flight request and requeue it at the head of its
        queue (per-session order preserved — it is again the oldest waiting
        entry of its session).

        Mid-prefill victims release their blocks and replay from the prompt
        — nothing was emitted, so replay is exact and free.  Decoding
        victims spill their KV through the ONE sync site (counted in
        ``spill_syncs``: a preempting tick satisfies ``host_syncs == ticks
        + spill_syncs``; non-preempting ticks keep the strict equality) and
        park it in the spill pool; if the park fails — no pool, pool too
        small — the emissions fold into the prompt NOW so the eventual
        re-issue replays the stream bit-identically."""
        if slot in self.prefilling:
            self.prefilling.pop(slot)
            self.cm.release(slot)
        else:
            self.live.pop(slot)
            # no pool to park into → skip the spill entirely (and its sync):
            # the emissions fold for replay below, and host_syncs == ticks
            # stays strict on a pool-less preempting engine
            spilled = self.spill(slot) if self.spill_pool is not None else None
            self.cm.release(slot)
            parked = (spilled is not None
                      and self.spill_pool.park(req.request_id, spilled,
                                               spilled.n_blocks))
            if not parked:
                req.fold_for_replay()   # paged prompts are tokens: can't fail
        req.slot = None
        self.stats.preemptions += 1
        self.scheduler.requeue(self.replica_id, req)

    def _tick_mixed(self) -> int:
        """ONE fixed-shape mixed step: decode rows (each with up to spec_k
        verified draft tokens), + prefill chunks packed against the token
        budget, one dispatch, one host sync."""
        if self.preempt:
            self._maybe_preempt()
        T = self.token_budget
        K = self.spec_k
        toks = np.zeros(T, np.int32)
        pos = np.full(T, -1, np.int32)
        rows = np.full(T, -1, np.int32)
        sample_idx = np.zeros((self.cm.n_slots, K + 1), np.int32)
        draft_toks = np.zeros((self.cm.n_slots, K), np.int32)
        draft_len = np.zeros(self.cm.n_slots, np.int32)
        finished: list[int] = []
        n = 0
        decode_slots = list(self.live.keys())
        # 0. grow live rows' tables to cover the position each is about to
        #    write — BEFORE packing, while prefilling slots still sit at
        #    pos=0 (a chunk that completes its prompt this tick sets pos=S,
        #    but its first decode write is next tick's business); draft
        #    positions get their own ensure in step 4
        self.cm.ensure_decode_blocks()
        # 1. every live decode row costs one token (budget >= n_slots, so
        #    decodes can never be starved by prefill chunks)
        for slot in decode_slots:
            seq = self.cm.slots[slot]
            toks[n] = self._last_host[slot]
            pos[n] = seq.pos
            rows[n] = slot
            sample_idx[slot] = n                  # all entries → base lane
            n += 1
        # 2. continue partial prefills in admission order (FIFO turns stay
        #    ordered: an older request's chunks always pack first)
        for slot in list(self.prefilling):
            if n >= T:
                break
            n = self._pack_chunk(slot, toks, pos, rows, sample_idx, n,
                                 finished)
        # 3. admit new requests into the remainder
        n = self._admit_mixed(toks, pos, rows, sample_idx, n, finished)
        # 4. draft tokens fill the lanes NOTHING else wanted (they would
        #    have dispatched as pads): row slot's drafts verify positions
        #    pos+1..pos+m.  Lane order does not matter — the kernel masks
        #    by POSITION and writes all packed K/V before any read — so a
        #    row's draft lanes need not be contiguous with its base lane.
        plans = self._plan_drafts(decode_slots, T - n)
        if plans:
            # grow ONLY the planned rows: by now a slot whose prompt just
            # completed sits at pos = S, and growing it would claim a
            # decode block its admission budget never reserved
            self.cm.ensure_decode_blocks(
                {s: len(d) for s, d in plans.items()}, only=set(plans))
            for slot, drafts in plans.items():
                seq = self.cm.slots[slot]
                m = len(drafts)
                toks[n:n + m] = drafts
                pos[n:n + m] = np.arange(seq.pos + 1, seq.pos + 1 + m)
                rows[n:n + m] = slot
                sample_idx[slot, 1:1 + m] = np.arange(n, n + m)
                draft_toks[slot, :m] = drafts
                draft_len[slot] = m
                self.stats.spec_drafted += m
                n += m
        if n == 0:
            return 0          # idle: nothing dispatched, not a tick
        t0 = time.monotonic()
        bt = jnp.asarray(self.cm.block_tables())       # (n_slots, max_blocks)
        sampled, n_acc, scores, pools = self._mixed(
            self.params, self.cm.pools, bt, jnp.asarray(toks),
            jnp.asarray(pos), jnp.asarray(rows), jnp.asarray(sample_idx),
            jnp.asarray(draft_toks), jnp.asarray(draft_len),
            self._next_seed())
        self.cm.pools = pools
        self.cm.publish()
        self.stats.blocks_in_use = self.cm.blocks_in_use
        # the ONE sync of this tick: tokens + accept counts + scores in one
        # device_get — speculation amortizes it over every accepted token
        host_toks, host_acc, host_scores = self._to_host(
            (sampled, n_acc, scores))
        dt = time.monotonic() - t0
        now = time.monotonic()
        n_emitted = 0
        # 4. decode rows advance: the accepted draft prefix plus the
        #    correction/bonus token all land this tick
        for slot in decode_slots:
            req = self.live[slot]
            seq = self.cm.slots[slot]
            m = int(draft_len[slot])
            a = int(host_acc[slot])
            n_emit = a + 1
            for j in range(n_emit):
                req.tokens.append(int(host_toks[slot, j]))
                req.scores.append(float(host_scores[slot, j, 0]))
                req.entropies.append(float(host_scores[slot, j, 1]))
                self.stats.tpot_s.append(dt / n_emit)
            self._last_host[slot] = int(host_toks[slot, a])
            seq.pos += n_emit
            self.stats.tokens_out += n_emit
            n_emitted += n_emit
            if m:
                self.stats.spec_accepted += a
                if a < m:
                    # KV written for the rejected tail (positions >= the new
                    # seq.pos) is rolled back: table truncated, tail blocks
                    # freed, trie untouched (see kvcache.rollback_writes)
                    self.stats.spec_rolled_back += m - a
                    self.cm.rollback_writes(slot, seq.pos)
            if len(req.tokens) >= req.max_new_tokens:
                self.live.pop(slot)
                self._release_slot(slot, req)
                self._complete(req)
        # 5. chunks that completed their prompt emit their first token
        for slot in finished:
            req = self.prefilling.pop(slot)
            tok = int(host_toks[slot, 0])
            self._last_host[slot] = tok
            n_emitted += 1
            self._emit_first_token(req, slot, tok, now, host_scores[slot, 0])
        self.stats.ticks += 1
        if decode_slots:
            self.stats.decode_ticks += 1
        return n_emitted

    # ----------------------------------------------------- dense decode tick
    def _tick_dense(self) -> int:
        self._admit_dense()
        if not self.live:
            self.stats.ticks += 1
            return 0
        t0 = time.monotonic()
        positions = self.cm.positions()[:, None]               # (B,1)
        active = self.cm.active_mask()
        new_toks, step_scores, self.cm.caches = self._step(
            self.params, self.cm.caches, self._last_tokens, positions,
            active, self._next_seed())
        self._last_tokens = new_toks
        # the ONE sync of this tick: tokens + scores in one device_get
        host_toks, host_scores = self._to_host((new_toks, step_scores))
        self.cm.advance()
        dt = time.monotonic() - t0
        done = []
        n_emitted = 0
        for slot, req in list(self.live.items()):
            req.tokens.append(int(host_toks[slot]))
            req.scores.append(float(host_scores[slot, 0]))
            req.entropies.append(float(host_scores[slot, 1]))
            n_emitted += 1
            self.stats.tpot_s.append(dt)
            if len(req.tokens) >= req.max_new_tokens:
                done.append(slot)
        for slot in done:
            req = self.live.pop(slot)
            self._release_slot(slot, req)
            self._complete(req)
        self.stats.ticks += 1
        self.stats.decode_ticks += 1
        self.stats.tokens_out += n_emitted
        return n_emitted

    def tick(self) -> int:
        """One engine step.  Paged: one unified mixed dispatch (decode rows +
        prefill chunks).  Dense: admit prefills, then decode all live slots.

        Fault seams fire at tick ENTRY — before any dispatch — so the pool
        is never mid-donation when a fault lands: a crash raises
        ``ReplicaCrashed`` (the node marks the replica down and evacuates),
        a stall returns 0 without progress (only the deployment watchdog
        can see it), a slow tick sleeps then proceeds (deadlines, not
        failover, handle it).
        """
        if self.crashed:
            raise ReplicaCrashed(f"replica {self.replica_id} is marked down")
        if self.faults is not None:
            if self.faults.on_tick(self) == "stall":
                return 0
        self._sweep_deadlines()
        if self.paged:
            return self._tick_mixed()
        return self._tick_dense()

    # --------------------------------------- spill (failover + preemption)
    def spill(self, slot: int) -> SpilledKV | None:
        """Spill one live slot's KV blocks to host (driver thread): on a
        replica being marked down (failover), or on a preemption victim
        being evicted for a higher-priority waiter.  The device-side gather
        happens in the cache manager; the ONE host transfer goes through
        ``_to_host`` — the same sanctioned sync site as the tick pull — and
        is counted in ``spill_syncs`` so the invariant on a spilling
        replica is ``host_syncs == ticks + spill_syncs`` (replicas that
        never spill keep the strict ``host_syncs == ticks``)."""
        if not self.paged:
            return None
        seq = self.cm.slots[slot]
        if not seq.active or not seq.table:
            return None
        host_blocks = self._to_host(self.cm.spill_device(slot))
        self.stats.spill_syncs += 1
        self.stats.spilled_sessions += 1
        self.stats.spilled_blocks += len(seq.table)
        return SpilledKV(request_id=seq.request_id, pos=seq.pos,
                         n_blocks=len(seq.table),
                         block_size=self.cm.block_size, blocks=host_blocks)

    def evacuate(self, *, spill_kv: bool = True
                 ) -> tuple[list[Request], list[tuple[Request, Any]]]:
        """Empty a dead replica (driver thread only, after ``crashed`` is
        set so racing submits bounce): queued requests pop for plain
        resubmission; mid-prefill requests release their blocks (replay is
        exact — nothing was emitted); live requests spill their KV when
        ``spill_kv`` (else, or on spill failure, they re-home as replays).
        Every slot is released here, so the allocator ends exactly where a
        normal drain would leave it.  Returns (queued, [(req, spilled)])."""
        queued = self.scheduler.drain(self.replica_id)
        inflight: list[tuple[Request, Any]] = []
        for slot, req in list(self.prefilling.items()):
            self.prefilling.pop(slot)
            self.cm.release(slot)
            inflight.append((req, None))
        for slot, req in list(self.live.items()):
            self.live.pop(slot)
            spilled = None
            if spill_kv:
                try:
                    spilled = self.spill(slot)
                except Exception:
                    spilled = None       # unrecoverable KV: replay instead
            self.cm.release(slot)
            inflight.append((req, spilled))
        return queued, inflight

    def adopt(self, req: Request, spilled: SpilledKV | None) -> bool:
        """Restore a sibling's spilled session into this replica: allocate
        fresh blocks, scatter the migrated KV in, resume decoding at the
        spilled position — the client-visible stream continues exactly
        where the dead replica left it (greedy decoding is bit-identical
        to the uninterrupted run).  False (nothing allocated) when this
        replica can't host it; the caller falls back to prompt replay."""
        if (not self.paged or self.crashed or spilled is None
                or not req.tokens):
            return False
        slot = self.cm.acquire(req.request_id)
        if slot is None:
            return False
        seq = self.cm.adopt(slot, self._norm_prompt(req.prompt), spilled,
                            req.max_new_tokens)
        if seq is None:
            return False                 # cm.adopt released the slot
        self._last_host[slot] = int(req.tokens[-1])
        req.slot = slot
        self.live[slot] = req
        self.stats.adopted_sessions += 1
        return True

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if self.idle():
                return
            self.tick()
        raise TimeoutError("engine did not drain")

"""The serving engine: Cascade hosting applied to LM inference.

One engine replica = one DFG vertex (a lambda bound to /serve/<name>) whose
"computation" is prefill+decode over a model whose weights live in the
replica's device store — data/compute collocation: requests (small objects)
move to the weights (the largest dependency), never the reverse (§2, §3.5).

Continuous batching: a fixed pool of KV slots; each engine tick decodes all
active slots in ONE jitted step (the fast path — no host round-trips between
stages), then admits waiting prefills into freed slots.  Prefill is its own
jitted program; splice into the slot is device-side.

The engine also exposes the Cascade put/latency ladder for benchmarks:
``step_fused`` counts one host dispatch per tick regardless of batch size.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pools import DispatchPolicy
from repro.models import decode_step, prefill
from repro.models.config import ModelConfig

from .kvcache import CacheManager
from .scheduler import Request, Scheduler


@dataclass
class EngineStats:
    ticks: int = 0
    tokens_out: int = 0
    prefills: int = 0
    ttft_s: list = field(default_factory=list)     # time to first token
    tpot_s: list = field(default_factory=list)     # time per output token


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 512, temperature: float = 0.0,
                 scheduler: Scheduler | None = None, replica_id: int = 0) -> None:
        self.cfg = cfg
        self.params = params
        self.cm = CacheManager(cfg, n_slots, max_len)
        self.scheduler = scheduler or Scheduler(n_replicas=1)
        self.replica_id = replica_id
        self.temperature = temperature
        self.stats = EngineStats()
        self.live: dict[int, Request] = {}
        self._last_tokens = jnp.zeros((n_slots,), jnp.int32)

        self._prefill = jax.jit(
            lambda p, toks, pos: prefill(p, toks, pos, cfg, max_len=max_len))
        self._decode = jax.jit(
            lambda p, caches, toks, pos: decode_step(p, caches, toks, pos, cfg))

    # ------------------------------------------------------------- client
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    # ------------------------------------------------------------- engine
    def _admit(self) -> None:
        free = self.cm.n_slots - self.cm.n_active
        for req in self.scheduler.admit(self.replica_id, free):
            slot = self.cm.acquire(req.request_id)
            assert slot is not None
            prompt = jnp.asarray(req.prompt)
            if prompt.ndim == 1:
                prompt = prompt[None, :]
            S = prompt.shape[1]
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (1, S))
            logits, one_caches = self._prefill(self.params, prompt, pos)
            self.cm.insert_prefill(slot, one_caches, S)
            tok = self._sample(logits)
            req.slot = slot
            req.tokens.append(int(tok[0]))
            req.first_token_s = time.monotonic()
            self.stats.ttft_s.append(req.first_token_s - req.arrived_s)
            self.stats.prefills += 1
            self.stats.tokens_out += 1
            self.live[slot] = req
            self._last_tokens = self._last_tokens.at[slot].set(tok[0])

    def _sample(self, logits) -> jnp.ndarray:
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        key = jax.random.PRNGKey(self.stats.ticks)
        return jax.random.categorical(key, logits / self.temperature).astype(jnp.int32)

    def tick(self) -> int:
        """One engine step: admit prefills, decode all active slots."""
        self._admit()
        if not self.live:
            self.stats.ticks += 1
            return 0
        t0 = time.monotonic()
        positions = self.cm.positions()[:, None]               # (B,1)
        toks = self._last_tokens
        logits, self.cm.caches = self._decode(self.params, self.cm.caches,
                                              toks, positions)
        new_toks = self._sample(logits)
        self._last_tokens = new_toks
        self.cm.advance()
        dt = time.monotonic() - t0
        done = []
        n_emitted = 0
        for slot, req in list(self.live.items()):
            req.tokens.append(int(new_toks[slot]))
            n_emitted += 1
            self.stats.tpot_s.append(dt)
            if len(req.tokens) >= req.max_new_tokens:
                req.done_s = time.monotonic()
                done.append(slot)
        for slot in done:
            self.cm.release(slot)
            del self.live[slot]
        self.stats.ticks += 1
        self.stats.tokens_out += n_emitted
        return n_emitted

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            pending = self.scheduler.pending(self.replica_id)
            if not pending and not self.live:
                return
            self.tick()
        raise TimeoutError("engine did not drain")

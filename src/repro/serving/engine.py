"""The serving engine: Cascade hosting applied to LM inference.

One engine replica = one DFG vertex (a lambda bound to /serve/<name>) whose
"computation" is prefill+decode over a model whose weights live in the
replica's device store — data/compute collocation: requests (small objects)
move to the weights (the largest dependency), never the reverse (§2, §3.5).

Continuous batching: a fixed pool of KV slots; each engine tick decodes all
active slots in ONE jitted step (the fast path — no host round-trips between
stages), then admits waiting prefills into freed slots.

Fast-path discipline inside the tick:

- **Batched prefill admission** — requests admitted in the same tick are
  batched over contiguous same-shape runs (admission order preserved) and
  each run executes ONE jitted prefill with B=k (no padding, so the path is
  safe for ring caches and SSM state alike); each row is spliced into its
  KV slot device-side.
- **Masked decode** — sampling is fused into the jitted decode step and
  inactive slots are masked there, so garbage rows never leak into
  ``_last_tokens`` and the host sees a single ready-to-read token vector.
- **One device→host transfer per tick** — the decode step's new tokens are
  pulled once via ``np.asarray`` (``stats.host_syncs`` counts every pull;
  one per decode tick plus one per prefill group, never per slot).

Paged mode (default for pure-attention token models, see
``models.supports_paged``): KV lives in a global block pool with per-request
block tables and a per-replica prefix cache (kvcache.PagedCacheManager).
Admission matches each prompt against the trie of cached token blocks and
prefills ONLY the suffix past the last matched block — the reused prefix's
KV is attended to through the block table without being recomputed
(``stats.prefix_hit_tokens`` counts the skipped tokens, so warm multi-turn
sessions show strictly fewer prefill FLOPs).  Suffix-length grouping
replaces full-prompt-shape grouping; the tick discipline above is unchanged.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (decode_step, paged_decode_step, paged_prefill,
                          prefill, supports_paged)
from repro.models.config import ModelConfig

from .kvcache import CacheManager, PagedCacheManager
from .scheduler import Request, Scheduler


@dataclass
class EngineStats:
    ticks: int = 0
    tokens_out: int = 0
    prefills: int = 0
    prefill_batches: int = 0                       # jitted prefill dispatches
    decode_ticks: int = 0                          # ticks that ran a decode
    host_syncs: int = 0                            # device→host transfers
    prompt_tokens: int = 0                         # total prompt tokens seen
    prefill_tokens: int = 0                        # tokens actually prefilled
    prefix_hit_tokens: int = 0                     # tokens reused from cache
    prefix_hits: int = 0                           # requests with a hit
    blocks_in_use: int = 0                         # gauge, sampled per tick
    ttft_s: list = field(default_factory=list)     # time to first token
    tpot_s: list = field(default_factory=list)     # time per output token


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 8,
                 max_len: int = 512, temperature: float = 0.0,
                 scheduler: Scheduler | None = None, replica_id: int = 0,
                 on_complete: Callable[[Request], None] | None = None,
                 seed_offset: int | None = None, paged: bool | None = None,
                 block_size: int = 16, num_blocks: int | None = None,
                 prefix_cache: bool = True, devstore=None,
                 kv_key: str | None = None) -> None:
        self.cfg = cfg
        self.params = params
        self.paged = supports_paged(cfg) if paged is None else paged
        if self.paged and not supports_paged(cfg):
            raise ValueError(f"config {cfg.name} cannot use the paged cache")
        if self.paged:
            self.cm: Any = PagedCacheManager(
                cfg, n_slots, max_len, block_size=block_size,
                num_blocks=num_blocks, prefix_cache=prefix_cache,
                devstore=devstore, kv_key=kv_key)
        else:
            self.cm = CacheManager(cfg, n_slots, max_len)
        self.scheduler = scheduler or Scheduler(n_replicas=1)
        self.replica_id = replica_id
        self.temperature = temperature
        self.on_complete = on_complete
        self.stats = EngineStats()
        self.live: dict[int, Request] = {}
        self._last_tokens = jnp.zeros((n_slots,), jnp.int32)
        # Sampling seed stream: one fresh seed per jitted dispatch, offset by
        # replica so same-tick prefill groups / decode steps / sibling
        # replicas never share a PRNG key.
        self._seed_base = (seed_offset if seed_offset is not None
                           else replica_id) * 1_000_003
        self._dispatches = 0

        temp = temperature

        def _sample(logits, seed):
            if temp <= 0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            key = jax.random.PRNGKey(seed)
            return jax.random.categorical(key, logits / temp).astype(jnp.int32)

        if self.paged:
            def _prefill_step(p, pools, bt, toks, pos, seed):
                logits, pools = paged_prefill(p, pools, bt, toks, pos, cfg)
                return _sample(logits, seed), pools

            def _decode_tick(p, pools, bt, toks, pos, active, seed):
                logits, pools = paged_decode_step(p, pools, bt, toks, pos, cfg)
                sampled = _sample(logits, seed)
                return jnp.where(active, sampled, toks), pools
        else:
            def _prefill_step(p, toks, pos, seed):
                logits, caches = prefill(p, toks, pos, cfg, max_len=max_len)
                return _sample(logits, seed), caches

            def _decode_tick(p, caches, toks, pos, active, seed):
                logits, new_caches = decode_step(p, caches, toks, pos, cfg)
                sampled = _sample(logits, seed)
                # masked decode: inactive slots keep their last token so stale
                # rows never feed garbage back into the next step
                return jnp.where(active, sampled, toks), new_caches

        # Paged mode donates the pool operand: decode scatters into every
        # layer's pool leaf, and without donation XLA must copy the whole
        # global block pool ((num_blocks, block_size, K, D) per layer) on
        # every dispatch — at realistic pool sizes that copy negates the
        # paging win.  Each dispatch replaces ``cm.pools`` with the returned
        # tree and ``publish()`` re-installs the fresh leaves.  Discipline:
        # between a dispatch and its publish() the devstore's /kv entry
        # aliases the donated (deleted) buffers, so KV reads through the
        # store must come from the tick thread (the engine's one-driver
        # model), never concurrently from another thread.
        donate = (1,) if self.paged else ()
        self._prefill = jax.jit(_prefill_step, donate_argnums=donate)
        self._step = jax.jit(_decode_tick, donate_argnums=donate)

    # ------------------------------------------------------------- client
    def submit(self, req: Request) -> None:
        """Enqueue a request, or reject it up front through the completion
        path (``req.error`` set, ``on_complete`` fired, nothing enqueued)
        when it could never be served: an oversized request must not blow up
        mid-admission batch, and one whose worst-case block demand exceeds
        what the pool can EVER provide must not park at the head of the
        queue forever."""
        req.prompt = self._norm_prompt(req.prompt)   # normalize ONCE: every
        err = self._validate(req)                    # later pass is a no-op
        if err is not None:
            self._reject(req, err)
            return
        self.scheduler.submit(req)

    def _validate(self, req: Request) -> str | None:
        S = len(self._norm_prompt(req.prompt))
        if S > self.cm.max_len:
            return f"prompt of {S} tokens exceeds max_len={self.cm.max_len}"
        if self.paged:
            # the paged pool has no ring fallback: a decode that reaches
            # max_len has no block to write and would kill the whole tick
            if self.cm.written_max(S, req.max_new_tokens) > self.cm.max_len:
                return (f"prompt of {S} tokens + {req.max_new_tokens} new "
                        f"tokens would write past max_len={self.cm.max_len}")
            # with the pool drained and the prefix cache fully evicted, at
            # most num_blocks-1 blocks exist (block 0 is the null block)
            cap = self.cm.num_blocks - 1
            need = self._block_cost(req)
            if need > cap:
                return (f"request needs up to {need} KV blocks but the pool "
                        f"can ever provide {cap} (raise num_blocks or lower "
                        f"max_new_tokens)")
        return None

    def _reject(self, req: Request, err: str) -> None:
        req.error = err
        self._complete(req)

    # ------------------------------------------------------------- engine
    def _next_seed(self) -> jnp.ndarray:
        self._dispatches += 1
        return jnp.int32(self._seed_base + self._dispatches)

    def _to_host(self, arr) -> np.ndarray:
        """THE device→host sync point; everything host-side reads through
        here so tests/benchmarks can assert the one-transfer-per-tick rule."""
        self.stats.host_syncs += 1
        return np.asarray(arr)

    @staticmethod
    def _norm_prompt(prompt) -> np.ndarray:
        """(S,) tokens or (S,d) embeds; squeeze a legacy leading batch dim."""
        p = np.asarray(prompt)
        if p.ndim >= 2 and p.shape[0] == 1:
            p = p[0]
        if np.issubdtype(p.dtype, np.integer) and p.dtype != np.int32:
            p = p.astype(np.int32)
        return p

    def _block_cost(self, req: Request) -> int:
        """Worst-case block footprint of a request (reuse only shrinks it)."""
        S = len(self._norm_prompt(req.prompt))
        return self.cm.block_cost(S, req.max_new_tokens)

    def _admit(self) -> None:
        free = self.cm.n_slots - self.cm.n_active
        if self.paged:
            reqs = self.scheduler.admit(
                self.replica_id, free,
                free_blocks=self.cm.available_for_admission(),
                block_cost=self._block_cost,
                max_blocks=self.cm.num_blocks - 1)
            self._admit_paged(reqs)
        else:
            reqs = self.scheduler.admit(self.replica_id, free)
            self._admit_dense(reqs)

    def _admit_dense(self, reqs: list[Request]) -> None:
        if not reqs:
            return
        # Batched multi-request prefill: batch CONTIGUOUS same-shape runs
        # (equal-length bucketing — no padding, so ring caches and SSM state
        # stay exact), one jitted prefill and ONE host pull per run.
        # Contiguity (not a shape→list dict) preserves admission order, so
        # a FIFO session's turns can never be prefilled out of order.
        groups: list[tuple[tuple, list[tuple[Request, np.ndarray]]]] = []
        for req in reqs:
            p = self._norm_prompt(req.prompt)
            if groups and groups[-1][0] == p.shape:
                groups[-1][1].append((req, p))
            else:
                groups.append((p.shape, [(req, p)]))
        for shape, group in groups:
            prompts = jnp.asarray(np.stack([p for _, p in group]))
            S = shape[0]
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                   (len(group), S))
            toks, group_caches = self._prefill(self.params, prompts, pos,
                                               self._next_seed())
            host_toks = self._to_host(toks)            # one sync per group
            self.stats.prefill_batches += 1
            now = time.monotonic()
            for row, (req, p) in enumerate(group):
                slot = self.cm.acquire(req.request_id)
                assert slot is not None
                self.cm.insert_prefill(slot, group_caches, S, row)
                self.stats.prompt_tokens += S
                self.stats.prefill_tokens += S
                self._finish_admission(req, slot, int(host_toks[row]), now)

    def _admit_paged(self, reqs: list[Request]) -> None:
        if not reqs:
            return
        # Same contiguous-run batching, but grouped by SUFFIX length: rows
        # with different prompt lengths batch together as long as the token
        # count left after prefix reuse matches (positions are per-row).
        groups: list[tuple[int, list[tuple[Request, np.ndarray, int]]]] = []
        for i, req in enumerate(reqs):
            err = self._validate(req)
            if err is not None:
                # unservable request enqueued behind submit()'s back (e.g.
                # straight into the scheduler): fail it alone, keep the batch
                self._reject(req, err)
                continue
            p = self._norm_prompt(req.prompt)
            slot = self.cm.acquire(req.request_id)
            seq = (self.cm.begin(slot, p, req.max_new_tokens)
                   if slot is not None else None)
            if seq is None:
                # slot/block accounting drift (begin released the slot): put
                # this and every not-yet-begun request back at the HEAD of
                # the queue in order — admitting later arrivals now would
                # reorder a FIFO session's turns — and retry next tick
                for r in reversed(reqs[i:]):
                    self.scheduler.requeue(self.replica_id, r)
                break
            suffix_len = len(p) - seq.reused
            self.stats.prompt_tokens += len(p)
            self.stats.prefill_tokens += suffix_len
            self.stats.prefix_hit_tokens += seq.reused
            if seq.reused:
                self.stats.prefix_hits += 1
            if groups and groups[-1][0] == suffix_len:
                groups[-1][1].append((req, p, slot))
            else:
                groups.append((suffix_len, [(req, p, slot)]))
        for suffix_len, group in groups:
            rows = [slot for _, _, slot in group]
            starts = [self.cm.slots[s].reused for s in rows]
            prompts = jnp.asarray(np.stack(
                [p[L:] for (_, p, _), L in zip(group, starts)]))
            pos = jnp.asarray(np.stack(
                [L + np.arange(suffix_len, dtype=np.int32) for L in starts]))
            bt = jnp.asarray(self.cm.block_tables(rows))
            toks, pools = self._prefill(self.params, self.cm.pools, bt,
                                        prompts, pos, self._next_seed())
            self.cm.pools = pools
            host_toks = self._to_host(toks)            # one sync per group
            self.stats.prefill_batches += 1
            now = time.monotonic()
            for row, (req, p, slot) in enumerate(group):
                # prefill K/V for this group is committed before any LATER
                # group reads the pool, so its blocks are safe to share now
                self.cm.commit_prompt(slot)
                self._finish_admission(req, slot, int(host_toks[row]), now)
        self.cm.publish()

    def _finish_admission(self, req: Request, slot: int, tok: int,
                          now: float) -> None:
        req.slot = slot
        req.tokens.append(tok)
        req.first_token_s = now
        self.stats.ttft_s.append(now - req.arrived_s)
        self.stats.prefills += 1
        self.stats.tokens_out += 1
        self._last_tokens = self._last_tokens.at[slot].set(tok)
        if len(req.tokens) >= req.max_new_tokens:
            self._release_slot(slot, req)              # done at first token
            self._complete(req)
        else:
            self.live[slot] = req

    def _release_slot(self, slot: int, req: Request) -> None:
        if self.paged:
            self.cm.finish(slot, req.tokens)
        else:
            self.cm.release(slot)

    def _complete(self, req: Request) -> None:
        req.done_s = time.monotonic()
        if self.on_complete is not None:
            self.on_complete(req)

    def tick(self) -> int:
        """One engine step: admit prefills, decode all active slots."""
        self._admit()
        if not self.live:
            self.stats.ticks += 1
            return 0
        t0 = time.monotonic()
        positions = self.cm.positions()[:, None]               # (B,1)
        active = self.cm.active_mask()
        if self.paged:
            self.cm.ensure_decode_blocks()
            bt = jnp.asarray(self.cm.block_tables())
            new_toks, pools = self._step(
                self.params, self.cm.pools, bt, self._last_tokens, positions,
                active, self._next_seed())
            self.cm.pools = pools
            self.cm.publish()
            self.stats.blocks_in_use = self.cm.blocks_in_use
        else:
            new_toks, self.cm.caches = self._step(
                self.params, self.cm.caches, self._last_tokens, positions,
                active, self._next_seed())
        self._last_tokens = new_toks
        host_toks = self._to_host(new_toks)       # the ONE sync of this tick
        self.cm.advance()
        dt = time.monotonic() - t0
        done = []
        n_emitted = 0
        for slot, req in list(self.live.items()):
            req.tokens.append(int(host_toks[slot]))
            n_emitted += 1
            self.stats.tpot_s.append(dt)
            if len(req.tokens) >= req.max_new_tokens:
                done.append(slot)
        for slot in done:
            req = self.live.pop(slot)
            self._release_slot(slot, req)
            self._complete(req)
        self.stats.ticks += 1
        self.stats.decode_ticks += 1
        self.stats.tokens_out += n_emitted
        return n_emitted

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            pending = self.scheduler.pending(self.replica_id)
            if not pending and not self.live:
                return
            self.tick()
        raise TimeoutError("engine did not drain")

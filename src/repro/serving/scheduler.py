"""Request scheduler = the Cascade dispatcher applied to serving (§3.3, §3.5).

Requests are objects put to the engine's request pool; the scheduler is the
dispatcher's policy layer: ROUND_ROBIN spreads requests across engine
replicas (load balancing), FIFO pins a session key (e.g. one chat session /
one camera) to a single replica so its turns stay ordered — the same two
policies, verbatim, as the paper's upcall dispatch.  (In the multi-tenant
``ServeNode`` each replica engine runs its own single-replica scheduler and
replica selection happens one level up, at the store's trigger-put member
pick; ``pending`` feeds the deployment's bounded-admission queue depth.)

A completed ``Request`` carries per-token scores — log p(token) and
next-token entropy, surfaced by the engine's in-dispatch sampler — which
cascade gates (``serving.cluster.CascadeRoute``) read to decide light→heavy
escalation.

Admission: waiting requests are admitted to free KV slots oldest-first
(continuous batching).  The dense engine admits in batches (``admit``): an
optional `prefill_budget` bounds how many prefills are spliced per decode
step so long prompts cannot starve decodes — the paper's "latency floor
under load" discipline applied to token serving.  The paged engine's
unified token-budget tick instead admits one head at a time (``admit_one``)
while it packs the tick's token budget: each admission interleaves with the
engine's begin/pack/commit, so the per-TOKEN budget — not a per-request
count — is what bounds prefill work per tick.  ``admit_one`` also takes the
per-request *block* budget: admission stops before the pool's
free+evictable blocks are oversubscribed, counting each candidate's
worst-case footprint (prefix reuse only makes the realized footprint
smaller, so the bound is safe).

Token-budget arithmetic with speculative decoding: a decode row is NOT
always one token — a speculative row feeds 1 + k tokens (its last committed
token plus k verified drafts).  The engine grants draft lanes LAST, after
every live row's mandatory lane and all prefill chunk packing
(engine._plan_drafts), so the budget remainder ``admit_one`` packs prefill
chunks into is exactly what a non-speculative tick would offer and can
never be oversubscribed by a k-token row.
"""
from __future__ import annotations

import time
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Any

from repro.core.pools import DispatchPolicy


@dataclass
class Request:
    request_id: str
    session_key: str
    prompt: Any                     # token array (1, S) or embeds (1, S, d)
    max_new_tokens: int = 16
    # latency budget, seconds RELATIVE to arrived_s (None = no deadline).
    # Enforced at engine admission, per tick (engine._sweep_deadlines), and
    # at the CascadeRoute boundary: an expired request completes with a
    # structured {"error": "deadline_exceeded", ...} — never a hang.
    deadline_s: float | None = None
    # optional draft stream for speculative decoding: token i is a guess for
    # generated token i (e.g. a CascadeRoute plants the LIGHT deployment's
    # generation here when escalating to heavy, so the heavy engine verifies
    # the light tokens k at a time instead of re-deriving them one per tick)
    draft_tokens: Any = None
    arrived_s: float = field(default_factory=time.monotonic)
    # engine-filled:
    slot: int | None = None
    tokens: list[int] = field(default_factory=list)
    # failover replay: how many leading entries of ``tokens`` were folded
    # into ``prompt`` for replay-prefill on a sibling replica.  Block/write
    # accounting subtracts it (the folded tokens were going to be written
    # as decode feedbacks anyway), and completion caches only
    # ``tokens[replay_offset:]`` as generated — so a replayed request's
    # allocator footprint is exactly the uninterrupted request's.
    replay_offset: int = 0
    # per-token scores, surfaced from the SAME in-dispatch sampler that
    # picked the token (no extra device→host traffic): log p(token) under
    # the model, and the full next-token distribution's entropy.  Cascade
    # gates (escalate-to-heavy decisions) read these.
    scores: list[float] = field(default_factory=list)      # log p(tok_i)
    entropies: list[float] = field(default_factory=list)   # H(p_i), nats
    first_token_s: float | None = None
    done_s: float | None = None
    # engine rejections set a string; admission sheds set a structured dict
    # ({"error": "shed_overload", "replica": ..., "depth": ..., ...})
    error: str | dict | None = None

    def mean_logprob(self) -> float:
        """Mean per-token log-likelihood of the generation — the CascadeServe
        confidence signal (low = the light model is guessing)."""
        return (sum(self.scores) / len(self.scores)) if self.scores \
            else float("-inf")

    def mean_entropy(self) -> float:
        """Mean next-token distribution entropy (high = uncertain)."""
        return (sum(self.entropies) / len(self.entropies)) if self.entropies \
            else float("inf")

    # ------------------------------------------------------------ deadlines
    def elapsed(self, now: float | None = None) -> float:
        return (time.monotonic() if now is None else now) - self.arrived_s

    def expired(self, now: float | None = None) -> bool:
        return (self.deadline_s is not None
                and self.elapsed(now) > self.deadline_s)

    def remaining(self, now: float | None = None) -> float | None:
        """Budget left, or None when the request carries no deadline."""
        if self.deadline_s is None:
            return None
        return self.deadline_s - self.elapsed(now)


class Scheduler:
    def __init__(self, *, policy: DispatchPolicy = DispatchPolicy.ROUND_ROBIN,
                 n_replicas: int = 1, prefill_budget: int = 2) -> None:
        self.policy = policy
        self.n_replicas = n_replicas
        self.prefill_budget = prefill_budget
        self.waiting: list[deque[Request]] = [deque() for _ in range(n_replicas)]
        self._rr = 0

    def submit(self, req: Request) -> int:
        """Route a request to a replica per the dispatch policy."""
        if self.policy is DispatchPolicy.FIFO:
            r = zlib.crc32(req.session_key.encode()) % self.n_replicas
        else:
            r = self._rr % self.n_replicas
            self._rr += 1
        self.waiting[r].append(req)
        return r

    def admit(self, replica: int, free_slots: int) -> list[Request]:
        """Oldest-first batch admission (dense engines), bounded by free
        slots and the per-tick prefill budget."""
        out = []
        q = self.waiting[replica]
        while q and len(out) < min(free_slots, self.prefill_budget):
            out.append(q.popleft())
        return out

    def admit_one(self, replica: int, *, free_slots: int,
                  free_blocks: int | None = None, block_cost: Any = None,
                  max_blocks: int | None = None) -> Request | None:
        """Pop the queue HEAD if it fits ``free_slots``/``free_blocks``, else
        None — admission is head-of-line (a too-big head blocks the queue
        rather than starving while smaller latecomers leapfrog it).  A head
        whose demand exceeds ``max_blocks`` — the pool's ABSOLUTE capacity,
        never attainable even fully drained — is popped through anyway so
        the engine's admission validation can reject it via the completion
        path; without that escape hatch it would stall the queue forever.
        (Engine ``submit`` already rejects such requests up front; this
        covers requests enqueued directly into the scheduler.)

        The paged engine's unified tick calls this in a loop while packing
        its token budget, so block accounting is re-read between admissions
        (each ``begin`` changes what is available)."""
        q = self.waiting[replica]
        if not q or free_slots <= 0:
            return None
        if free_blocks is not None and block_cost is not None:
            need = block_cost(q[0])
            if (max_blocks is None or need <= max_blocks) and need > free_blocks:
                return None
        return q.popleft()

    def requeue(self, replica: int, req: Request) -> None:
        """Return an admitted-but-unplaced request to the HEAD of its queue
        (oldest-first order is preserved when callers requeue a contiguous
        admitted run in reverse)."""
        self.waiting[replica].appendleft(req)

    def pop_expired(self, replica: int, now: float | None = None
                    ) -> list[Request]:
        """Remove and return every queued request whose deadline has passed.

        Pop-rotates IN PLACE (pop each element once, append keepers back)
        rather than rebuilding the deque: an upcall thread may be appending
        concurrently, and a replacement deque would silently drop its
        arrival.  Relative order of the keepers is preserved."""
        q = self.waiting[replica]
        now = time.monotonic() if now is None else now
        expired: list[Request] = []
        for _ in range(len(q)):
            req = q.popleft()
            (expired if req.expired(now) else q).append(req)
        return expired

    def drain(self, replica: int) -> list[Request]:
        """Pop every queued request (replica evacuation on mark-down).
        Same in-place pop discipline as ``pop_expired``: a concurrent
        submit's append is either drained or survives for the sweep."""
        q = self.waiting[replica]
        out: list[Request] = []
        for _ in range(len(q)):
            out.append(q.popleft())
        return out

    def pending(self, replica: int) -> int:
        return len(self.waiting[replica])

"""Request scheduler = the Cascade dispatcher applied to serving (§3.3, §3.5).

Requests are objects put to the engine's request pool; the scheduler is the
dispatcher's policy layer: ROUND_ROBIN spreads requests across engine
replicas (load balancing), FIFO pins a session key (e.g. one chat session /
one camera) to a single replica so its turns stay ordered — the same two
policies, verbatim, as the paper's upcall dispatch.

Admission: waiting requests are admitted to free KV slots oldest-first
(continuous batching); an optional `prefill_budget` bounds how many prefills
are spliced per decode step so long prompts cannot starve decodes — the
paper's "latency floor under load" discipline applied to token serving.
"""
from __future__ import annotations

import time
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Any

from repro.core.pools import DispatchPolicy


@dataclass
class Request:
    request_id: str
    session_key: str
    prompt: Any                     # token array (1, S) or embeds (1, S, d)
    max_new_tokens: int = 16
    arrived_s: float = field(default_factory=time.monotonic)
    # engine-filled:
    slot: int | None = None
    tokens: list[int] = field(default_factory=list)
    first_token_s: float | None = None
    done_s: float | None = None


class Scheduler:
    def __init__(self, *, policy: DispatchPolicy = DispatchPolicy.ROUND_ROBIN,
                 n_replicas: int = 1, prefill_budget: int = 2) -> None:
        self.policy = policy
        self.n_replicas = n_replicas
        self.prefill_budget = prefill_budget
        self.waiting: list[deque[Request]] = [deque() for _ in range(n_replicas)]
        self._rr = 0

    def submit(self, req: Request) -> int:
        """Route a request to a replica per the dispatch policy."""
        if self.policy is DispatchPolicy.FIFO:
            r = zlib.crc32(req.session_key.encode()) % self.n_replicas
        else:
            r = self._rr % self.n_replicas
            self._rr += 1
        self.waiting[r].append(req)
        return r

    def admit(self, replica: int, free_slots: int) -> list[Request]:
        """Oldest-first admission bounded by slots and prefill budget."""
        out = []
        q = self.waiting[replica]
        while q and len(out) < min(free_slots, self.prefill_budget):
            out.append(q.popleft())
        return out

    def pending(self, replica: int) -> int:
        return len(self.waiting[replica])

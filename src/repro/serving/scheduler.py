"""Request scheduler = the Cascade dispatcher applied to serving (§3.3, §3.5).

Requests are objects put to the engine's request pool; the scheduler is the
dispatcher's policy layer: ROUND_ROBIN spreads requests across engine
replicas (load balancing), FIFO pins a session key (e.g. one chat session /
one camera) to a single replica so its turns stay ordered — the same two
policies, verbatim, as the paper's upcall dispatch.  (In the multi-tenant
``ServeNode`` each replica engine runs its own single-replica scheduler and
replica selection happens one level up, at the store's trigger-put member
pick; ``pending`` feeds the deployment's bounded-admission queue depth.)

A completed ``Request`` carries per-token scores — log p(token) and
next-token entropy, surfaced by the engine's in-dispatch sampler — which
cascade gates (``serving.cluster.CascadeRoute``) read to decide light→heavy
escalation.

Out-of-order issue queue (SLO classes, deadline-derived priority)
-----------------------------------------------------------------
Waiting requests form an ISSUE QUEUE in the style of an out-of-order core:
each entry waits with readiness predicates — a free KV slot, its worst-case
block footprint within the pool's admissible budget, a token-budget lane
(the engine calls ``admit_one`` only while lanes remain), a draft stream if
any (drafts ride ON the request, so they are ready by construction) — and
any READY entry may issue into the tick.  Issue order among ready entries is
earliest-virtual-deadline-first (EDF): a request's virtual deadline is
``arrived_s + deadline_s`` when it carries an explicit deadline, else
``arrived_s +`` its SLO class's default latency target
(``SLO_TARGETS``: ``interactive`` ≪ ``batch``).  Priority aging is intrinsic
— virtual deadlines are ABSOLUTE, so a parked batch request eventually has
an earlier deadline than any fresh interactive arrival and batch can never
starve: the wait behind newer interactive traffic is bounded by the gap
between the class targets.  With a uniform class and no explicit deadlines
EDF degenerates to exact arrival-order FIFO, so single-class workloads
behave precisely as the head-of-line scheduler did.

Per-session ordering stays EXACT and free: FIFO affinity already pins a
session to one replica, and within a replica only the OLDEST waiting entry
of each session is eligible to issue (younger turns of the same session are
held back), so cross-session reordering — the only reordering EDF performs —
can never reorder a conversation.  A too-big head therefore still blocks its
OWN session, but no longer blocks everyone else's.

An entry whose demand exceeds ``max_blocks`` — the pool's ABSOLUTE capacity,
never attainable even fully drained — is issued anyway so the engine's
admission validation can reject it via the completion path; without that
escape hatch it would sit in the queue forever.

Admission: the dense engine admits in batches (``admit``): an optional
`prefill_budget` bounds how many prefills are spliced per decode step so
long prompts cannot starve decodes — the paper's "latency floor under load"
discipline applied to token serving.  ``admit`` sweeps nothing itself but
SKIPS deadline-expired entries (they stay queued for ``pop_expired``), so a
dead head never consumes a free slot or a prefill-budget lane.  The paged
engine's unified token-budget tick admits one entry at a time (``admit_one``)
while it packs the tick's token budget: each admission interleaves with the
engine's begin/pack/commit, so the per-TOKEN budget — not a per-request
count — is what bounds prefill work per tick, and block accounting is
re-read between admissions.

Token-budget arithmetic with speculative decoding: a decode row is NOT
always one token — a speculative row feeds 1 + k tokens (its last committed
token plus k verified drafts).  The engine grants draft lanes LAST, after
every live row's mandatory lane and all prefill chunk packing
(engine._plan_drafts), so the budget remainder ``admit_one`` packs prefill
chunks into is exactly what a non-speculative tick would offer and can
never be oversubscribed by a k-token row.
"""
from __future__ import annotations

import time
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core.pools import DispatchPolicy

# SLO classes: default latency targets (seconds) from which a request's
# virtual deadline is derived when it carries no explicit ``deadline_s``.
# The interactive/batch GAP is the aging bound: a queued batch request is
# passed over by newer interactive arrivals for at most
# (batch target - interactive target) before its absolute virtual deadline
# becomes the earliest in the queue.
SLO_INTERACTIVE = "interactive"
SLO_BATCH = "batch"
SLO_TARGETS: dict[str, float] = {SLO_INTERACTIVE: 0.25, SLO_BATCH: 4.0}


def virtual_deadline(req: "Request") -> float:
    """Absolute EDF priority (smaller = sooner): explicit deadline when the
    request carries one, else the SLO class's default latency target."""
    if req.deadline_s is not None:
        return req.arrived_s + req.deadline_s
    return req.arrived_s + SLO_TARGETS.get(req.slo, SLO_TARGETS[SLO_BATCH])


@dataclass
class Request:
    request_id: str
    session_key: str
    prompt: Any                     # token array (1, S) or embeds (1, S, d)
    max_new_tokens: int = 16
    # latency budget, seconds RELATIVE to arrived_s (None = no deadline).
    # Enforced at engine admission, per tick (engine._sweep_deadlines), and
    # at the CascadeRoute boundary: an expired request completes with a
    # structured {"error": "deadline_exceeded", ...} — never a hang.
    deadline_s: float | None = None
    # SLO class ("interactive" | "batch"): sets the default latency target
    # the issue queue derives this request's virtual deadline from when no
    # explicit deadline_s is given, and marks it for the per-class
    # queue-wait histograms.  Interactive requests issue ahead of batch
    # ones under pressure (and, on a preempting engine, may evict a batch
    # victim's KV to the spill pool); absolute virtual deadlines age batch
    # entries so they can never starve.
    slo: str = SLO_BATCH
    # optional draft stream for speculative decoding: token i is a guess for
    # generated token i (e.g. a CascadeRoute plants the LIGHT deployment's
    # generation here when escalating to heavy, so the heavy engine verifies
    # the light tokens k at a time instead of re-deriving them one per tick)
    draft_tokens: Any = None
    arrived_s: float = field(default_factory=time.monotonic)
    # engine-filled:
    slot: int | None = None
    tokens: list[int] = field(default_factory=list)
    # failover/preemption replay: how many leading entries of ``tokens``
    # were folded into ``prompt`` for replay-prefill (on a sibling replica,
    # or on re-issue after a preemption whose spilled KV was lost).  Block/
    # write accounting subtracts it (the folded tokens were going to be
    # written as decode feedbacks anyway), and completion caches only
    # ``tokens[replay_offset:]`` as generated — so a replayed request's
    # allocator footprint is exactly the uninterrupted request's.
    replay_offset: int = 0
    # when the request first issued into an engine (slot granted); queue
    # wait = issued_s - arrived_s feeds the per-SLO-class histograms
    issued_s: float | None = None
    # per-token scores, surfaced from the SAME in-dispatch sampler that
    # picked the token (no extra device→host traffic): log p(token) under
    # the model, and the full next-token distribution's entropy.  Cascade
    # gates (escalate-to-heavy decisions) read these.
    scores: list[float] = field(default_factory=list)      # log p(tok_i)
    entropies: list[float] = field(default_factory=list)   # H(p_i), nats
    first_token_s: float | None = None
    done_s: float | None = None
    # engine rejections set a string; admission sheds set a structured dict
    # ({"error": "shed_overload", "replica": ..., "depth": ..., ...})
    error: str | dict | None = None

    def mean_logprob(self) -> float:
        """Mean per-token log-likelihood of the generation — the CascadeServe
        confidence signal (low = the light model is guessing)."""
        return (sum(self.scores) / len(self.scores)) if self.scores \
            else float("-inf")

    def mean_entropy(self) -> float:
        """Mean next-token distribution entropy (high = uncertain)."""
        return (sum(self.entropies) / len(self.entropies)) if self.entropies \
            else float("inf")

    # ------------------------------------------------------------ deadlines
    def elapsed(self, now: float | None = None) -> float:
        return (time.monotonic() if now is None else now) - self.arrived_s

    def expired(self, now: float | None = None) -> bool:
        return (self.deadline_s is not None
                and self.elapsed(now) > self.deadline_s)

    def remaining(self, now: float | None = None) -> float | None:
        """Budget left, or None when the request carries no deadline."""
        if self.deadline_s is None:
            return None
        return self.deadline_s - self.elapsed(now)

    # --------------------------------------------------------------- replay
    def fold_for_replay(self) -> bool:
        """Fold the not-yet-folded emissions into the prompt so a replay
        PREFILLS them and decode resumes the stream exactly (greedy decoding
        stays bit-identical to the uninterrupted run).  Used by deployment
        failover when a dead replica's KV could not migrate, and by the
        preemption resume path when the spill pool no longer holds the
        parked KV.  False for embeds prompts with emissions — tokens can't
        concatenate onto an embedding matrix, so those can't be replayed."""
        new = self.tokens[self.replay_offset:]
        if not new:
            return True
        p = np.asarray(self.prompt)
        if not np.issubdtype(p.dtype, np.integer):
            return False
        self.prompt = np.concatenate([p, np.asarray(new, p.dtype)])
        self.replay_offset = len(self.tokens)
        return True


class Scheduler:
    def __init__(self, *, policy: DispatchPolicy = DispatchPolicy.ROUND_ROBIN,
                 n_replicas: int = 1, prefill_budget: int = 2) -> None:
        self.policy = policy
        self.n_replicas = n_replicas
        self.prefill_budget = prefill_budget
        # Arrival order is the queue's PHYSICAL order (appends at the tail;
        # ``requeue`` restores an un-placed head).  Issue order is computed
        # per call by the EDF scan — the deque is never resorted, so
        # ``pop_expired``/``drain`` keep their exact in-place semantics
        # under concurrent submits.
        self.waiting: list[deque[Request]] = [deque() for _ in range(n_replicas)]
        self._rr = 0

    def submit(self, req: Request) -> int:
        """Route a request to a replica per the dispatch policy."""
        if self.policy is DispatchPolicy.FIFO:
            r = zlib.crc32(req.session_key.encode()) % self.n_replicas
        else:
            r = self._rr % self.n_replicas
            self._rr += 1
        self.waiting[r].append(req)
        return r

    # ------------------------------------------------------------ issue scan
    def _issue_scan(self, replica: int, *, free_blocks: int | None = None,
                    block_cost: Any = None, max_blocks: int | None = None,
                    now: float | None = None) -> tuple[int, Request] | None:
        """The issue-queue scan: over the arrival-ordered deque, find the
        READY entry with the earliest virtual deadline.

        Eligibility per entry:
        - session-ordered: only the FIRST (oldest) waiting entry of each
          session may issue — younger turns are invisible to the scan, so
          per-session FIFO is exact;
        - not deadline-expired (expired entries stay queued for
          ``pop_expired`` — a dead head must not consume a slot or lane);
        - ready: worst-case block footprint within ``free_blocks`` — except
          an entry whose demand exceeds ``max_blocks`` (never servable),
          which is issued anyway for the engine's rejection path.

        Ties on the virtual deadline resolve to queue position (arrival
        order; a requeued head sits at position 0), keeping single-class
        traffic exactly FIFO.  Returns (index, request) or None.  O(pending)
        per issue — pending is watermark-bounded in deployments, and the
        scan is pure host-side bookkeeping off the dispatch path."""
        q = self.waiting[replica]
        if not q:
            return None
        now = time.monotonic() if now is None else now
        best: tuple[float, int, Request] | None = None
        seen_sessions: set[str] = set()
        for i in range(len(q)):          # index scan: appends may race
            try:
                req = q[i]
            except IndexError:           # concurrent pop shrank the deque
                break
            if req.session_key in seen_sessions:
                continue
            seen_sessions.add(req.session_key)
            if req.expired(now):
                continue
            if free_blocks is not None and block_cost is not None:
                need = block_cost(req)
                if ((max_blocks is None or need <= max_blocks)
                        and need > free_blocks):
                    continue             # waits on blocks; others may issue
            vdl = virtual_deadline(req)
            if best is None or vdl < best[0]:
                best = (vdl, i, req)
        if best is None:
            return None
        return best[1], best[2]

    def _pop_at(self, replica: int, index: int, req: Request) -> Request:
        """Remove the scanned entry; ``del q[i]`` is atomic under the GIL
        and concurrent submits only append past it."""
        q = self.waiting[replica]
        try:
            if q[index] is req:
                del q[index]
                return req
        except IndexError:
            pass
        q.remove(req)                    # a concurrent pop shifted it
        return req

    def admit(self, replica: int, free_slots: int) -> list[Request]:
        """Batch admission (dense engines), bounded by free slots and the
        per-tick prefill budget: repeated issue-queue picks, so the batch
        comes out in priority order with expired entries skipped."""
        out: list[Request] = []
        now = time.monotonic()
        while len(out) < min(free_slots, self.prefill_budget):
            got = self._issue_scan(replica, now=now)
            if got is None:
                break
            out.append(self._pop_at(replica, *got))
        return out

    def admit_one(self, replica: int, *, free_slots: int,
                  free_blocks: int | None = None, block_cost: Any = None,
                  max_blocks: int | None = None) -> Request | None:
        """Issue ONE ready request (earliest virtual deadline), or None when
        nothing is ready.  The paged engine's unified tick calls this in a
        loop while packing its token budget, so block accounting is re-read
        between admissions (each ``begin`` changes what is available)."""
        if free_slots <= 0:
            return None
        got = self._issue_scan(replica, free_blocks=free_blocks,
                               block_cost=block_cost, max_blocks=max_blocks)
        if got is None:
            return None
        return self._pop_at(replica, *got)

    def best_waiting(self, replica: int) -> Request | None:
        """The entry the NEXT issue would pick if resources were infinite —
        the engine's preemption pressure signal: when this request exists
        but cannot issue for lack of slots/blocks, and some in-flight
        request has a strictly later virtual deadline, the engine may spill
        that victim.  Read-only (nothing is popped)."""
        got = self._issue_scan(replica)
        return None if got is None else got[1]

    def requeue(self, replica: int, req: Request) -> None:
        """Return an admitted-but-unplaced (or preempted) request to the
        HEAD of its queue: it becomes the oldest waiting entry of its
        session again, so per-session order is preserved (callers that
        requeue a contiguous admitted run do so in reverse)."""
        self.waiting[replica].appendleft(req)

    def pop_expired(self, replica: int, now: float | None = None
                    ) -> list[Request]:
        """Remove and return every queued request whose deadline has passed.

        Pop-rotates IN PLACE (pop each element once, append keepers back)
        rather than rebuilding the deque: an upcall thread may be appending
        concurrently, and a replacement deque would silently drop its
        arrival.  Relative order of the keepers is preserved."""
        q = self.waiting[replica]
        now = time.monotonic() if now is None else now
        expired: list[Request] = []
        for _ in range(len(q)):
            req = q.popleft()
            (expired if req.expired(now) else q).append(req)
        return expired

    def drain(self, replica: int) -> list[Request]:
        """Pop every queued request (replica evacuation on mark-down).
        Same in-place pop discipline as ``pop_expired``: a concurrent
        submit's append is either drained or survives for the sweep."""
        q = self.waiting[replica]
        out: list[Request] = []
        for _ in range(len(q)):
            out.append(q.popleft())
        return out

    def pending(self, replica: int) -> int:
        return len(self.waiting[replica])

"""Deterministic fault injection for the serving fast path.

Vortex-style hosting (PAPERS.md) stands or falls on what happens when a
component stalls or dies; this module makes those conditions a first-class,
REPRODUCIBLE input instead of a hardware accident.  A ``FaultInjector``
holds a seeded schedule of ``FaultSpec``s and installs at three seams:

- **engine tick** (``ServeEngine.tick`` entry, driver thread): CRASH marks
  the engine crashed and raises ``ReplicaCrashed``; STALL makes the busy
  engine no-op forever (tick returns 0 without dispatching — the model of a
  wedged replica, detected only by the deployment's progress watchdog);
  SLOW_TICK sleeps ``duration_s`` before the dispatch for ``count`` ticks
  (progress continues, so the watchdog tolerates it — this is the fault
  that exercises per-request deadlines, not failover).
- **engine submit** (upcall thread): SUBMIT_ERROR raises the transient
  ``InjectedFault`` for ``count`` consecutive submits — the deployment's
  bounded retry moves the request to a sibling.
- **store trigger_put** (client thread, via ``CascadeStore.fault_hook``):
  SUBMIT_ERROR specs with ``seam="store"`` fail the trigger_put itself —
  the deployment-level backoff/retry seam.

Faults fire at tick/submit ENTRY only, never mid-dispatch: the engine's
donated-pool discipline (devstore aliases donated buffers between dispatch
and publish) means a fault landing inside a tick could strand the pool in
an unreadable state; firing at the seam keeps every recovery path exercised
without modeling torn device state.

Interplay with preemption (``ServeEngine(preempt=True)``): the tick seam
fires BEFORE ``_maybe_preempt``, so a crash can never land between a
victim's spill and its requeue — a preemption either completed on an
earlier tick (the victim is back in the queue, its KV parked in the
deployment-shared ``SpillPool``) or never started.  A crashed replica's
evacuation then drains preempted requests as ordinary QUEUED entries;
``_re_home`` submits them to a sibling, whose admission unparks the shared
pool entry and ``adopt``s it — or falls back to prompt replay if the pool
evicted it.  Either way the stream stays bit-identical under greedy
decoding, which is what the chaos suite asserts with preemption enabled.

Everything here is pure host logic — no jax, one internal lock — so the
PR 6 sanitizers (lock-order tracker, sync-site budget) hold trivially and
the static sync-site budget over ``serving/`` stays at one.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from enum import Enum


class FaultKind(str, Enum):
    CRASH = "crash"              # replica dies at tick entry
    STALL = "stall"              # replica wedges: busy but never progresses
    SLOW_TICK = "slow_tick"      # tick sleeps duration_s (progress continues)
    SUBMIT_ERROR = "submit_error"  # transient failure at a submit seam


class ReplicaCrashed(RuntimeError):
    """The replica is dead: permanent until the deployment marks it down."""


class InjectedFault(RuntimeError):
    """A transient injected failure (submit/store seam): retry elsewhere."""


@dataclass
class FaultSpec:
    """One scheduled fault.

    ``deployment``/``replica`` select the target ("*" / -1 = first match).
    ``at_tick`` arms tick faults once the target's observed tick count
    reaches it; a NEGATIVE at_tick is resolved at injector construction to a
    seeded draw from [1, -at_tick] (deterministic chaos schedules).
    ``at_submit``/``count`` arm submit faults for ``count`` consecutive
    submit events starting at the ``at_submit``-th.  ``kv_recoverable``
    models whether a crashed replica's KV pool can still be spilled
    (False = the sessions fall back to prompt replay)."""
    kind: FaultKind
    deployment: str = "*"
    replica: int = -1
    at_tick: int = 1
    at_submit: int = 0
    count: int = 1
    duration_s: float = 0.0
    kv_recoverable: bool = True
    seam: str = "engine"          # SUBMIT_ERROR only: "engine" | "store"
    # resolved/armed state (injector-internal):
    fired: int = field(default=0, compare=False)
    bound: tuple | None = field(default=None, compare=False)


class _BoundSeam:
    """One (deployment, replica)'s view of the injector — what an engine's
    ``faults`` attribute holds."""

    def __init__(self, injector: "FaultInjector", deployment: str,
                 replica: int) -> None:
        self._inj = injector
        self.deployment = deployment
        self.replica = replica

    def on_tick(self, engine) -> str | None:
        return self._inj.on_tick(engine, self.deployment, self.replica)

    def on_submit(self) -> None:
        self._inj.on_submit(self.deployment, self.replica)


class FaultInjector:
    """Seeded deterministic fault schedule over the serving seams."""

    def __init__(self, specs: list[FaultSpec], seed: int = 0) -> None:
        rng = random.Random(seed)
        self.specs = list(specs)
        for spec in self.specs:
            if spec.at_tick < 0:
                spec.at_tick = rng.randrange(1, -spec.at_tick + 1)
        self._lock = threading.Lock()
        self._ticks: dict[tuple[str, int], int] = {}
        self._submits: dict[tuple[str, int], int] = {}
        self.fired_log: list[str] = []

    # ------------------------------------------------------------ installers
    def bind(self, deployment: str, replica: int) -> _BoundSeam:
        """The engine-seam hook: assign to ``engine.faults`` (deployments do
        this via ``ModelDeployment.install_faults``)."""
        return _BoundSeam(self, deployment, replica)

    def store_hook(self):
        """The store-seam hook: assign to ``CascadeStore.fault_hook``; fires
        SUBMIT_ERROR specs with ``seam="store"`` whose deployment name
        appears in the trigger_put key."""
        def hook(key: str) -> None:
            with self._lock:
                for spec in self.specs:
                    if (spec.kind is not FaultKind.SUBMIT_ERROR
                            or spec.seam != "store"
                            or spec.fired >= spec.count):
                        continue
                    if (spec.deployment != "*"
                            and f"/{spec.deployment}/" not in key):
                        continue
                    spec.fired += 1
                    self.fired_log.append(f"store_submit_error:{key}")
                    raise InjectedFault(
                        f"injected store submit error on {key}")
        return hook

    # ----------------------------------------------------------- seam events
    def _matches(self, spec: FaultSpec, deployment: str, replica: int) -> bool:
        if spec.deployment != "*" and spec.deployment != deployment:
            return False
        if spec.replica >= 0 and spec.replica != replica:
            return False
        # single-target faults latch onto whoever fired them first, so a
        # wildcard CRASH kills exactly one replica
        if spec.bound is not None and spec.bound != (deployment, replica):
            return False
        return True

    def on_tick(self, engine, deployment: str, replica: int) -> str | None:
        """Called at tick ENTRY by the bound engine.  Returns "stall" for a
        wedged tick, sleeps for slow ticks, raises ``ReplicaCrashed`` for a
        crash (after flagging the engine so later submits bounce)."""
        sleep_s = 0.0
        verdict: str | None = None
        crash: FaultSpec | None = None
        with self._lock:
            k = (deployment, replica)
            self._ticks[k] = self._ticks.get(k, 0) + 1
            t = self._ticks[k]
            for spec in self.specs:
                if not self._matches(spec, deployment, replica):
                    continue
                if spec.kind is FaultKind.CRASH:
                    if spec.fired == 0 and t >= spec.at_tick:
                        spec.fired = 1
                        spec.bound = k
                        self.fired_log.append(
                            f"crash:{deployment}/r{replica}@tick{t}")
                        crash = spec
                elif spec.kind is FaultKind.STALL:
                    if t >= spec.at_tick:
                        if spec.fired == 0:
                            spec.fired = 1
                            spec.bound = k
                            self.fired_log.append(
                                f"stall:{deployment}/r{replica}@tick{t}")
                        verdict = "stall"
                elif spec.kind is FaultKind.SLOW_TICK:
                    if t >= spec.at_tick and spec.fired < spec.count:
                        spec.fired += 1
                        spec.bound = k
                        sleep_s = max(sleep_s, spec.duration_s)
        if crash is not None:
            engine.crashed = True
            engine.kv_recoverable = crash.kv_recoverable
            raise ReplicaCrashed(
                f"injected crash: {deployment}/replica{replica}")
        if sleep_s > 0.0:
            time.sleep(sleep_s)
        return verdict

    def on_submit(self, deployment: str, replica: int) -> None:
        """Called at submit ENTRY by the bound engine (upcall thread)."""
        with self._lock:
            k = (deployment, replica)
            self._submits[k] = self._submits.get(k, 0) + 1
            s = self._submits[k]
            for spec in self.specs:
                if (spec.kind is not FaultKind.SUBMIT_ERROR
                        or spec.seam != "engine"
                        or not self._matches(spec, deployment, replica)):
                    continue
                if s > spec.at_submit and spec.fired < spec.count:
                    spec.fired += 1
                    spec.bound = spec.bound or k
                    self.fired_log.append(
                        f"submit_error:{deployment}/r{replica}@submit{s}")
                    raise InjectedFault(
                        f"injected submit error: {deployment}/"
                        f"replica{replica}")


def poisoned_lambda(exc: type[BaseException] = RuntimeError,
                    msg: str = "injected lambda poison"):
    """An always-raising upcall fn — the dispatcher-seam fault (a poisoned
    request's lambda raising on the upcall thread); the dispatcher must
    contain and count it (``Dispatcher.stats().upcall_errors``), never let
    it wedge the thread."""
    def fn(_obj, _event):
        raise exc(msg)
    return fn

"""Multi-replica serving cluster on the Cascade fast path (§3.3, §3.5).

``ServeCluster`` hosts N ``ServeEngine`` replicas the way the paper hosts any
lambda: each replica lives on one Cascade ``Worker`` and is registered on the
``/serve/<model>/req`` pool, so requests ARRIVE as ``trigger_put``s through
the store → dispatcher → upcall-thread fast path (nothing is stored or
copied; the upcall carries references).  Completed responses are ``put`` back
into the ``/serve/<model>/out`` pool, where clients read them with ``get``.

Replica selection is the store's trigger-put member pick, i.e. the paper's
two dispatch policies end-to-end:

- ``ROUND_ROBIN`` — trigger-puts spread evenly over the home shard's members
  (one engine replica per member): load balancing.
- ``FIFO`` — the member is chosen by ``affinity_shard_hash`` over the
  ``/serve/<model>/req/<session>`` prefix, so every turn of a session lands
  on the SAME replica, and the single upcall thread per worker keeps the
  session's turns in submission order (KV/session locality, §3.3's
  same-key-same-queue rule lifted to the cluster level).

Request keys: ``/serve/<model>/req/<session>/<request_id>``; payloads are
small dicts (prompt + decode budget) — the request moves to the weights, the
weights never move (§2 data/compute collocation).

The decode loop itself is the engine's unified token-budget tick (paged
models): decode rows and chunked prefills packed into ONE fixed-shape jitted
mixed step per tick, one device→host transfer per tick
(``host_syncs == ticks``).  Dense (SSM/hybrid/embeds) replicas keep the
phase-separated discipline: batched prefill admission + masked fused decode
(``host_syncs == decode_ticks + prefill_batches``).
"""
from __future__ import annotations

import functools
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.core.devstore import DeviceStore
from repro.core.dispatcher import LambdaHandle
from repro.core.objects import CascadeObject
from repro.core.pools import (DispatchPolicy, Persistence, PoolSpec,
                              affinity_shard_hash)
from repro.core.store import CascadeStore, Worker
from repro.models import supports_paged
from repro.models.config import ModelConfig

from .engine import ServeEngine
from .scheduler import Request, Scheduler

# key = /serve/<model>/req/<session>/<request_id> → 5 components; hashing the
# first 4 ("serve", model, "req", session) gives per-session affinity.
_SESSION_DEPTH = 4


class ServeCluster:
    """N engine replicas as lambdas on a Cascade store (one per worker).

    Pure-attention token models serve from paged KV by default: each replica
    owns a block pool + prefix trie (kvcache.PagedCacheManager), and all the
    pools live on ONE shared DeviceStore under ``/kv/replica<r>`` — FIFO
    session affinity makes the per-replica trie pay: every turn of a session
    lands where its prefix blocks already sit.
    """

    def __init__(self, cfg: ModelConfig, params, *, n_replicas: int = 2,
                 n_slots: int = 4, max_len: int = 64,
                 policy: DispatchPolicy = DispatchPolicy.ROUND_ROBIN,
                 model_name: str | None = None,
                 temperature: float = 0.0, paged: bool | None = None,
                 block_size: int = 16, num_blocks: int | None = None,
                 prefix_cache: bool = True,
                 token_budget: int | None = None) -> None:
        self.cfg = cfg
        self.policy = policy
        name = model_name or cfg.name
        self.req_prefix = f"/serve/{name}/req"
        self.out_prefix = f"/serve/{name}/out"
        self.paged = supports_paged(cfg) if paged is None else paged
        # One worker per replica; a single upcall thread per worker keeps
        # FIFO sessions ordered (the dispatcher's same-queue guarantee).
        self.workers = [Worker(i, n_upcall_threads=1)
                        for i in range(n_replicas)]
        self.store = CascadeStore(self.workers)
        session_hash = functools.partial(affinity_shard_hash,
                                         depth=_SESSION_DEPTH)
        self.store.create_pool(PoolSpec(
            path=self.req_prefix, persistence=Persistence.TRANSIENT,
            replication=n_replicas, dispatch=policy,
            shard_hash=session_hash))
        self.store.create_pool(PoolSpec(path=self.out_prefix, replication=1))
        # One device store for every replica's KV block pool (keep_versions=1:
        # decode rewrites all leaves each tick, retaining predecessors would
        # double pool memory).
        self.kv_store: DeviceStore | None = None
        if self.paged:
            self.kv_store = DeviceStore(jax.make_mesh((1, 1), ("data", "model")),
                                        keep_versions=1)
            self.kv_store.create_pool(PoolSpec(path="/kv"))
        self.engines = []
        for r in range(n_replicas):
            kw: dict[str, Any] = dict(paged=self.paged)
            if self.paged:
                kw.update(block_size=block_size, num_blocks=num_blocks,
                          prefix_cache=prefix_cache, devstore=self.kv_store,
                          kv_key=f"/kv/replica{r}/pool",
                          token_budget=token_budget)
            self.engines.append(ServeEngine(
                cfg, params, n_slots=n_slots, max_len=max_len,
                temperature=temperature, scheduler=Scheduler(n_replicas=1),
                on_complete=self._on_complete, seed_offset=r, **kw))
        # Collocated replicas run identical programs: share the jitted
        # callables so each program compiles once per cluster, not once per
        # replica (the paged mixed step has exactly ONE program — its packed
        # shape is fixed at token_budget).
        for eng in self.engines[1:]:
            if self.paged:
                eng._mixed = self.engines[0]._mixed
            else:
                eng._prefill = self.engines[0]._prefill
                eng._step = self.engines[0]._step
        for r in range(n_replicas):
            handle = LambdaHandle(
                name=f"serve-replica-{r}", prefix=self.req_prefix,
                fn=functools.partial(self._on_request, r), dispatch=policy,
                # dispatcher-level mirror of the store's member pick: FIFO
                # queue selection hashes the session prefix, not the full key
                queue_hash=session_hash if policy is DispatchPolicy.FIFO
                else None)
            self.store.register_lambda(handle, worker_ids=[r])
        # request_id → replica index, for introspection/tests; bounded so a
        # long-running cluster doesn't grow it without limit.
        self.routed: dict[str, int] = {}
        self._routed_cap = 4096
        self._lock = threading.Lock()
        self._submitted = 0
        self._completed = 0

    # ------------------------------------------------------------- lambdas
    def _on_request(self, replica: int, obj: CascadeObject, _event) -> str:
        """The serving lambda: runs on the replica worker's upcall thread."""
        comps = obj.key.split("/")
        session, request_id = comps[-2], comps[-1]
        payload = obj.payload
        req = Request(request_id=request_id, session_key=session,
                      prompt=payload["prompt"],
                      max_new_tokens=int(payload.get("max_new_tokens", 16)))
        with self._lock:
            self.routed[request_id] = replica
            while len(self.routed) > self._routed_cap:
                self.routed.pop(next(iter(self.routed)))
        self.engines[replica].submit(req)
        return request_id

    def _on_complete(self, req: Request) -> None:
        """Engine completion hook: the response lands back in the store.
        A rejected request (oversized prompt, impossible block demand) still
        completes — empty tokens at the normal key, and its reason under
        ``<request_id>/error`` so clients can tell refusal from a short
        generation (read it with ``error()``)."""
        if req.error is not None:
            self.store.put(f"{self.out_prefix}/{req.request_id}/error",
                           req.error)
        self.store.put(f"{self.out_prefix}/{req.request_id}",
                       np.asarray(req.tokens, np.int32))
        with self._lock:
            self._completed += 1

    # ------------------------------------------------------------- clients
    def submit(self, session_key: str, request_id: str, prompt: Any, *,
               max_new_tokens: int = 16):
        """Fire a request into the fast path (trigger_put; nothing stored)."""
        key = f"{self.req_prefix}/{session_key}/{request_id}"
        with self._lock:
            self._submitted += 1
        return self.store.trigger_put(
            key, {"prompt": np.asarray(prompt),
                  "max_new_tokens": max_new_tokens})

    def result(self, request_id: str) -> np.ndarray | None:
        obj = self.store.get(f"{self.out_prefix}/{request_id}")
        return None if obj is None else np.asarray(obj.payload)

    def error(self, request_id: str) -> str | None:
        """Why a request was rejected; None while pending or on success."""
        obj = self.store.get(f"{self.out_prefix}/{request_id}/error")
        return None if obj is None else str(obj.payload)

    # -------------------------------------------------------------- driver
    def _idle(self) -> bool:
        return all(eng.idle() for eng in self.engines)

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        """Tick every busy replica until all submitted requests completed.

        In the paper's deployment each replica's engine loop runs on its own
        node; here one driver thread round-robins the ticks (the jitted step
        releases the GIL into XLA either way), while upcall threads keep
        feeding the schedulers concurrently.
        """
        for _ in range(max_ticks):
            busy = False
            for eng in self.engines:
                if not eng.idle():
                    eng.tick()
                    busy = True
            if not busy:
                with self._lock:
                    done = self._completed == self._submitted
                if done and self._idle():
                    return
                time.sleep(0.0002)   # in-flight upcalls not yet enqueued
        raise TimeoutError("cluster did not drain")

    # --------------------------------------------------------------- stats
    def stats(self) -> dict[str, Any]:
        """Aggregate latency/throughput stats across replicas."""
        ttft = sorted(t for e in self.engines for t in e.stats.ttft_s)
        tpot = sorted(t for e in self.engines for t in e.stats.tpot_s)

        def pct(xs: list[float], q: float) -> float:
            return xs[min(len(xs) - 1, int(q * len(xs)))] if xs else float("nan")

        return {
            "n_replicas": len(self.engines),
            "requests": sum(e.stats.prefills for e in self.engines),
            "tokens_out": sum(e.stats.tokens_out for e in self.engines),
            "per_replica_requests": [e.stats.prefills for e in self.engines],
            "host_syncs": sum(e.stats.host_syncs for e in self.engines),
            "ticks": sum(e.stats.ticks for e in self.engines),
            "decode_ticks": sum(e.stats.decode_ticks for e in self.engines),
            "prefill_batches": sum(e.stats.prefill_batches for e in self.engines),
            "prefill_chunks": sum(e.stats.prefill_chunks for e in self.engines),
            "prompt_tokens": sum(e.stats.prompt_tokens for e in self.engines),
            "prefill_tokens": sum(e.stats.prefill_tokens for e in self.engines),
            "prefix_hit_tokens": sum(e.stats.prefix_hit_tokens
                                     for e in self.engines),
            "prefix_hits": sum(e.stats.prefix_hits for e in self.engines),
            "blocks_in_use": sum(e.stats.blocks_in_use for e in self.engines),
            "ttft_p50_s": pct(ttft, 0.50), "ttft_p99_s": pct(ttft, 0.99),
            "tpot_p50_s": pct(tpot, 0.50), "tpot_p99_s": pct(tpot, 0.99),
        }

    def close(self) -> None:
        self.store.close()

    def __enter__(self) -> "ServeCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

"""Multi-tenant serving on the Cascade fast path (§2, §3.3, §3.5).

Cascade's thesis is that ONE platform hosts many collocated ML services with
per-event latency guarantees.  This module is that thesis applied to LM
serving, split into two layers:

``ServeNode``
    One Cascade node-group: the shared ``Worker`` set (one upcall thread per
    worker, so FIFO sessions stay ordered), the ``CascadeStore`` they form,
    and a single KV ``DeviceStore`` every paged deployment's block pools
    live on.  The node's driver loop ticks every busy engine across ALL
    deployments — a paged attention model and a dense SSM model run side by
    side on the same workers, each keeping its own host-sync invariant
    (paged: ``host_syncs == ticks``; dense: ``host_syncs == decode_ticks +
    prefill_batches``).

``ModelDeployment``
    One hosted model: a replica set of ``ServeEngine``s registered as
    lambdas on ``/serve/<model>/req``, responses ``put`` into
    ``/serve/<model>/out``, paged KV pools under
    ``/kv/<model>/replica<r>/pool`` on the node's device store.  Replica
    selection is the store's trigger-put member pick (ROUND_ROBIN spreads
    load; FIFO routes by ``affinity_shard_hash`` over the session prefix so
    a session's turns stay on one replica, in order).  ``stop()`` tears the
    deployment down: lambdas unregistered, req/out pools removed from the
    store, KV pools dropped from the device store.

Bounded admission (MultiTASC++-style shed/redirect)
---------------------------------------------------
A deployment constructed with a ``watermark`` bounds each replica's queue:
the serving lambda measures its replica's depth — engine backlog (queued +
mid-prefill + decoding) plus the worker's outstanding upcall events (the
dispatcher's per-queue depth introspection) — and an over-watermark arrival
is REDIRECTED to the least-loaded sibling replica still under the
watermark, or, when every sibling is saturated, SHED with a structured
``/error`` reason (never silently dropped: the client sees exactly why).
Continuous shed/redirect keeps tail latency flat under overload instead of
letting queues grow without bound; ``stats()`` reports both counters.
Redirect trades FIFO session affinity for boundedness — exactly the
MultiTASC++ trade.

Cascade escalation (CascadeServe-style light→heavy routing)
-----------------------------------------------------------
``CascadeRoute(light, heavy, gate)`` submits every request to the LIGHT
deployment first.  When the gate trips — mean decode logprob below (or mean
next-token entropy above) a threshold, computed from the per-token scores
the engine's in-dispatch sampler already has on device — the request is
escalated via an internal ``trigger_put`` into the HEAVY deployment's req
pool: the request moves to the heavy weights, the weights never move (§2
data/compute collocation).  Confident light answers never touch the heavy
model, which is what puts cascaded serving ahead of single-model serving on
the latency/throughput frontier.  The escalated request carries the light
generation as a DRAFT stream (``draft_from_light``): a speculative heavy
deployment (``spec_k > 0``) verifies the light tokens k at a time in its
one ragged dispatch — the self-drafting cascade, where the light model
doubles as the heavy model's draft model and its work is never wasted.
"""
from __future__ import annotations

import functools
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable

import jax
import numpy as np

from repro.core.devstore import DeviceStore
from repro.core.dispatcher import LambdaHandle
from repro.core.objects import CascadeObject
from repro.core.pools import (DispatchPolicy, Persistence, PoolSpec,
                              affinity_shard_hash)
from repro.core.store import CascadeStore, SpillPool, Worker
from repro.models import supports_paged
from repro.models.config import ModelConfig

from .engine import ServeEngine
from .faults import InjectedFault, ReplicaCrashed
from .scheduler import Request, Scheduler, virtual_deadline

# key = /serve/<model>/req/<session>/<request_id> → 5 components; hashing the
# first 4 ("serve", model, "req", session) gives per-session affinity.
_SESSION_DEPTH = 4


class ModelDeployment:
    """One model hosted on a ``ServeNode``: engines, pools, admission.

    Created through ``ServeNode.deploy`` — replica ``r`` lives on node
    worker ``r``, its lambda registered on ``/serve/<name>/req``, its paged
    KV pool (pure-attention models) on the node's shared device store under
    ``/kv/<name>/replica<r>/pool``.  FIFO session affinity makes the
    per-replica prefix trie pay: every turn of a session lands where its
    prefix blocks already sit.
    """

    def __init__(self, node: "ServeNode", name: str, cfg: ModelConfig,
                 params, *, n_replicas: int, n_slots: int, max_len: int,
                 policy: DispatchPolicy, temperature: float,
                 paged: bool | None, block_size: int,
                 num_blocks: int | None, prefix_cache: bool,
                 token_budget: int | None, watermark: int | None,
                 seed_base: int, spec_k: int = 0,
                 watchdog_s: float | None = None, retry_budget: int = 2,
                 retry_backoff_s: float = 0.002, preempt: bool = False,
                 spill_capacity_blocks: int = 256,
                 kv_dtype: str | None = None,
                 devices_per_replica: int | None = None) -> None:
        if n_replicas > len(node.workers):
            raise ValueError(
                f"deployment {name!r} wants {n_replicas} replicas but the "
                f"node has {len(node.workers)} workers")
        self.node = node
        self.name = name
        self.cfg = cfg
        self.policy = policy
        self.watermark = watermark
        self.req_prefix = f"/serve/{name}/req"
        self.out_prefix = f"/serve/{name}/out"
        self.paged = supports_paged(cfg) if paged is None else paged
        if spec_k and not self.paged:
            raise ValueError(
                f"deployment {name!r}: spec_k={spec_k} needs the paged path "
                f"(speculative verify rows + KV rollback; see "
                f"models.supports_speculative)")
        self.worker_ids = list(range(n_replicas))
        session_hash = functools.partial(affinity_shard_hash,
                                         depth=_SESSION_DEPTH)
        node.store.create_pool(PoolSpec(
            path=self.req_prefix, persistence=Persistence.TRANSIENT,
            replication=n_replicas, dispatch=policy,
            shard_hash=session_hash), worker_ids=self.worker_ids)
        node.store.create_pool(PoolSpec(path=self.out_prefix, replication=1))
        # Preemption (opt-in, paged only): ONE deployment-wide spill pool,
        # store-backed under /spill/<name>, shared by every replica engine —
        # so a session preempted on replica A whose replica later dies can
        # still be unparked by the sibling its re-homed request lands on.
        # Engines park/unpark on the driver thread only (tick + mark_down),
        # so the shared instance needs no lock.
        self.preempt = bool(preempt)
        if self.preempt and not self.paged:
            raise ValueError(f"deployment {name!r}: preemption needs the "
                             f"paged path (KV blocks to spill)")
        self.spill_pool: SpillPool | None = None
        self.spill_prefix = f"/spill/{name}"
        if self.preempt:
            node.store.create_pool(PoolSpec(path=self.spill_prefix,
                                            replication=1))
            self.spill_pool = SpillPool(
                capacity_blocks=spill_capacity_blocks, store=node.store,
                prefix=self.spill_prefix)
        # Mesh slices (devices_per_replica=d): the node carves d local
        # devices per replica out of its free pool — DISJOINT slices, so
        # sibling replicas never contend for a device — and each engine
        # compiles its unified tick against its own slice, with params and
        # the paged KV pool installed sharded (launch.sharding rules).
        self.meshes: list[Any] = []
        if devices_per_replica is not None:
            if not self.paged:
                raise ValueError(
                    f"deployment {name!r}: mesh slices shard the paged KV "
                    f"pool; the dense path is single-device only")
            self.meshes = node.take_device_slices(n_replicas,
                                                  devices_per_replica)
        self.engines: list[ServeEngine] = []
        for r in range(n_replicas):
            kw: dict[str, Any] = dict(paged=self.paged)
            if self.paged:
                kw.update(block_size=block_size, num_blocks=num_blocks,
                          prefix_cache=prefix_cache,
                          devstore=node.kv_store(),
                          kv_key=f"/kv/{name}/replica{r}/pool",
                          kv_dtype=kv_dtype,
                          token_budget=token_budget, spec_k=spec_k,
                          spill_pool=self.spill_pool, preempt=self.preempt)
                if self.meshes:
                    kw["mesh"] = self.meshes[r]
            self.engines.append(ServeEngine(
                cfg, params, n_slots=n_slots, max_len=max_len,
                temperature=temperature, scheduler=Scheduler(n_replicas=1),
                on_complete=self._on_engine_complete,
                seed_offset=seed_base + r, **kw))
        # Collocated replicas run identical programs: share the jitted
        # callables so each program compiles once per deployment, not once
        # per replica (the paged mixed step has exactly ONE program — its
        # packed shape is fixed at token_budget).  Sliced replicas can NOT
        # share: each jit pins out_shardings to its own slice's mesh.
        if not self.meshes:
            for eng in self.engines[1:]:
                if self.paged:
                    eng._mixed = self.engines[0]._mixed
                else:
                    eng._prefill = self.engines[0]._prefill
                    eng._step = self.engines[0]._step
        self._handles: list[tuple[LambdaHandle, int]] = []
        for r in range(n_replicas):
            handle = LambdaHandle(
                name=f"{name}-replica-{r}", prefix=self.req_prefix,
                fn=functools.partial(self._on_request, r), dispatch=policy,
                # dispatcher-level mirror of the store's member pick: FIFO
                # queue selection hashes the session prefix, not the full key
                queue_hash=session_hash if policy is DispatchPolicy.FIFO
                else None)
            node.store.register_lambda(handle, worker_ids=[self.worker_ids[r]])
            self._handles.append((handle, self.worker_ids[r]))
        # request_id → replica index, for introspection/tests; bounded so a
        # long-running deployment doesn't grow it without limit.
        self.routed: dict[str, int] = {}
        self._routed_cap = 4096
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.shed = 0            # over-watermark arrivals refused outright
        self.redirected = 0      # over-watermark arrivals moved to a sibling
        self.preempt_admits = 0  # over-watermark arrivals admitted anyway
        #                          because the target held a lower-priority
        #                          in-flight victim (preempt-before-shed)
        self.listener_errors = 0  # on_done callbacks that raised (and were
        #                           contained so the completion still landed)
        # ------------------------------------------------- fault tolerance
        # ``down`` maps replica → reason; mark_down (driver thread only)
        # populates it and evacuates.  ``_progress`` backs the per-replica
        # tick watchdog: (stats snapshot, last time it changed) — a BUSY
        # replica whose snapshot freezes for > watchdog_s is wedged (an
        # un-stalled busy engine always advances ticks or prefill tokens).
        self.watchdog_s = watchdog_s
        self.retry_budget = retry_budget
        self.retry_backoff_s = retry_backoff_s
        self.down: dict[int, str] = {}
        self.failovers = 0        # replicas marked down
        self.rehomed = 0          # requests moved off a dead replica
        self.migrated = 0         # ... with their KV spilled + restored
        self.replayed = 0         # ... by folding emissions into the prompt
        self.failover_failed = 0  # ... completed with a replica_failed error
        self.submit_retries = 0   # submits retried on a sibling / backoff
        self._progress: list[tuple[tuple, float]] = [
            ((0, 0, 0), time.monotonic()) for _ in range(n_replicas)]
        # completion listeners (e.g. a CascadeRoute's gate); fired BEFORE the
        # response is put so an escalation's submit is counted before this
        # request's completion — the node can never observe a false drain.
        self.on_done: list[Callable[[Request], None]] = []
        self._stopped = False

    # ---------------------------------------------------------- admission
    def queue_depth(self, replica: int) -> int:
        """This replica's bounded-queue depth: engine backlog (queued +
        mid-prefill + decoding) plus THIS replica lambda's outstanding
        upcall events (the dispatcher's per-handle depth introspection) —
        requests trigger-put to this replica whose serving lambda hasn't
        finished enqueueing them yet.  Filtered per handle so another
        deployment's traffic on the shared worker never trips this
        deployment's watermark."""
        wid = self.worker_ids[replica]
        handle = self._handles[replica][0]
        return (self.engines[replica].backlog()
                + self.node.workers[wid].dispatcher.queue_depth(handle.name))

    def _least_loaded_sibling(self, replica: int) -> int | None:
        """The redirect target: the sibling with the smallest depth still
        under the watermark, or None when every sibling is saturated.

        Depth reads are deliberately lock-free HEURISTICS (MultiTASC++'s
        continuous decisions, not admission-control transactions): a
        sibling mid-lambda is transiently counted in both its upcall depth
        and its engine backlog, and two workers racing the same sibling can
        each redirect to it — so decisions can be off by ±1 per concurrent
        arrival.  The watermark bounds queue GROWTH, which tolerates that
        slack; serializing every admission through a node-wide lock would
        put a mutex on the fast path instead."""
        best, best_depth = None, None
        for r in range(len(self.engines)):
            if r == replica or r in self.down or self.engines[r].crashed:
                continue
            d = self.queue_depth(r)
            if d < self.watermark and (best is None or d < best_depth):
                best, best_depth = r, d
        return best

    def _can_preempt_for(self, req: Request, replica: int) -> bool:
        """Whether admitting ``req`` over the watermark is justified by the
        EDF policy: the target holds some request, in a DIFFERENT session,
        with a strictly later virtual deadline — either IN FLIGHT (the
        engine's tick-entry preemption can spill it to make room) or still
        QUEUED (``req`` will issue ahead of it, so the watermark's wait
        bound on this arrival holds; the later-deadline entry was already
        accepted and merely keeps its place).  A lock-free heuristic read
        over the engine's slot maps and its scheduler's arrival deque
        (``list(deque)`` snapshots atomically under the GIL — same
        discipline as ``_least_loaded_sibling``: the watermark bounds
        growth, so an off-by-one race admits at most one extra request,
        which the next tick's preemption or shed absorbs)."""
        if not self.preempt:
            return False
        eng = self.engines[replica]
        vdl = virtual_deadline(req)
        queued = list(eng.scheduler.waiting[eng.replica_id])
        inflight = [r for m in (eng.prefilling, eng.live)
                    for r in list(m.values())]
        return any(r.session_key != req.session_key
                   and virtual_deadline(r) > vdl
                   for r in inflight + queued)

    def _shed(self, req: Request, replica: int, depth: int) -> None:
        """MultiTASC++-style shed: refuse with a STRUCTURED reason so the
        client can tell overload from a model refusal or a short answer."""
        req.error = {"error": "shed_overload", "deployment": self.name,
                     "replica": replica, "depth": depth,
                     "watermark": self.watermark}
        with self._lock:
            self.shed += 1
        self._complete_request(req)

    # ------------------------------------------------- replica health
    def install_faults(self, injector) -> None:
        """Bind a ``serving.faults.FaultInjector`` to every replica's
        engine seams (tick + submit)."""
        for r, eng in enumerate(self.engines):
            eng.faults = injector.bind(self.name, r)

    def is_down(self, replica: int) -> bool:
        return replica in self.down

    def _failover_target(self, exclude: set[int] | tuple = ()) -> int | None:
        """Least-loaded HEALTHY replica (down/crashed/excluded skipped).
        No watermark here: completing an already-admitted request beats
        boundedness — shedding work the client was promised would turn a
        replica fault into an availability fault."""
        cands = [r for r in range(len(self.engines))
                 if r not in self.down and not self.engines[r].crashed
                 and r not in exclude]
        if not cands:
            return None
        return min(cands, key=self.queue_depth)

    def mark_down(self, replica: int, reason: str) -> None:
        """Take a dead/wedged replica out of service and re-home every
        request it holds.  DRIVER THREAD ONLY (it touches engine slot
        state); idempotent.  Order matters: the down-flag and the engine's
        ``crashed`` bit are set BEFORE evacuation so a submit racing this
        mark-down raises ``ReplicaCrashed`` and retries on a sibling
        instead of landing in the drained queue (``sweep_down`` catches
        the residual window)."""
        with self._lock:
            if replica in self.down:
                return
            self.down[replica] = reason
            self.failovers += 1
        eng = self.engines[replica]
        eng.crashed = True
        spill = eng.paged and eng.kv_recoverable
        try:
            queued, inflight = eng.evacuate(spill_kv=spill)
        except Exception:
            queued, inflight = [], []
        for req in queued:
            self._re_home(req, None)
        for req, spilled in inflight:
            self._re_home(req, spilled)

    def sweep_down(self) -> None:
        """Driver-thread sweep: re-home any request that slipped into a
        down replica's queue between the submit-side ``crashed`` check and
        the evacuation drain (the mark-down race's residual window)."""
        for r in list(self.down):
            eng = self.engines[r]
            if eng.idle():
                continue
            try:
                queued, inflight = eng.evacuate(spill_kv=False)
            except Exception:
                continue
            for req in queued:
                self._re_home(req, None)
            for req, spilled in inflight:
                self._re_home(req, spilled)

    def check_watchdog(self, now: float | None = None) -> None:
        """Per-replica tick watchdog (driver thread): a BUSY replica whose
        progress snapshot hasn't changed within ``watchdog_s`` is wedged —
        a healthy busy engine always advances ticks, prefill tokens, or
        output tokens every driver pass — and is marked down."""
        if self.watchdog_s is None:
            return
        now = time.monotonic() if now is None else now
        for r, eng in enumerate(self.engines):
            if r in self.down:
                continue
            snap = (eng.stats.ticks, eng.stats.prefill_tokens,
                    eng.stats.tokens_out)
            last, since = self._progress[r]
            if eng.idle() or snap != last:
                self._progress[r] = (snap, now)
            elif now - since > self.watchdog_s:
                self.mark_down(r, "stalled")

    def _fold_for_replay(self, req: Request) -> bool:
        """Replay folding now lives on the request itself
        (``Request.fold_for_replay`` — the preemption resume path needs it
        engine-side too); kept as a thin delegate for callers/tests."""
        return req.fold_for_replay()

    def _re_home(self, req: Request, spilled) -> None:
        """Move one evacuated request to a healthy sibling: KV migration
        (adopt the spilled blocks) when possible, replay otherwise; every
        path terminates — no sibling or no replay means a structured
        ``replica_failed`` completion, never a stranded request."""
        tried: set[int] = set()
        while True:
            target = self._failover_target(tried)
            if target is None:
                self._fail_request(req, "no healthy sibling to re-home onto")
                return
            eng = self.engines[target]
            if spilled is not None and not req.expired() \
                    and eng.adopt(req, spilled):
                with self._lock:
                    self.rehomed += 1
                    self.migrated += 1
                    self.routed[req.request_id] = target
                return
            replay = bool(req.tokens)
            if not self._fold_for_replay(req):
                self._fail_request(
                    req, "session not replayable (embeds prompt) and its "
                         "KV was unrecoverable")
                return
            try:
                eng.submit(req)
            except (ReplicaCrashed, InjectedFault):
                tried.add(target)
                with self._lock:
                    self.submit_retries += 1
                continue
            with self._lock:
                self.rehomed += 1
                if replay:
                    self.replayed += 1
                self.routed[req.request_id] = target
            return

    def _fail_request(self, req: Request, reason: str) -> None:
        """Terminal structured error for a request a fault orphaned: the
        client sees WHY (and keeps any partial tokens) instead of a result
        that never arrives."""
        req.error = {"error": "replica_failed", "deployment": self.name,
                     "reason": reason, "request_id": req.request_id,
                     "generated": len(req.tokens)}
        with self._lock:
            self.failover_failed += 1
        self._complete_request(req)

    # ------------------------------------------------------------- lambdas
    def _on_request(self, replica: int, obj: CascadeObject, _event) -> str:
        """The serving lambda: runs on the replica worker's upcall thread.
        Bounded admission happens HERE, at the door — before the request
        ever reaches an engine queue."""
        comps = obj.key.split("/")
        session, request_id = comps[-2], comps[-1]
        payload = obj.payload
        req = Request(request_id=request_id, session_key=session,
                      prompt=payload["prompt"],
                      max_new_tokens=int(payload.get("max_new_tokens", 16)),
                      draft_tokens=payload.get("draft"),
                      deadline_s=payload.get("deadline_s"))
        if payload.get("slo") is not None:
            req.slo = str(payload["slo"])
        if "t0" in payload:
            # deadline budgets are measured from CLIENT submit time, not
            # from when the upcall got scheduled
            req.arrived_s = payload["t0"]
        target = replica
        if target in self.down or self.engines[target].crashed:
            # arrival aimed at a dead replica (FIFO affinity outlives the
            # replica): re-target to the least-loaded healthy sibling
            t = self._failover_target()
            if t is None:
                self._fail_request(req, self.down.get(target, "crashed"))
                return request_id
            target = t
        if self.watermark is not None:
            # minus one: this very event still counts in the worker's
            # outstanding-upcall depth while we are running it
            depth = self.queue_depth(target) - 1
            if depth >= self.watermark:
                sibling = self._least_loaded_sibling(target)
                if sibling is not None:
                    target = sibling
                    with self._lock:
                        self.redirected += 1
                elif self._can_preempt_for(req, target):
                    # preempt-before-shed: every sibling is saturated, but
                    # the target holds an in-flight request with a strictly
                    # later virtual deadline — admit over the watermark and
                    # let the engine's tick-entry preemption make room by
                    # spilling that victim, instead of refusing work the
                    # EDF policy says should run first.  The overshoot is
                    # bounded: one admission per arrival, one spill per tick.
                    with self._lock:
                        self.preempt_admits += 1
                else:
                    self._shed(req, target, depth)
                    return request_id
        # Bounded retry with capped exponential backoff: a transient
        # injected/real submit failure (or a replica crashing between the
        # health check above and the enqueue) moves the request to the next
        # healthy sibling; exhaustion terminates with a structured error —
        # admission never strands a request.
        tried: set[int] = set()
        delay = self.retry_backoff_s
        for _ in range(self.retry_budget + 1):
            try:
                self.engines[target].submit(req)
            except (ReplicaCrashed, InjectedFault):
                tried.add(target)
                with self._lock:
                    self.submit_retries += 1
                nxt = self._failover_target(tried)
                if nxt is None:
                    break
                target = nxt
                time.sleep(delay)
                delay = min(delay * 2, 0.05)
                continue
            with self._lock:
                self.routed[request_id] = target
                while len(self.routed) > self._routed_cap:
                    self.routed.pop(next(iter(self.routed)))
            return request_id
        self._fail_request(req, "no healthy replica accepted the submit")
        return request_id

    def _on_engine_complete(self, req: Request) -> None:
        self._complete_request(req)

    def _complete_request(self, req: Request) -> None:
        """Completion at the store boundary, shared by engine completions,
        engine rejections, and admission sheds.  A refused request still
        completes — empty tokens at the normal key, and its reason under
        ``<request_id>/error`` so clients can tell refusal from a short
        generation (read it with ``error()``)."""
        req.done_s = req.done_s or time.monotonic()
        if self.spill_pool is not None:
            # terminal state reached outside an engine (shed, failover
            # failure): a preempted request's parked KV must not leak
            self.spill_pool.discard(req.request_id)
        for fn in list(self.on_done):
            try:
                fn(req)
            except Exception:
                # a listener failure (e.g. a cascade escalating into a
                # stopped deployment) must not lose THIS request's answer:
                # the response is still put and the completion still counted
                # (the client sees the un-escalated result), and the drain
                # can still finish.  Counted so operators can see it.
                with self._lock:
                    self.listener_errors += 1
        if req.error is not None:
            self.node.store.put(f"{self.out_prefix}/{req.request_id}/error",
                                req.error)
        self.node.store.put(f"{self.out_prefix}/{req.request_id}",
                            np.asarray(req.tokens, np.int32))
        with self._lock:
            self.completed += 1
        self.node._note_completed()

    # ------------------------------------------------------------- clients
    def submit(self, session_key: str, request_id: str, prompt: Any, *,
               max_new_tokens: int = 16, draft_tokens: Any = None,
               deadline_s: float | None = None, slo: str | None = None):
        """Fire a request into the fast path (trigger_put; nothing stored).
        ``draft_tokens`` rides in the payload for speculative deployments
        (``spec_k > 0``): token i is a guess for generated token i — this is
        how a cascade plants the light model's generation as the heavy
        model's draft.  ``deadline_s`` is the request's latency budget from
        THIS call; ``slo`` tags its class ("interactive" | "batch", default
        batch) — the issue queue derives priority from the class target when
        no explicit deadline is given, and preempting deployments may evict
        a batch victim's KV for an interactive waiter.  Transient store-seam
        failures retry with capped exponential backoff, and exhaustion
        completes the request with a structured error rather than raising
        after it was counted."""
        if self._stopped:
            raise RuntimeError(f"deployment {self.name!r} is stopped")
        key = f"{self.req_prefix}/{session_key}/{request_id}"
        with self._lock:
            self.submitted += 1
        self.node._note_submitted()
        t0 = time.monotonic()
        payload = {"prompt": np.asarray(prompt),
                   "max_new_tokens": max_new_tokens, "t0": t0}
        if draft_tokens is not None:
            payload["draft"] = np.asarray(draft_tokens, np.int32)
        if deadline_s is not None:
            payload["deadline_s"] = float(deadline_s)
        if slo is not None:
            payload["slo"] = str(slo)
        delay = self.retry_backoff_s
        for attempt in range(self.retry_budget + 1):
            try:
                return self.node.store.trigger_put(key, payload)
            except InjectedFault:
                with self._lock:
                    self.submit_retries += 1
                if attempt == self.retry_budget:
                    break
                time.sleep(delay)
                delay = min(delay * 2, 0.05)
        req = Request(request_id=request_id, session_key=session_key,
                      prompt=payload["prompt"],
                      max_new_tokens=max_new_tokens, deadline_s=deadline_s,
                      arrived_s=t0)
        self._fail_request(req, "store submit failed after retries")
        return None

    def result(self, request_id: str) -> np.ndarray | None:
        if self._stopped:
            return None          # out pool is gone with the deployment
        obj = self.node.store.get(f"{self.out_prefix}/{request_id}")
        return None if obj is None else np.asarray(obj.payload)

    def error(self, request_id: str):
        """Why a request was refused: an engine-rejection string, or a
        structured shed dict.  None while pending or on success."""
        if self._stopped:
            return None
        obj = self.node.store.get(f"{self.out_prefix}/{request_id}/error")
        return None if obj is None else obj.payload

    # --------------------------------------------------------------- stats
    def idle(self) -> bool:
        return all(eng.idle() for eng in self.engines)

    def stats(self) -> dict[str, Any]:
        """Latency/throughput/admission stats across this deployment."""
        ttft = sorted(t for e in self.engines for t in e.stats.ttft_s)
        tpot = sorted(t for e in self.engines for t in e.stats.tpot_s)
        queue_waits: dict[str, list[float]] = {}
        for e in self.engines:
            for slo, ws in e.stats.queue_wait_s.items():
                queue_waits.setdefault(slo, []).extend(ws)
        for ws in queue_waits.values():
            ws.sort()

        def pct(xs: list[float], q: float) -> float:
            return xs[min(len(xs) - 1, int(q * len(xs)))] if xs else float("nan")

        with self._lock:
            shed, redirected = self.shed, self.redirected
            preempt_admits = self.preempt_admits
            submitted, completed = self.submitted, self.completed
            listener_errors = self.listener_errors
            fault = {"down": dict(self.down),
                     "failovers": self.failovers,
                     "rehomed": self.rehomed,
                     "migrated": self.migrated,
                     "replayed": self.replayed,
                     "failover_failed": self.failover_failed,
                     "submit_retries": self.submit_retries}
        drafted = sum(e.stats.spec_drafted for e in self.engines)
        accepted = sum(e.stats.spec_accepted for e in self.engines)
        return {
            "deployment": self.name,
            "paged": self.paged,
            # KV pool precision: storage dtype knob + measured bytes per
            # token slot (K/V + scale leaves over every layer) — the number
            # the quantization win is asserted on, independent of wall-clock
            "kv_dtype": (self.engines[0].cm.kv_dtype if self.paged
                         else None),
            "kv_bytes_per_token": (self.engines[0].cm.kv_bytes_per_token()
                                   if self.paged else 0.0),
            "n_replicas": len(self.engines),
            "submitted": submitted,
            "completed": completed,
            "shed": shed,
            "redirected": redirected,
            "listener_errors": listener_errors,
            "requests": sum(e.stats.prefills for e in self.engines),
            "tokens_out": sum(e.stats.tokens_out for e in self.engines),
            "per_replica_requests": [e.stats.prefills for e in self.engines],
            "queue_depths": [self.queue_depth(r)
                             for r in range(len(self.engines))],
            "host_syncs": sum(e.stats.host_syncs for e in self.engines),
            "ticks": sum(e.stats.ticks for e in self.engines),
            "decode_ticks": sum(e.stats.decode_ticks for e in self.engines),
            "prefill_batches": sum(e.stats.prefill_batches for e in self.engines),
            "prefill_chunks": sum(e.stats.prefill_chunks for e in self.engines),
            "prompt_tokens": sum(e.stats.prompt_tokens for e in self.engines),
            "prefill_tokens": sum(e.stats.prefill_tokens for e in self.engines),
            "prefix_hit_tokens": sum(e.stats.prefix_hit_tokens
                                     for e in self.engines),
            "prefix_hits": sum(e.stats.prefix_hits for e in self.engines),
            "blocks_in_use": sum(e.stats.blocks_in_use for e in self.engines),
            # speculative decoding counters (0s when spec_k == 0; the rate
            # follows EngineStats.spec_acceptance_rate's convention — NaN
            # when nothing was drafted, distinct from "all rejected")
            "spec_drafted": drafted,
            "spec_accepted": accepted,
            "spec_rolled_back": sum(e.stats.spec_rolled_back
                                    for e in self.engines),
            "spec_acceptance_rate": (accepted / drafted if drafted
                                     else float("nan")),
            # fault tolerance: replica health + failover + deadlines
            **fault,
            "deadline_exceeded": sum(e.stats.deadline_exceeded
                                     for e in self.engines),
            "spill_syncs": sum(e.stats.spill_syncs for e in self.engines),
            "spilled_sessions": sum(e.stats.spilled_sessions
                                    for e in self.engines),
            "adopted_sessions": sum(e.stats.adopted_sessions
                                    for e in self.engines),
            # overload preemption (issue-queue scheduler; zeros when off)
            "preempt": self.preempt,
            "preemptions": sum(e.stats.preemptions for e in self.engines),
            "spilled_blocks": sum(e.stats.spilled_blocks
                                  for e in self.engines),
            "resumes": sum(e.stats.resumes for e in self.engines),
            "preempt_admits": preempt_admits,
            **(self.spill_pool.stats() if self.spill_pool is not None
               else {}),
            # per-SLO-class queue wait (issued_s - arrived_s) histograms
            "queue_wait_s": {
                slo: {"n": len(ws),
                      "p50_s": pct(ws, 0.50), "p99_s": pct(ws, 0.99)}
                for slo, ws in sorted(queue_waits.items())},
            "ttft_p50_s": pct(ttft, 0.50), "ttft_p99_s": pct(ttft, 0.99),
            "tpot_p50_s": pct(tpot, 0.50), "tpot_p99_s": pct(tpot, 0.99),
        }

    # ------------------------------------------------------------ teardown
    def stop(self) -> None:
        """Tear the deployment down: unregister its lambdas, remove its
        req/out pools from the store, drop its KV pools from the device
        store.  Call after draining — in-flight requests are the owner's
        responsibility (the node cannot answer them once the out pool is
        gone)."""
        if self._stopped:
            return
        self._stopped = True
        for handle, wid in self._handles:
            self.node.store.unregister_lambda(handle, [wid])
        self.node.store.remove_pool(self.req_prefix)
        self.node.store.remove_pool(self.out_prefix)
        if self.spill_pool is not None:
            self.node.store.remove_pool(self.spill_prefix)
        if self.paged and self.node._kv_store is not None:
            self.node._kv_store.remove_prefix(f"/kv/{self.name}")
        if self.meshes:
            self.node.release_device_slices(self.meshes)
            self.meshes = []
        self.node.deployments.pop(self.name, None)


class ServeNode:
    """One multi-tenant serving node-group: shared workers + store + KV
    device store, hosting any number of ``ModelDeployment``s.

    The driver loop (``run_until_drained`` / ``step``) round-robins ticks
    over every busy engine of every deployment — in the paper's deployment
    each replica's loop runs on its own node; here one thread drives them
    all (the jitted steps release the GIL into XLA either way) while the
    workers' upcall threads keep feeding the schedulers concurrently.
    """

    def __init__(self, *, n_workers: int = 2) -> None:
        # One upcall thread per worker: the single thread keeps FIFO
        # sessions ordered (the dispatcher's same-queue guarantee).
        self.workers = [Worker(i, n_upcall_threads=1)
                        for i in range(n_workers)]
        self.store = CascadeStore(self.workers)
        # One device store for every paged deployment's KV block pools
        # (keep_versions=1: decode rewrites all leaves each tick, retaining
        # predecessors would double pool memory).  Created lazily so a node
        # hosting only dense models allocates nothing.
        self._kv_store: DeviceStore | None = None
        self.deployments: dict[str, ModelDeployment] = {}
        self._lock = threading.Lock()
        self._submitted = 0
        self._completed = 0
        self._n_deployed = 0
        # Local accelerator pool for mesh-sliced deployments: slices are
        # carved off this free list (disjoint per replica) and returned on
        # deployment stop().  Single-device deployments never touch it.
        self._free_devices: list[Any] = list(jax.devices())

    def kv_store(self) -> DeviceStore:
        if self._kv_store is None:
            # The store mesh is only the DEFAULT placement for unregistered
            # keys; sliced deployments register per-key pool shardings that
            # carry their own slice meshes.
            self._kv_store = DeviceStore(
                jax.make_mesh((1, 1), ("data", "model")), keep_versions=1)
            self._kv_store.create_pool(PoolSpec(path="/kv"))
        return self._kv_store

    # ------------------------------------------------------- device slices
    def take_device_slices(self, n_slices: int, devices_per_slice: int):
        """Carve ``n_slices`` disjoint (1, devices_per_slice) meshes out of
        the node's free device pool (``launch.mesh.mesh_slices``).  Raises
        ValueError when the pool cannot cover the request — co-resident
        deployments hold their slices until stop()."""
        from repro.launch.mesh import mesh_slices
        with self._lock:
            meshes = mesh_slices(n_slices, devices_per_slice,
                                 devices=self._free_devices)
            taken = n_slices * devices_per_slice
            self._free_devices = self._free_devices[taken:]
        return meshes

    def release_device_slices(self, meshes) -> None:
        with self._lock:
            for m in meshes:
                self._free_devices.extend(m.devices.flat)

    # --------------------------------------------------------- deployments
    def deploy(self, name: str, cfg: ModelConfig, params, *,
               n_replicas: int = 2, n_slots: int = 4, max_len: int = 64,
               policy: DispatchPolicy = DispatchPolicy.ROUND_ROBIN,
               temperature: float = 0.0, paged: bool | None = None,
               block_size: int = 16, num_blocks: int | None = None,
               prefix_cache: bool = True, token_budget: int | None = None,
               watermark: int | None = None,
               spec_k: int = 0, watchdog_s: float | None = None,
               retry_budget: int = 2,
               retry_backoff_s: float = 0.002,
               preempt: bool = False,
               spill_capacity_blocks: int = 256,
               kv_dtype: str | None = None,
               devices_per_replica: int | None = None) -> ModelDeployment:
        """Host ``cfg`` under ``/serve/<name>``; see ``ModelDeployment``.
        ``watermark`` bounds each replica's queue depth (None = unbounded).
        ``spec_k`` > 0 enables speculative decoding on paged engines: up to
        that many draft tokens verified per decode row per tick.
        ``watchdog_s`` arms the per-replica tick watchdog (None = off): a
        busy replica with no tick progress within the bound is marked down
        and its sessions re-home to siblings.  ``retry_budget`` /
        ``retry_backoff_s`` bound the transient-submit retry loop.
        ``preempt`` (paged only) arms EDF preemption: under pressure an
        engine may spill one in-flight victim's KV per tick into a
        deployment-wide host-side spill pool (``spill_capacity_blocks``)
        and admission turns preempt-before-shed for higher-priority
        arrivals.
        ``kv_dtype`` (paged only; default ``cfg.kv_dtype``) sets the KV
        block pool storage precision — ``"int8"``/``"fp8_e4m3"`` quantize
        on write with per-(block, slot, kv-head) scales and the kernels
        dequantize in-register, roughly halving decode HBM traffic;
        ``stats()["kv_bytes_per_token"]`` reports the measured footprint.
        ``devices_per_replica`` (paged only; default single-device) gives
        each replica its own DISJOINT mesh slice of that many local
        devices: params and the KV block pool install sharded over the
        slice (kv_heads over 'model') and the unified tick compiles
        against it.
        """
        if name in self.deployments:
            raise ValueError(f"deployment {name!r} already exists")
        with self._lock:
            seed_base = self._n_deployed * 131
            self._n_deployed += 1
        dep = ModelDeployment(
            self, name, cfg, params, n_replicas=n_replicas, n_slots=n_slots,
            max_len=max_len, policy=policy, temperature=temperature,
            paged=paged, block_size=block_size, num_blocks=num_blocks,
            prefix_cache=prefix_cache, token_budget=token_budget,
            watermark=watermark, seed_base=seed_base, spec_k=spec_k,
            watchdog_s=watchdog_s, retry_budget=retry_budget,
            retry_backoff_s=retry_backoff_s, preempt=preempt,
            spill_capacity_blocks=spill_capacity_blocks, kv_dtype=kv_dtype,
            devices_per_replica=devices_per_replica)
        self.deployments[name] = dep
        return dep

    def deployment(self, name: str) -> ModelDeployment:
        return self.deployments[name]

    def install_faults(self, injector) -> None:
        """Install a ``serving.faults.FaultInjector`` at every seam: each
        deployed replica's tick/submit hooks plus the store's trigger_put
        hook.  Deploy first, then install (new deployments are not bound
        retroactively)."""
        for dep in self.deployments.values():
            dep.install_faults(injector)
        self.store.fault_hook = injector.store_hook()

    def undeploy(self, name: str) -> None:
        self.deployments[name].stop()

    # ----------------------------------------------------- request counting
    def _note_submitted(self) -> None:
        with self._lock:
            self._submitted += 1

    def _note_completed(self) -> None:
        with self._lock:
            self._completed += 1

    # -------------------------------------------------------------- driver
    def _idle(self) -> bool:
        return all(dep.idle() for dep in list(self.deployments.values()))

    def step(self) -> int:
        """Tick every busy engine across all deployments once; returns how
        many engines were busy.  Replica health runs here too: a crash
        (raised from the tick seam, or flagged from a submit-side fault)
        marks the replica down and re-homes its sessions; the per-replica
        watchdog catches wedged-but-not-crashed replicas; the down-sweep
        re-homes stragglers that raced into a dead replica's queue — so
        ``run_until_drained`` RESOLVES (every request reaches a terminal
        state) when a replica dies mid-drain, instead of timing out."""
        busy = 0
        for dep in list(self.deployments.values()):
            for r, eng in enumerate(dep.engines):
                if dep.is_down(r):
                    continue
                if eng.crashed:
                    dep.mark_down(r, "crashed")
                    continue
                if not eng.idle():
                    try:
                        eng.tick()
                    except ReplicaCrashed:
                        dep.mark_down(r, "crashed")
                        continue
                    busy += 1
            dep.check_watchdog()
            dep.sweep_down()
        return busy

    def _busy_report(self) -> str:
        """Name who is still holding the drain up (for TimeoutError)."""
        parts = []
        for dep in list(self.deployments.values()):
            if dep.down:
                parts.append(f"{dep.name}: down={dep.down}")
            for r, eng in enumerate(dep.engines):
                if not eng.idle():
                    parts.append(
                        f"{dep.name}/replica{r}(queued="
                        f"{eng.scheduler.pending(eng.replica_id)}, "
                        f"prefilling={len(eng.prefilling)}, "
                        f"decoding={len(eng.live)})")
        upcalls = sum(w.dispatcher.queue_depth() for w in self.workers)
        if upcalls:
            parts.append(f"{upcalls} in-flight upcall(s)")
        with self._lock:
            if self._completed < self._submitted:
                parts.append(f"{self._submitted - self._completed} request(s)"
                             f" awaiting completion")
        return "; ".join(parts) or "nothing visibly busy (lost completion?)"

    def run_until_drained(self, timeout_s: float = 60.0) -> None:
        """Tick every busy engine until every submitted request completed.

        Bounded by WALL CLOCK, not iteration count — idle spins while
        waiting on upcall delivery cost ~0.2 ms each and must not eat the
        budget of a slow prefill.  On timeout the error names the still-busy
        replicas and their queue states.
        """
        deadline = time.monotonic() + timeout_s
        while True:
            busy = self.step()
            if not busy:
                with self._lock:
                    done = self._completed == self._submitted
                if done and self._idle():
                    return
                time.sleep(0.0002)   # in-flight upcalls not yet enqueued
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"ServeNode did not drain within {timeout_s:.1f}s; "
                    f"still busy: {self._busy_report()}")

    # --------------------------------------------------------------- stats
    def stats(self) -> dict[str, Any]:
        with self._lock:
            submitted, completed = self._submitted, self._completed
        return {
            "n_workers": len(self.workers),
            "submitted": submitted,
            "completed": completed,
            "upcall_depths": [w.dispatcher.queue_depths()
                              for w in self.workers],
            "deployments": {name: dep.stats()
                            for name, dep in self.deployments.items()},
        }

    def close(self) -> None:
        self.store.close()

    def __enter__(self) -> "ServeNode":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ======================================================================
# Cascade escalation: light model first, heavy only when the gate trips
# ======================================================================
@dataclass
class CascadeGate:
    """The escalation decision (CascadeServe): a request whose light-model
    generation looks UNCERTAIN is re-run on the heavy model.

    ``metric="logprob"``: escalate when the mean per-token log-likelihood of
    the light generation falls below ``threshold`` (the model was guessing).
    ``metric="entropy"``: escalate when the mean next-token entropy exceeds
    ``threshold``.  Both read the per-token scores the engine's in-dispatch
    sampler surfaced — no extra device traffic, no logits on host.
    """
    metric: str = "logprob"
    threshold: float = -1.0

    def __post_init__(self) -> None:
        if self.metric not in ("logprob", "entropy"):
            raise ValueError(f"unknown gate metric {self.metric!r}")

    def trips(self, req: Request) -> bool:
        if self.metric == "logprob":
            return req.mean_logprob() < self.threshold
        return req.mean_entropy() > self.threshold


class CascadeRoute:
    """Submit to the light deployment; escalate gated requests to the heavy
    one via an internal trigger_put into its req pool (the request moves to
    the weights — the weights never move).

    ``escalate_on_error=True`` also fails over requests the light
    deployment refused (shed under overload, engine rejection) — the heavy
    deployment is the fallback path, with its own watermark as the final
    bound.  ``result()`` resolves to the heavy answer for escalated
    requests and the light answer otherwise.

    ``draft_from_light=True`` makes the cascade SELF-DRAFTING: a
    gate-escalated request carries the light model's generation as its
    draft stream, and a speculative heavy deployment (``spec_k > 0``)
    verifies those tokens k at a time in its one ragged dispatch instead of
    re-deriving them one tick each — the light model's work is never wasted
    (CascadeServe), it is the heavy model's draft model.  Wherever the
    heavy model agrees with the light answer, decode ticks collapse; where
    it disagrees, the acceptance rule rejects the drafts and the output is
    exactly what the heavy model alone would have produced.
    """

    def __init__(self, light: ModelDeployment, heavy: ModelDeployment,
                 gate: CascadeGate | None = None, *,
                 escalate_on_error: bool = True,
                 draft_from_light: bool = True) -> None:
        if light.node is not heavy.node:
            raise ValueError("cascade endpoints must share one ServeNode")
        self.light = light
        self.heavy = heavy
        self.gate = gate or CascadeGate()
        self.escalate_on_error = escalate_on_error
        self.draft_from_light = draft_from_light
        self._lock = threading.Lock()
        self._pending: dict[str, tuple[str, np.ndarray, int,
                                       float | None, float]] = {}
        # bounded like ModelDeployment.routed: a long-running route must not
        # grow per-request state forever (insertion-order eviction)
        self._escalated: dict[str, None] = {}
        self._escalated_cap = 4096
        self.requests = 0
        self.gate_trips = 0       # escalations decided by the gate
        self.error_failovers = 0  # escalations because light refused
        self.deadline_skips = 0   # escalations skipped: no budget left
        self.escalation_failures = 0  # heavy submits that failed after
        #                               retries (the light answer stands)
        light.on_done.append(self._on_light_done)

    # ------------------------------------------------------------- clients
    def submit(self, session_key: str, request_id: str, prompt: Any, *,
               max_new_tokens: int = 16, deadline_s: float | None = None):
        p = np.asarray(prompt)
        # record BEFORE submitting (the completion listener may fire before
        # submit returns), and roll back if the submit never happened — a
        # failed submit must not skew escalation_rate or leak the entry
        # (every request that does enter the light deployment completes —
        # served, rejected, or shed — so _pending is otherwise bounded by
        # what is in flight).  ``deadline_s`` is the END-TO-END budget from
        # this call: the heavy tier gets whatever remains after light.
        t0 = time.monotonic()
        with self._lock:
            self.requests += 1
            self._pending[request_id] = (session_key, p, max_new_tokens,
                                         deadline_s, t0)
        try:
            return self.light.submit(session_key, request_id, p,
                                     max_new_tokens=max_new_tokens,
                                     deadline_s=deadline_s)
        except BaseException:
            with self._lock:
                self.requests -= 1
                self._pending.pop(request_id, None)
            raise

    def escalated(self, request_id: str) -> bool:
        with self._lock:
            return request_id in self._escalated

    def _resolve(self, request_id: str) -> ModelDeployment:
        """Which deployment's answer is authoritative.  A RECENT escalation
        (still in the bounded set) resolves to heavy even while the heavy
        answer is pending; an escalation old enough to have been evicted
        from the set still resolves correctly because the heavy answer is
        DURABLE in the heavy out pool — eviction only loses the
        pending-escalation window, never the answer."""
        if self.escalated(request_id):
            return self.heavy
        if self.heavy.result(request_id) is not None:
            return self.heavy
        return self.light

    def result(self, request_id: str) -> np.ndarray | None:
        return self._resolve(request_id).result(request_id)

    def error(self, request_id: str):
        return self._resolve(request_id).error(request_id)

    # ---------------------------------------------------------- escalation
    def _on_light_done(self, req: Request) -> None:
        """Light-deployment completion listener: runs the gate and, when it
        trips, fires the internal trigger_put into the heavy pool.  Runs on
        the node's driver thread (engine completions) or a worker upcall
        thread (rejections/sheds) — before the light response is put, so
        the heavy submission is always counted before this completion and
        the node can never observe a false drain."""
        with self._lock:
            info = self._pending.pop(req.request_id, None)
        if info is None:
            return                      # not routed through this cascade
        session, prompt, max_new, deadline, t0 = info
        if req.error is not None:
            # a deadline_exceeded from the light tier is terminal: the
            # budget is spent, escalating would only burn heavy capacity on
            # an answer the client has already written off
            if (isinstance(req.error, dict)
                    and req.error.get("error") == "deadline_exceeded"):
                with self._lock:
                    self.deadline_skips += 1
                return
            if not self.escalate_on_error:
                return
            reason = "error_failover"
        elif self.gate.trips(req):
            reason = "gate"
        else:
            return
        # deadline-aware escalation: the heavy tier only gets what remains
        # of the END-TO-END budget.  An exhausted budget skips escalation —
        # the light answer stands (or its error does) rather than queueing
        # heavy work guaranteed to expire.
        remaining: float | None = None
        if deadline is not None:
            remaining = deadline - (time.monotonic() - t0)
            if remaining <= 0:
                with self._lock:
                    self.deadline_skips += 1
                return
        # submit FIRST, record after: a failed heavy submit (e.g. stopped
        # deployment) must not leave the request marked escalated — the
        # route would then resolve to a heavy answer that can never come.
        # The reverse race (heavy completing before the set is updated) is
        # harmless: _resolve falls back to the durable heavy out pool.
        # Self-drafting: a gate escalation ships the light generation as the
        # heavy deployment's draft stream (error failovers have no tokens).
        draft = (np.asarray(req.tokens, np.int32)
                 if self.draft_from_light and reason == "gate" and req.tokens
                 else None)
        # bounded retry: a heavy replica crashing at the submit seam (or an
        # injected transient) must not strand the request — retry briefly,
        # and on exhaustion let the light answer stand rather than raising
        # into the completion listener (satellite: heavy-tier crash after a
        # successful light pass must resolve, never pend forever).  A
        # submit that the deployment itself contains (returns None after
        # _fail_request) resolves via the heavy error path.
        delay = 0.002
        for attempt in range(3):
            try:
                self.heavy.submit(session, req.request_id, prompt,
                                  max_new_tokens=max_new, draft_tokens=draft,
                                  deadline_s=remaining)
                break
            except (ReplicaCrashed, InjectedFault):
                if attempt == 2:
                    with self._lock:
                        self.escalation_failures += 1
                    return
                time.sleep(delay)
                delay = min(delay * 2, 0.05)
        with self._lock:
            self._escalated[req.request_id] = None
            while len(self._escalated) > self._escalated_cap:
                self._escalated.pop(next(iter(self._escalated)))
            if reason == "gate":
                self.gate_trips += 1
            else:
                self.error_failovers += 1

    # --------------------------------------------------------------- stats
    def stats(self) -> dict[str, Any]:
        with self._lock:
            n, trips, fails = self.requests, self.gate_trips, \
                self.error_failovers
            skips, esc_fails = self.deadline_skips, self.escalation_failures
        return {
            "light": self.light.name, "heavy": self.heavy.name,
            "metric": self.gate.metric, "threshold": self.gate.threshold,
            "requests": n,
            "escalated": trips + fails,
            "gate_trips": trips,
            "error_failovers": fails,
            "deadline_skips": skips,
            "escalation_failures": esc_fails,
            "escalation_rate": (trips + fails) / n if n else float("nan"),
        }


# ======================================================================
# Single-model convenience wrapper (the pre-multi-tenant API)
# ======================================================================
class ServeCluster:
    """One model on its own ``ServeNode`` — the single-tenant special case.

    Kept as the convenience entry point (tests, benchmarks, quick drivers):
    construct with a config and params and get N replicas behind the fast
    path, exactly as before the node/deployment split.  Multi-model hosting,
    bounded admission and cascade routing live on ``ServeNode`` /
    ``ModelDeployment`` / ``CascadeRoute``.
    """

    def __init__(self, cfg: ModelConfig, params, *, n_replicas: int = 2,
                 n_slots: int = 4, max_len: int = 64,
                 policy: DispatchPolicy = DispatchPolicy.ROUND_ROBIN,
                 model_name: str | None = None,
                 temperature: float = 0.0, paged: bool | None = None,
                 block_size: int = 16, num_blocks: int | None = None,
                 prefix_cache: bool = True,
                 token_budget: int | None = None,
                 watermark: int | None = None,
                 spec_k: int = 0,
                 watchdog_s: float | None = None,
                 retry_budget: int = 2,
                 retry_backoff_s: float = 0.002,
                 preempt: bool = False,
                 spill_capacity_blocks: int = 256,
                 kv_dtype: str | None = None,
                 devices_per_replica: int | None = None) -> None:
        self.node = ServeNode(n_workers=n_replicas)
        self.dep = self.node.deploy(
            model_name or cfg.name, cfg, params, n_replicas=n_replicas,
            n_slots=n_slots, max_len=max_len, policy=policy,
            temperature=temperature, paged=paged, block_size=block_size,
            num_blocks=num_blocks, prefix_cache=prefix_cache,
            token_budget=token_budget, watermark=watermark, spec_k=spec_k,
            watchdog_s=watchdog_s, retry_budget=retry_budget,
            retry_backoff_s=retry_backoff_s, preempt=preempt,
            spill_capacity_blocks=spill_capacity_blocks, kv_dtype=kv_dtype,
            devices_per_replica=devices_per_replica)
        self.cfg = cfg
        self.policy = policy

    # ------------------------------------------------ delegated attributes
    @property
    def workers(self):
        return self.node.workers

    @property
    def store(self):
        return self.node.store

    @property
    def kv_store(self):
        return self.node._kv_store

    @property
    def engines(self):
        return self.dep.engines

    @property
    def routed(self):
        return self.dep.routed

    @property
    def paged(self):
        return self.dep.paged

    @property
    def req_prefix(self):
        return self.dep.req_prefix

    @property
    def out_prefix(self):
        return self.dep.out_prefix

    # ------------------------------------------------------------- clients
    def submit(self, session_key: str, request_id: str, prompt: Any, *,
               max_new_tokens: int = 16, deadline_s: float | None = None,
               slo: str | None = None):
        return self.dep.submit(session_key, request_id, prompt,
                               max_new_tokens=max_new_tokens,
                               deadline_s=deadline_s, slo=slo)

    def result(self, request_id: str) -> np.ndarray | None:
        return self.dep.result(request_id)

    def error(self, request_id: str):
        err = self.dep.error(request_id)
        return None if err is None else str(err)

    def run_until_drained(self, timeout_s: float = 60.0) -> None:
        self.node.run_until_drained(timeout_s)

    def stats(self) -> dict[str, Any]:
        return self.dep.stats()

    def close(self) -> None:
        self.node.close()

    def __enter__(self) -> "ServeCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

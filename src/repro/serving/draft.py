"""Draft sources for speculative decoding on the unified tick.

A ``DraftSource`` proposes up to k candidate next tokens for a decode row;
the engine packs them behind the row's last committed token so the target
model VERIFIES all of them in the one existing ragged dispatch, and the
in-dispatch acceptance rule (models.sampling.speculative_verify) keeps the
longest target-confirmed prefix.  Drafting is pure host-side bookkeeping —
no extra model dispatch, no extra device→host sync — so a draft source must
be cheap: it runs on the tick's critical path once per live decode row.

Two sources ship, composed by default:

``RequestDraftSource`` — the cascade drafter (CascadeServe's "light work is
    never wasted"): a request escalated light→heavy carries the LIGHT
    deployment's generation in ``Request.draft_tokens``, and the heavy
    model verifies those tokens k at a time instead of re-deriving them one
    tick each.  Drafts are proposed only while the heavy generation is
    still on-script (its tokens so far equal the draft prefix) — once it
    diverges the light answer is no longer predictive and lanes are better
    spent elsewhere.

``NgramDraftSource`` — self-drafting (prompt-lookup decoding): match the
    trailing n-gram of prompt+generated against earlier occurrences in the
    same history and propose the continuation after the most recent match.
    Free lunch on repetitive text (quotes, code, structured output);
    harmless elsewhere (unaccepted drafts cost only spare budget lanes the
    acceptance rule rejects in-dispatch).
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from .scheduler import Request

# Lazily-built request history (prompt + generated tokens): the engine hands
# sources a zero-arg provider instead of the array itself, so a source that
# never looks at history (RequestDraftSource — the cascade path) costs no
# O(S + generated) concatenation per row per tick.
HistoryFn = Callable[[], np.ndarray]


class DraftSource:
    """Proposes up to ``k`` draft tokens continuing the request."""

    def propose(self, req: Request, history: HistoryFn, k: int) -> list[int]:
        """``history()`` returns the request's prompt + generated tokens
        (the last entry is the token about to be fed) — call it only if
        needed; it is built on first call.  Return 0..k int tokens that
        guess the continuation.  Fewer than k is fine; an empty list means
        "no guess" and the row decodes plainly this tick."""
        raise NotImplementedError


class NgramDraftSource(DraftSource):
    """Self-drafting from the request's own history (prompt lookup).
    ``max_history`` bounds the per-tick scan (and the match window) so
    drafting stays O(max_history), not O(prompt + generated), on the
    tick's critical path."""

    def __init__(self, n: int = 3, max_history: int = 2048) -> None:
        if n < 1:
            raise ValueError("n-gram order must be >= 1")
        self.n = n
        self.max_history = max_history

    def propose(self, req: Request, history: HistoryFn, k: int) -> list[int]:
        h = np.asarray(history())
        if self.max_history is not None:
            h = h[-self.max_history:]
        L = len(h)
        n = self.n
        if k <= 0 or L <= n:
            return []
        suffix = h[L - n:]
        windows = np.lib.stride_tricks.sliding_window_view(h, n)
        matches = np.flatnonzero((windows == suffix).all(axis=1))
        matches = matches[matches < L - n]          # drop the trivial self-match
        if len(matches) == 0:
            return []
        i = int(matches[-1])                        # most recent occurrence
        return [int(t) for t in h[i + n:i + n + k]]


class RequestDraftSource(DraftSource):
    """Drafts carried BY the request (``Request.draft_tokens``): token i of
    the draft is the guess for generated token i.  Proposed only while the
    generation is on-script (generated tokens == draft prefix).  Never
    touches ``history`` — the cascade fast path does no per-tick copies."""

    def propose(self, req: Request, history: HistoryFn, k: int) -> list[int]:
        d = req.draft_tokens
        if d is None or k <= 0:
            return []
        d = np.asarray(d)
        g = len(req.tokens)
        if g == 0 or g >= len(d):
            return []
        if not np.array_equal(np.asarray(req.tokens, dtype=np.int64),
                              np.asarray(d[:g], dtype=np.int64)):
            return []
        return [int(t) for t in d[g:g + k]]


class ChainDraftSource(DraftSource):
    """First source that yields tokens wins."""

    def __init__(self, sources: list[DraftSource]) -> None:
        self.sources = list(sources)

    def propose(self, req: Request, history: HistoryFn, k: int) -> list[int]:
        for s in self.sources:
            out = s.propose(req, history, k)
            if out:
                return out
        return []


def default_draft_source() -> DraftSource:
    """Engine default: request-carried drafts (the cascade path) first,
    n-gram self-drafting as the fallback."""
    return ChainDraftSource([RequestDraftSource(), NgramDraftSource()])

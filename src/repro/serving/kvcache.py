"""KV-cache slot manager for continuous batching.

The engine owns one global cache tree (batch dim = n_slots).  Each slot is
leased to a live request; prefill produces a single-sequence cache that is
spliced into the slot (a device-side dynamic_update_slice per leaf — no host
copies, per the fast-path discipline).  Slot position counters live on host;
cache tensors never leave the device.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import init_decode_caches
from repro.models.config import ModelConfig


def _splice_slot(global_caches, one_caches, slot: int):
    """Write a B=1 cache tree into batch row `slot` of the global tree.

    Cache leaves are stacked (R, B, ...): batch is axis 1 for array leaves
    of rank>=2; mamba 'ssm'/'conv' leaves follow the same convention.
    """
    def splice(g, o):
        return jax.lax.dynamic_update_slice_in_dim(g, o.astype(g.dtype), slot, axis=1)
    return jax.tree.map(splice, global_caches, one_caches)


@dataclass
class SlotState:
    request_id: str | None = None
    pos: int = 0            # next absolute position to decode
    active: bool = False


class CacheManager:
    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int) -> None:
        self.cfg, self.n_slots, self.max_len = cfg, n_slots, max_len
        self.caches = init_decode_caches(cfg, n_slots, max_len)
        self.slots = [SlotState() for _ in range(n_slots)]
        self._splice = jax.jit(_splice_slot, static_argnums=(2,))

    def acquire(self, request_id: str) -> int | None:
        for i, s in enumerate(self.slots):
            if not s.active:
                self.slots[i] = SlotState(request_id=request_id, active=True)
                return i
        return None

    def release(self, slot: int) -> None:
        self.slots[slot] = SlotState()

    def insert_prefill(self, slot: int, one_caches, prompt_len: int) -> None:
        self.caches = self._splice(self.caches, one_caches, slot)
        self.slots[slot].pos = prompt_len

    def active_mask(self) -> jnp.ndarray:
        return jnp.asarray([s.active for s in self.slots], dtype=bool)

    def positions(self) -> jnp.ndarray:
        return jnp.asarray([s.pos for s in self.slots], dtype=jnp.int32)

    def advance(self) -> None:
        for s in self.slots:
            if s.active:
                s.pos += 1

    @property
    def n_active(self) -> int:
        return sum(s.active for s in self.slots)

"""KV-cache slot manager for continuous batching.

The engine owns one global cache tree (batch dim = n_slots).  Each slot is
leased to a live request; prefill produces a single-sequence cache that is
spliced into the slot (a device-side dynamic_update_slice per leaf — no host
copies, per the fast-path discipline).  Slot position counters live on host;
cache tensors never leave the device.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import init_decode_caches
from repro.models.config import ModelConfig


def _splice_slot(global_caches, src_caches, slot, row):
    """Write row ``row`` of a B=k cache tree into batch row ``slot`` of the
    global tree (device-side; no host copies).

    ``slot``/``row`` are traced operands (not static), so every
    (slot, row, k) splice for a given source batch size shares one compiled
    program instead of compiling per index pair.

    Cache leaves are stacked (R, B, ...): batch is axis 1 for array leaves
    of rank>=2; mamba 'ssm'/'conv' leaves follow the same convention.
    """
    def splice(g, o):
        one = jax.lax.dynamic_slice_in_dim(o, row, 1, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(g, one.astype(g.dtype), slot, axis=1)
    return jax.tree.map(splice, global_caches, src_caches)


# Jitted once at module scope: every CacheManager (hence every cluster
# replica) shares one compilation per (cache structure, source batch) shape.
_splice_jit = jax.jit(_splice_slot)


@dataclass
class SlotState:
    request_id: str | None = None
    pos: int = 0            # next absolute position to decode
    active: bool = False


class CacheManager:
    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int) -> None:
        self.cfg, self.n_slots, self.max_len = cfg, n_slots, max_len
        self.caches = init_decode_caches(cfg, n_slots, max_len)
        self.slots = [SlotState() for _ in range(n_slots)]
        self._splice = _splice_jit

    def acquire(self, request_id: str) -> int | None:
        for i, s in enumerate(self.slots):
            if not s.active:
                self.slots[i] = SlotState(request_id=request_id, active=True)
                return i
        return None

    def release(self, slot: int) -> None:
        self.slots[slot] = SlotState()

    def insert_prefill(self, slot: int, src_caches, prompt_len: int,
                       row: int = 0) -> None:
        """Splice row ``row`` of a (possibly batched) prefill cache tree into
        ``slot``; batched admission splices one row per admitted request."""
        self.caches = self._splice(self.caches, src_caches,
                                   jnp.int32(slot), jnp.int32(row))
        self.slots[slot].pos = prompt_len

    def active_mask(self) -> jnp.ndarray:
        return jnp.asarray([s.active for s in self.slots], dtype=bool)

    def positions(self) -> jnp.ndarray:
        return jnp.asarray([s.pos for s in self.slots], dtype=jnp.int32)

    def advance(self) -> None:
        for s in self.slots:
            if s.active:
                s.pos += 1

    @property
    def n_active(self) -> int:
        return sum(s.active for s in self.slots)

"""KV-cache managers for continuous batching: dense slots + paged blocks.

Dense manager (``CacheManager``): the engine owns one global cache tree
(batch dim = n_slots).  Each slot is leased to a live request; prefill
produces a single-sequence cache that is spliced into the slot (a device-side
dynamic_update_slice per leaf — no host copies, per the fast-path
discipline).  Slot position counters live on host; cache tensors never leave
the device.  This remains the path for architectures whose decode state
cannot be paged (SSM/conv state carries the whole history in O(1) per
request) and for embeds-mode frontends.

KV paging & prefix cache (``PagedCacheManager``)
------------------------------------------------
For pure-attention models the per-slot dense tree is replaced by a **global
block pool**: every layer holds (num_blocks, block_size, K, D) K/V tensors,
and a request's cache is a *block table* — the list of physical blocks that
back its logical positions [0, ctx).  The pool is a Cascade object: it is
``put`` on a ``core.devstore.DeviceStore`` under the engine's ``/kv`` pool
key after every mutation (a reference install, never a copy), so KV state
gets the same placement/versioning treatment as any other device object.
On a multi-tenant ``ServeNode`` all deployments share ONE device store and
keys are namespaced ``/kv/<model>/replica<r>/pool``; deployment teardown
drops the prefix and the pool memory with it.

On top of the pool sits a **per-replica prefix cache**: a trie over prompt
token *blocks* (``core.trie.PathTrie`` — the dispatcher's path-prefix
matcher — keyed by one path component per block of tokens).  A new request
walks the trie with its prompt; every matched block is reused by reference
(refcount++) and prefill skips straight to the first divergent block,
computing only the suffix.  Because sharing is block-aligned, copy-on-write
degenerates to refcounting: a shared block is never written (a request's own
tokens always land in its private tail blocks), so the "copy" arm of COW
never executes.  Commit is at CHUNK granularity
(``commit_prefill_progress``): full blocks are donated to the trie the
moment their tokens are packed into the tick's mixed dispatch, so a
same-tick later admission with the same prefix matches them instead of
prefilling its own copy (intra-batch sharing — the packed step writes all
K/V before any token reads, which makes the not-yet-dispatched blocks safe
to share).  Requests that still race to prefill the same prefix from
different ticks' partial progress are reconciled by commit-time dedup:
whoever commits second adopts the incumbent's blocks and frees its
duplicates, so block references always follow the trie's own chains and the
allocator's free+evictable accounting stays exact.  Completed requests
donate their full blocks (prompt AND generated tokens) back to the trie;
unreferenced cached blocks are reclaimed LRU-first when the free list runs
dry.  Block 0 is a reserved null block: inactive decode rows are clamped
onto it so masked lanes scribble harmlessly.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trie import PathTrie
from repro.models import init_decode_caches, init_paged_pools
from repro.models.config import ModelConfig


def _splice_slot(global_caches, src_caches, slot, row):
    """Write row ``row`` of a B=k cache tree into batch row ``slot`` of the
    global tree (device-side; no host copies).

    ``slot``/``row`` are traced operands (not static), so every
    (slot, row, k) splice for a given source batch size shares one compiled
    program instead of compiling per index pair.

    Cache leaves are stacked (R, B, ...): batch is axis 1 for array leaves
    of rank>=2; mamba 'ssm'/'conv' leaves follow the same convention.
    """
    def splice(g, o):
        one = jax.lax.dynamic_slice_in_dim(o, row, 1, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(g, one.astype(g.dtype), slot, axis=1)
    return jax.tree.map(splice, global_caches, src_caches)


# Jitted once at module scope: every CacheManager (hence every cluster
# replica) shares one compilation per (cache structure, source batch) shape.
_splice_jit = jax.jit(_splice_slot)


def _gather_blocks(pools, idx):
    """Gather the blocks ``idx`` (table order) out of every pool leaf —
    device-side; leaves are (repeat, num_blocks, bs, K, D), block axis 1."""
    return jax.tree.map(lambda leaf: leaf[:, idx], pools)


def _scatter_blocks(pools, blocks, idx):
    """Scatter migrated blocks into freshly allocated pool slots ``idx``."""
    return jax.tree.map(
        lambda p, b: p.at[:, idx].set(b.astype(p.dtype)), pools, blocks)


# Spill gathers are read-only (the source pool stays live until release);
# restore scatters rewrite every leaf, so the pool operand is donated — same
# discipline as the engine's mixed step, and the caller reassigns
# ``self.pools`` from the result before publishing.  Both compile once per
# distinct block COUNT (the failover path is rare; a compile there is fine).
_gather_jit = jax.jit(_gather_blocks)
_scatter_jit = jax.jit(_scatter_blocks, donate_argnums=(0,))


@dataclass
class SpilledKV:
    """A live session's committed KV, spilled off a replica: the host-side
    tree of its table's blocks in TABLE ORDER, plus the positions they back.
    Restoring allocates the same COUNT of fresh blocks and scatters these in
    — the session resumes decoding at ``pos`` as if it had never moved (KV
    is valid over [0, pos)).

    Two producers, one restore path: failover spills a DEAD replica's live
    slots (``engine.evacuate`` → deployment ``_re_home`` adopts immediately
    on a sibling), and preemption spills a low-priority victim's slot into
    the host-side ``core.store.SpillPool``, where the entry PARKS — as a
    Cascade object when the pool is store-backed — until the request
    re-issues and ``engine.adopt`` unparks it.  Either way ``adopt`` is the
    single restore site, with prompt replay (``Request.fold_for_replay``)
    as the fallback when the entry was evicted or geometry changed."""
    request_id: str
    pos: int                      # next position to write on resume
    n_blocks: int
    block_size: int
    blocks: Any                   # host pytree, leaves (..., n_blocks, bs, K, D)

    @property
    def nbytes(self) -> int:
        """Host bytes this entry pins while parked (spill-pool accounting
        is in blocks; bytes are for observability).  A property, numpy
        style, so ``CascadeObject.nbytes()`` sizes a parked entry correctly
        when the spill pool publishes it to the store."""
        total = 0
        for leaf in jax.tree.leaves(self.blocks):
            total += np.asarray(leaf).nbytes
        return total


@dataclass
class SlotState:
    request_id: str | None = None
    pos: int = 0            # next absolute position to decode
    active: bool = False


class CacheManager:
    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int) -> None:
        self.cfg, self.n_slots, self.max_len = cfg, n_slots, max_len
        self.caches = init_decode_caches(cfg, n_slots, max_len)
        self.slots = [SlotState() for _ in range(n_slots)]
        self._splice = _splice_jit

    def acquire(self, request_id: str) -> int | None:
        for i, s in enumerate(self.slots):
            if not s.active:
                self.slots[i] = SlotState(request_id=request_id, active=True)
                return i
        return None

    def release(self, slot: int) -> None:
        self.slots[slot] = SlotState()

    def insert_prefill(self, slot: int, src_caches, prompt_len: int,
                       row: int = 0) -> None:
        """Splice row ``row`` of a (possibly batched) prefill cache tree into
        ``slot``; batched admission splices one row per admitted request."""
        self.caches = self._splice(self.caches, src_caches,
                                   jnp.int32(slot), jnp.int32(row))
        self.slots[slot].pos = prompt_len

    def active_mask(self) -> jnp.ndarray:
        return jnp.asarray([s.active for s in self.slots], dtype=bool)

    def positions(self) -> jnp.ndarray:
        return jnp.asarray([s.pos for s in self.slots], dtype=jnp.int32)

    def advance(self) -> None:
        for s in self.slots:
            if s.active:
                s.pos += 1

    @property
    def n_active(self) -> int:
        return sum(s.active for s in self.slots)


# ======================================================================
# Paged KV cache with trie-based prefix reuse
# ======================================================================
@dataclass
class _CachedBlock:
    """Trie residency record for one full token block."""
    block: int
    key: str                 # trie path ("/<blk0>/<blk1>/.../<blki>")
    parent: str | None
    children: int = 0        # cached child blocks (pin: can't evict parents)
    last_used: int = 0       # allocator clock at last touch (LRU)


class PrefixBlockAllocator:
    """Host-side block accounting: free list, refcounts, and the token-block
    prefix trie.  Touches no device memory — it only hands out block ids.

    The trie reuses ``core.trie.PathTrie`` (the dispatcher's Fig-2 prefix
    matcher): a prompt's i-th full block becomes the path component
    ``"-".join(tokens[i*bs:(i+1)*bs])``, so ``PathTrie.match`` over the whole
    prompt path returns exactly the chain of consecutive cached blocks —
    prefix matching on keys and prefix matching on token histories are the
    same operation.  A cached block's KV is valid for any request whose
    prompt shares the full path down to it, because K/V at a position is a
    deterministic function of (params, all preceding tokens, position).
    """

    def __init__(self, num_blocks: int, block_size: int, *,
                 enable_cache: bool = True) -> None:
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.enable_cache = enable_cache
        # block 0 reserved: the null block masked lanes are clamped onto
        self.free: list[int] = list(range(num_blocks - 1, 0, -1))
        self.refcount = [0] * num_blocks
        self.trie: PathTrie[_CachedBlock] = PathTrie()
        self._cached: dict[str, _CachedBlock] = {}
        self._by_block: dict[int, _CachedBlock] = {}
        self._clock = 0
        self.evictions = 0
        self.dedup_blocks = 0    # duplicate blocks swapped for incumbents

    # ------------------------------------------------------------- helpers
    def _block_key(self, tokens: Sequence[int], i: int) -> str:
        """THE trie key encoding of one full token block (path component)."""
        bs = self.block_size
        return "-".join(str(int(t)) for t in tokens[i * bs:(i + 1) * bs])

    def _components(self, tokens: Sequence[int], n_blocks: int) -> list[str]:
        return [self._block_key(tokens, i) for i in range(n_blocks)]

    def _touch(self, meta: _CachedBlock) -> None:
        self._clock += 1
        meta.last_used = self._clock

    # --------------------------------------------------------------- match
    def match(self, tokens: Sequence[int], max_blocks: int) -> list[int]:
        """Longest chain of cached blocks covering a prefix of ``tokens``
        (capped at ``max_blocks``); matched blocks are ref'd and LRU-touched.
        """
        if not self.enable_cache:
            return []
        n_full = min(len(tokens) // self.block_size, max_blocks)
        if n_full <= 0:
            return []
        key = "/" + "/".join(self._components(tokens, n_full))
        chain = self.trie.match(key)          # shallow → deep, consecutive
        out = []
        for meta in chain:
            self.refcount[meta.block] += 1
            self._touch(meta)
            out.append(meta.block)
        return out

    # ------------------------------------------------------------ allocate
    def allocate(self, n: int) -> list[int] | None:
        """Pop ``n`` fresh blocks, evicting LRU unreferenced cached blocks
        as needed.  Returns None (allocating nothing) if that's impossible."""
        if n <= 0:
            return []
        while len(self.free) < n:
            if not self._evict_one():
                return None
        out = [self.free.pop() for _ in range(n)]
        for b in out:
            self.refcount[b] += 1
        return out

    def _evict_one(self) -> bool:
        best: _CachedBlock | None = None
        for meta in self._cached.values():
            if self.refcount[meta.block] == 0 and meta.children == 0:
                if best is None or meta.last_used < best.last_used:
                    best = meta
        if best is None:
            return False
        self.trie.remove(best.key, best)
        del self._cached[best.key]
        del self._by_block[best.block]
        if best.parent is not None:
            self._cached[best.parent].children -= 1
        self.free.append(best.block)
        self.evictions += 1
        return True

    def available(self) -> int:
        """Blocks obtainable right now: free + evictable (cached, unref'd).
        References land only on trie-incumbent blocks (``match`` refs
        root-consecutive chains; ``cache_blocks`` swaps duplicates for
        incumbents at commit), so a referenced cached block's ancestors are
        referenced too — equivalently, an unreferenced cached block heads an
        unreferenced subtree, which leaf-first iterated eviction can always
        reclaim."""
        evictable = sum(1 for m in self._cached.values()
                        if self.refcount[m.block] == 0)
        return len(self.free) + evictable

    @property
    def blocks_in_use(self) -> int:
        """Non-null blocks currently held (leased to requests or cached)."""
        return self.num_blocks - 1 - len(self.free)

    # --------------------------------------------------------------- cache
    def path_key(self, tokens: Sequence[int], n_blocks: int) -> str:
        """Trie path of the first ``n_blocks`` full blocks of ``tokens``
        ("" for zero blocks) — the resume point for ``cache_blocks_range``.
        """
        if n_blocks <= 0:
            return ""
        return "/" + "/".join(self._components(tokens, n_blocks))

    def cache_blocks(self, tokens: Sequence[int], table: list[int]) -> int:
        """Donate the full blocks of ``tokens`` (backed by ``table``) to the
        trie, walking from the root.  Returns how many were newly cached."""
        n_full = min(len(tokens) // self.block_size, len(table))
        added, _ = self.cache_blocks_range(tokens, table, 0, n_full, "")
        return added

    def cache_blocks_range(self, tokens: Sequence[int], table: list[int],
                           start: int, stop: int, prefix_key: str
                           ) -> tuple[int, str]:
        """Donate blocks [start, stop) of ``tokens`` to the trie, resuming
        under the already-committed path ``prefix_key`` (the caller carries
        it across chunks, so per-chunk commit does O(chunk) — not O(prefix)
        — key-building work on the tick's host path).  Chains strictly:
        block i is cached only under an existing (or just-created) parent
        path, so every trie chain is consecutive.

        Commit-time dedup: when a path is already cached under a DIFFERENT
        physical block (two requests racing to prefill a shared prefix from
        different ticks' partial progress), ``table`` is rewritten in place
        to the cached incumbent and the duplicate block is released — its
        K/V is identical (same tokens, same positions).  This keeps every
        reference on the trie's own chain, so a referenced cached block's
        ancestors are always referenced too; ``available`` counts on that
        invariant.  Returns (newly cached count, extended path key)."""
        if not self.enable_cache:
            return 0, prefix_key
        added = 0
        key = prefix_key
        for i in range(start, stop):
            parent = key or None
            key += "/" + self._block_key(tokens, i)
            meta = self._cached.get(key)
            if meta is not None:
                self._touch(meta)
                blk = int(table[i])
                if blk != meta.block:
                    # duplicate computation of cached content: adopt the
                    # incumbent, free our copy
                    self.refcount[meta.block] += 1
                    self.refcount[blk] -= 1
                    assert self.refcount[blk] >= 0, \
                        f"refcount underflow on {blk}"
                    if self.refcount[blk] == 0 and blk not in self._by_block:
                        self.free.append(blk)
                    table[i] = meta.block
                    self.dedup_blocks += 1
                continue
            blk = int(table[i])
            if blk in self._by_block:
                # this physical block is already cached under another path
                # (can't happen for consistent tables; guard anyway)
                continue
            meta = _CachedBlock(block=blk, key=key, parent=parent)
            self.trie.insert(key, meta)
            self._cached[key] = meta
            self._by_block[blk] = meta
            if parent is not None:
                self._cached[parent].children += 1
            self._touch(meta)
            added += 1
        return added, key

    # --------------------------------------------------------------- unref
    def unref(self, table: Sequence[int]) -> None:
        """Drop one reference per block; uncached blocks return to the free
        list at zero, cached blocks stay resident (evictable)."""
        for blk in table:
            blk = int(blk)
            self.refcount[blk] -= 1
            assert self.refcount[blk] >= 0, f"refcount underflow on {blk}"
            if self.refcount[blk] == 0 and blk not in self._by_block:
                self.free.append(blk)

    @property
    def n_cached(self) -> int:
        return len(self._cached)


@dataclass
class PagedSeq:
    """Per-slot request state: block table + positions + prompt tokens."""
    request_id: str | None = None
    prompt: np.ndarray | None = None   # host prompt tokens (trie keys)
    table: list[int] = field(default_factory=list)
    reused: int = 0                    # reused prefix length, tokens
    reserve: int = 0                   # worst-case total blocks this request
    prefill_pos: int = 0               # next prompt position to prefill
    committed: int = 0                 # full blocks already in the trie
    trie_key: str = ""                 # path of those blocks (resume point)
    pos: int = 0                       # next absolute position to decode
    active: bool = False


class PagedCacheManager:
    """Paged drop-in for ``CacheManager``: same slot/position interface, but
    cache state is (pools, block tables) instead of a per-slot dense tree.

    ``devstore``/``kv_key``: when given, the pool tree is installed on the
    DeviceStore after every mutation (``publish``) so KV blocks live on the
    Cascade store like any other device object; by default a private
    single-device store is created (keep_versions=1 — decode rewrites every
    leaf each tick, so retaining predecessors would double pool memory).
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int, *,
                 block_size: int = 16, num_blocks: int | None = None,
                 prefix_cache: bool = True, devstore=None,
                 kv_key: str | None = None,
                 kv_dtype: str | None = None,
                 mesh=None) -> None:
        self.cfg, self.n_slots, self.max_len = cfg, n_slots, max_len
        self.block_size = block_size
        self.max_blocks = max(1, math.ceil(max_len / block_size))
        if num_blocks is None:
            # every slot can grow to max_len, plus null block, plus slack so
            # the prefix cache can retain blocks past their request
            num_blocks = 1 + (n_slots + 2) * self.max_blocks
        self.num_blocks = num_blocks
        self.kv_dtype = cfg.kv_dtype if kv_dtype is None else kv_dtype
        self.alloc = PrefixBlockAllocator(num_blocks, block_size,
                                          enable_cache=prefix_cache)
        self.pools = init_paged_pools(cfg, num_blocks, block_size,
                                      kv_dtype=self.kv_dtype)
        # Sharded pool (``mesh`` = this replica's device slice): every K/V
        # leaf gets a NamedSharding over kv_heads/'model'
        # (launch.sharding.kv_pool_shardings); block tables stay host-side.
        # The initial device_put already matches the registered policy, so
        # the very first publish — like every per-tick publish after it —
        # takes the donate fast path.
        self.mesh = mesh
        self.pool_shardings = None
        self._scatter = _scatter_jit
        if mesh is not None:
            from repro.launch.sharding import kv_pool_shardings
            self.pool_shardings = kv_pool_shardings(cfg, mesh,
                                                    kv_dtype=self.kv_dtype)
            self.pools = jax.device_put(self.pools, self.pool_shardings)
            # restore scatters donate the pool; pin the output shardings so
            # an adopt can never drift the pool off its registered policy
            # (which would turn every later publish into a copy)
            self._scatter = jax.jit(_scatter_blocks, donate_argnums=(0,),
                                    out_shardings=self.pool_shardings)
        self.slots = [PagedSeq() for _ in range(n_slots)]
        if devstore is None:
            from repro.core.devstore import DeviceStore
            from repro.core.pools import PoolSpec
            host = jax.make_mesh((1, 1), ("data", "model"))
            devstore = DeviceStore(host, keep_versions=1)
            devstore.create_pool(PoolSpec(path="/kv"))
            kv_key = kv_key or "/kv/pool"
        self.devstore = devstore
        self.kv_key = kv_key or "/kv/pool"
        if self.pool_shardings is not None:
            self.devstore.register_sharding(self.kv_key, self.pool_shardings)
        self.publish()

    # ----------------------------------------------------- devstore bridge
    def publish(self) -> None:
        """Install the current pool tree on the device store (reference
        move — the leaves already live on the right devices)."""
        self.devstore.put(self.kv_key, self.pools, donate=True)

    def kv_bytes_per_token(self) -> float:
        """HBM bytes the pool stores per token slot, summed over every
        layer's K/V (and, when quantized, scale) leaves.  This is also the
        bytes a decode token READS per full-context pass, so the quant win
        (bf16 → int8+f32-scales ≈ 2D/(D+4)) shows up here independent of
        wall-clock noise."""
        per_slot = 0.0
        for leaf in jax.tree.leaves(self.pools):
            per_slot += leaf.dtype.itemsize * leaf.size / (
                self.num_blocks * self.block_size)
        return per_slot

    # ------------------------------------------------------ slot interface
    def acquire(self, request_id: str) -> int | None:
        for i, s in enumerate(self.slots):
            if not s.active:
                self.slots[i] = PagedSeq(request_id=request_id, active=True)
                return i
        return None

    def release(self, slot: int) -> None:
        """Release without caching (error paths); ``finish`` is the normal
        completion route."""
        seq = self.slots[slot]
        if seq.table:
            self.alloc.unref(seq.table)
        self.slots[slot] = PagedSeq()

    @staticmethod
    def written_max(prompt_len: int, max_new_tokens: int) -> int:
        """Number of positions whose K/V gets written: the prompt plus
        max_new-1 fed-back tokens (the final sample is never written).  THE
        write-accounting rule — admission validation, block budgeting, and
        ``begin``'s reserve all derive from this one definition."""
        return prompt_len + max(0, max_new_tokens - 1)

    def block_cost(self, prompt_len: int, max_new_tokens: int) -> int:
        """Worst-case block footprint of a request.  ``begin`` reserves
        exactly this, so scheduler admission and decode-time growth can
        never disagree."""
        return min(self.max_blocks,
                   math.ceil(self.written_max(prompt_len, max_new_tokens)
                             / self.block_size))

    def begin(self, slot: int, prompt_tokens: np.ndarray,
              max_new_tokens: int) -> PagedSeq | None:
        """Build the request's block table: reuse every cached block of a
        block-aligned prompt prefix, allocate fresh blocks for the rest.
        At least one prompt token is always left to prefill (the last-token
        logits must be computed), so a fully-cached prompt reuses one block
        less than it matched.  Returns None if blocks are exhausted."""
        seq = self.slots[slot]
        S = len(prompt_tokens)
        if S > self.max_len:
            # fail fast with a real error: a too-long prompt would otherwise
            # overflow the fixed-width block table mid-admission
            self.release(slot)
            raise ValueError(f"prompt of {S} tokens exceeds max_len="
                             f"{self.max_len}")
        n_prompt_blocks = math.ceil(S / self.block_size)
        reuse_cap = (S - 1) // self.block_size
        matched = self.alloc.match(prompt_tokens, reuse_cap)
        fresh = self.alloc.allocate(n_prompt_blocks - len(matched))
        if fresh is None:
            self.alloc.unref(matched)
            self.release(slot)
            return None
        seq.prompt = np.asarray(prompt_tokens)
        seq.table = matched + fresh
        seq.reused = len(matched) * self.block_size
        seq.prefill_pos = seq.reused
        # matched blocks are already trie-resident: chunk commits resume
        # right past them (one-time O(reused) key build, O(chunk) per chunk)
        seq.committed = len(matched)
        seq.trie_key = self.alloc.path_key(seq.prompt, len(matched))
        seq.reserve = self.block_cost(S, max_new_tokens)
        return seq

    def commit_prefill_progress(self, slot: int, new_pos: int) -> bool:
        """Chunk-granularity trie commit: the engine just PACKED prompt
        positions [prefill_pos, new_pos) of this slot into the current tick's
        mixed dispatch.  Every full block now covered is donated to the trie
        immediately — before the dispatch even runs — which is sound because
        the packed step writes all packed K/V before any packed token reads,
        so a same-tick later admission that matches these blocks attends to
        K/V written in the very same dispatch.  This is what makes
        intra-batch prefix sharing work: two same-prefix requests admitted in
        one tick share blocks instead of both prefilling the prefix.

        Returns True when the prompt is complete (the slot is ready to
        decode at pos = S; its boundary token samples this tick)."""
        seq = self.slots[slot]
        seq.prefill_pos = new_pos
        n_full = min(new_pos // self.block_size, len(seq.table))
        if n_full > seq.committed:
            _, seq.trie_key = self.alloc.cache_blocks_range(
                seq.prompt, seq.table, seq.committed, n_full, seq.trie_key)
            seq.committed = n_full
        if new_pos >= len(seq.prompt):
            seq.pos = len(seq.prompt)
            return True
        return False

    def finish(self, slot: int, generated: Sequence[int]) -> None:
        """Normal completion: cache the full blocks of everything whose K/V
        was actually written — prompt plus generated[:-1] (the final sampled
        token is never fed back) — then drop the request's references.
        Resumes past the chunk-committed prompt blocks, so only the
        generated tail does new key-building work."""
        seq = self.slots[slot]
        written = np.concatenate([
            seq.prompt, np.asarray(list(generated[:-1]), dtype=np.int64)
        ]) if len(generated) > 1 else seq.prompt
        n_full = min(len(written) // self.block_size, len(seq.table))
        if n_full > seq.committed:
            self.alloc.cache_blocks_range(written, seq.table, seq.committed,
                                          n_full, seq.trie_key)
        self.alloc.unref(seq.table)
        self.slots[slot] = PagedSeq()

    # ---------------------------------------------------------- decode I/O
    def ensure_decode_blocks(self, extra: dict[int, int] | None = None, *,
                             only: set[int] | None = None) -> None:
        """Grow each active slot's table to cover the position it is about to
        write — plus ``extra[slot]`` further positions for speculative draft
        tokens verified (and KV-written) in the same dispatch.  Admission
        reserves worst-case block budgets (``block_cost`` covers
        ``written_max``, and the engine caps drafts so ``pos + extra`` never
        exceeds the last written position), so allocation here cannot fail
        unless the caller overran max_len.

        ``only`` restricts growth to those slots: the engine's mid-tick
        draft ensure must touch ONLY the rows it planned drafts for — by
        then a slot that completed its prompt in this very tick already
        sits at pos = S, and growing it here would demand a decode block
        its admission budget never reserved (crashing a valid
        ``max_new_tokens == 1`` request whose prompt ends block-aligned)."""
        for i, seq in enumerate(self.slots):
            if not seq.active or (only is not None and i not in only):
                continue
            last = seq.pos + (extra.get(i, 0) if extra else 0)
            blk_idx = last // self.block_size
            if blk_idx >= self.max_blocks:
                raise RuntimeError(
                    f"request {seq.request_id} overran max_len={self.max_len}")
            while blk_idx >= len(seq.table):
                got = self.alloc.allocate(1)
                if got is None:
                    raise RuntimeError("KV block pool exhausted mid-decode "
                                       "(admission budget violated)")
                seq.table.extend(got)

    def rollback_writes(self, slot: int, valid_len: int) -> int:
        """Speculative-decode rollback: K/V at positions >= ``valid_len`` in
        this slot belongs to REJECTED draft tokens.  Truncate the block
        table to the blocks covering positions [0, valid_len) and free the
        tail blocks — each exactly once.

        Why this is a pure table truncation: tail blocks past the write
        watermark are always PRIVATE to the request.  Draft positions lie
        past the prompt, matched prefix blocks all sit below the prompt's
        block-aligned prefix, and generated-token blocks enter the trie
        only at ``finish`` — so the freed blocks were freshly allocated
        this request (refcount 1, not trie-resident) and ``unref`` returns
        them straight to the free list.  Trie refcounts and shared prefix
        blocks are untouched, which is what keeps the allocator state
        identical to a from-scratch replay of only the accepted tokens.

        Stale K/V left INSIDE the kept last block (positions >= valid_len)
        is harmless: the causal mask hides positions beyond every query,
        and the row's next decode writes those positions before any token
        can attend to them.  Returns the number of blocks freed."""
        seq = self.slots[slot]
        keep = max(math.ceil(valid_len / self.block_size), seq.committed)
        if keep >= len(seq.table):
            return 0
        tail = seq.table[keep:]
        del seq.table[keep:]
        self.alloc.unref(tail)
        return len(tail)

    # -------------------------------------------------- spill / restore
    def spill_device(self, slot: int):
        """Device-side gather of this slot's blocks, in table order — NO
        host transfer happens here (the engine pulls the returned tree
        through its one sanctioned sync site, ``_to_host``)."""
        seq = self.slots[slot]
        idx = jnp.asarray(np.asarray(seq.table, np.int32))
        return _gather_jit(self.pools, idx)

    def adopt(self, slot: int, prompt: np.ndarray, spilled: SpilledKV,
              max_new_tokens: int) -> PagedSeq | None:
        """Install a spilled sibling session into ``slot``: allocate the
        same count of fresh blocks, scatter the migrated KV in, and resume
        at ``spilled.pos``.  Accounting is exact: the fresh blocks are
        refcount-1 private (the source replica's trie residency did not
        travel), ``reserve`` is the request's original worst-case footprint
        so decode growth stays within the admission budget, and ``finish``
        later donates prompt+generated blocks to THIS replica's trie under
        their token keys (commit-time dedup reconciles any incumbent).
        Returns None — slot released, nothing allocated — when the block
        geometry differs or the pool can't cover the worst case."""
        seq = self.slots[slot]
        S = len(prompt)
        reserve = self.block_cost(S, max_new_tokens)
        if (spilled.block_size != self.block_size
                or spilled.n_blocks > self.max_blocks
                or reserve > self.available_for_admission()):
            self.release(slot)
            return None
        fresh = self.alloc.allocate(spilled.n_blocks)
        if fresh is None:
            self.release(slot)
            return None
        seq.prompt = np.asarray(prompt)
        seq.table = list(fresh)
        seq.reused = 0
        seq.reserve = max(reserve, spilled.n_blocks)
        seq.prefill_pos = S            # prompt fully in KV already
        seq.committed = 0              # nothing trie-resident here yet
        seq.trie_key = ""
        seq.pos = spilled.pos
        idx = jnp.asarray(np.asarray(fresh, np.int32))
        blocks = jax.tree.map(jnp.asarray, spilled.blocks)   # host → device
        # donation discipline: the devstore entry aliases the donated pool
        # until publish() reinstalls the fresh tree (driver thread only —
        # same rule as the engine's mixed dispatch)
        self.pools = self._scatter(self.pools, blocks, idx)
        self.publish()
        return seq

    def block_tables(self, slots: list[int] | None = None) -> np.ndarray:
        """(B, max_blocks) int32 table, -1 = unused (clamped to the null
        block device-side).  Default: one row per slot, inactive rows all -1.
        """
        idxs = list(range(self.n_slots)) if slots is None else list(slots)
        bt = np.full((len(idxs), self.max_blocks), -1, np.int32)
        for r, i in enumerate(idxs):
            t = self.slots[i].table
            bt[r, :len(t)] = t
        return bt

    def available_for_admission(self) -> int:
        """Free+evictable blocks minus what active requests may still claim
        for decode growth — the budget the scheduler admits against."""
        outstanding = sum(max(0, s.reserve - len(s.table))
                          for s in self.slots if s.active)
        return self.alloc.available() - outstanding

    # ------------------------------------------- dense-compatible counters
    def active_mask(self) -> jnp.ndarray:
        return jnp.asarray([s.active for s in self.slots], dtype=bool)

    def positions(self) -> jnp.ndarray:
        return jnp.asarray([s.pos for s in self.slots], dtype=jnp.int32)

    def advance(self) -> None:
        for s in self.slots:
            if s.active:
                s.pos += 1

    @property
    def n_active(self) -> int:
        return sum(s.active for s in self.slots)

    @property
    def blocks_in_use(self) -> int:
        return self.alloc.blocks_in_use

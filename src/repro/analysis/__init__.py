"""cascade-lint: project-specific static analysis + runtime sanitizers.

Cascade's latency story rests on invariants the code can only state by
convention — this package makes three of them machine-checked:

1. **Lock discipline** (``lock_discipline``): every attribute a class
   mutates under one of its locks is mutated under that lock *everywhere*
   (the store/dispatcher/driver threads touch shared state only under
   their locks).
2. **Host-sync discipline** (``sync_discipline``): the serving fast path
   has exactly ONE device→host sync site per tick — ``host_syncs ==
   ticks`` holds statically, not just when a test happens to trip it.
3. **Donation & recompile hazards** (``donation``): a buffer donated to a
   jitted dispatch is dead — reading it afterwards is a use-after-free;
   and jitted calls must not be fed shape-varying or Python-scalar
   operands that would break the compile-once fixed-shape tick.

``runner`` is the CLI (``make lint`` / ``python -m repro.analysis``);
``sanitizer`` is the runtime half — a lock-order tracker (acquisition
graph + cycle detection) and a device-sync call-site sanitizer wired into
the threaded serving tests by ``tests/conftest.py``.

Suppressions are inline pragmas with a one-line justification::

    # lint: guarded-by(seq_lock) per-shard sequencer serializes writers
    # lint: allow-sync(training loop; not on the serving fast path)
    # lint: allow-donated-read(operand is rebound before this read)
    # lint: static-ok(value is compile-time constant per engine)
    # lint: sync-site(THE one per-tick device->host pull)

A pragma suppresses only a matching finding on its own statement (or the
statement directly below a standalone pragma line); ``guarded-by`` must
name the inferred guard lock or a lock actually held at the site — a
wrong name keeps the finding.
"""
from .base import Finding, Pragma, SourceInfo, iter_python_files
from .donation import DonationPass
from .lock_discipline import LockDisciplinePass
from .runner import ALL_PASSES, lint_paths, main
from .sync_discipline import SyncDisciplinePass

__all__ = [
    "Finding", "Pragma", "SourceInfo", "iter_python_files",
    "LockDisciplinePass", "SyncDisciplinePass", "DonationPass",
    "ALL_PASSES", "lint_paths", "main",
]

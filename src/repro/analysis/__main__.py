"""``python -m repro.analysis <paths>`` — run cascade-lint."""
from .runner import main

if __name__ == "__main__":
    raise SystemExit(main())

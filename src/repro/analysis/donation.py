"""Pass 3 — donation & recompile hazards around ``jax.jit`` call sites.

Two hot-path rules:

**Donation (use-after-donate).**  ``X = jax.jit(fn, donate_argnums=(i,))``
hands operand ``i``'s buffer to XLA: after any call ``X(...)`` the operand
is dead and reading it is a use-after-free (at best an error, at worst a
silent whole-pool copy — PR 3's original bug class).  Within each function
the pass tracks calls of known-jitted names, marks the donated positional
operands' dotted paths dead, and flags any later *read* of a dead path.
A store to the same path (``self.cm.pools = pools``) revives it.  The
analysis is linear over the statement stream — the shape all dispatch
code in this repo has — so a read that is only conditionally dead is
still flagged; annotate real counterexamples with
``# lint: allow-donated-read(why)``.

**Recompile (shape/value hazard).**  A jitted callable compiled without
``static_argnums``/``static_argnames`` re-traces whenever a Python scalar
argument changes value.  Calls of a known-jitted name that pass a bare
int/float/bool literal or a ``len(...)`` are flagged: the compile-once
fixed-shape tick cannot tolerate per-call retraces.  Suppress a
compile-time-constant with ``# lint: static-ok(why)``.

Only *literal* ``donate_argnums`` tuples/ints are understood; jit wrappers
built through helpers or comprehensions are out of scope (they get no
findings, not wrong ones).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass

from .base import Finding, SourceInfo, dotted_name


@dataclass(frozen=True)
class JittedCallable:
    name: str                    # dotted name it is callable as, e.g. "self._mixed"
    donated: tuple[int, ...]     # positional operand indexes donated
    has_static: bool             # static_argnums / static_argnames given


def _literal_argnums(node: ast.AST) -> tuple[int, ...] | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out: list[int] = []
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, int):
                out.append(el.value)
            else:
                return None
        return tuple(out)
    return None


def _jit_spec(value: ast.AST) -> tuple[tuple[int, ...], bool] | None:
    """(donated positions, has_static) for a ``jax.jit(...)`` call, else None."""
    if not isinstance(value, ast.Call) \
            or dotted_name(value.func) not in ("jax.jit", "jit"):
        return None
    donated: tuple[int, ...] = ()
    has_static = False
    for kw in value.keywords:
        if kw.arg == "donate_argnums":
            nums = _literal_argnums(kw.value)
            if nums is None:
                return None          # non-literal spec: out of scope
            donated = nums
        elif kw.arg in ("static_argnums", "static_argnames"):
            has_static = True
    return donated, has_static


def collect_jitted(tree: ast.Module) -> dict[str, JittedCallable]:
    """Jitted callables bound to stable names, module- and class-level."""
    out: dict[str, JittedCallable] = {}

    def record(target: ast.AST, value: ast.AST) -> None:
        spec = _jit_spec(value)
        if spec is None:
            return
        dn = dotted_name(target)
        if dn is None:
            return
        out[dn] = JittedCallable(dn, spec[0], spec[1])

    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                record(t, node.value)
    return out


class _ScopeWalker(ast.NodeVisitor):
    """Linear walk of one function: dead donated paths + literal-arg calls."""

    def __init__(self, src: SourceInfo, jitted: dict[str, JittedCallable],
                 qual: str) -> None:
        self.src = src
        self.jitted = jitted
        self.qual = qual
        self.dead: dict[str, int] = {}     # dotted path -> donation line
        self.findings: list[Finding] = []

    # ------------------------------------------------------------- helpers
    def _check_reads(self, node: ast.AST) -> None:
        if not self.dead:
            return
        for sub in ast.walk(node):
            dn = dotted_name(sub)
            if dn is None or dn not in self.dead:
                continue
            if not isinstance(getattr(sub, "ctx", None), ast.Load):
                continue
            line = sub.lineno
            end = getattr(sub, "end_lineno", line) or line
            if self.src.pragma_at(line, end, "allow-donated-read"):
                continue
            self.findings.append(Finding(
                self.src.path, line, "donation",
                f"{dn} was donated to a jitted call on line "
                f"{self.dead[dn]} and not rebound — reading it is a "
                f"use-after-donate (in {self.qual})"))

    def _apply_stores(self, node: ast.stmt) -> None:
        targets: list[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for t in targets:
            for el in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                       else [t]):
                dn = dotted_name(el)
                if dn is not None:
                    self.dead.pop(dn, None)

    def _apply_calls(self, node: ast.AST) -> None:
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            fn = dotted_name(sub.func)
            spec = self.jitted.get(fn) if fn else None
            if spec is None:
                continue
            for idx in spec.donated:
                if idx < len(sub.args):
                    dn = dotted_name(sub.args[idx])
                    if dn is not None:
                        self.dead[dn] = sub.lineno
            if not spec.has_static:
                self._check_retrace_args(sub, fn)

    def _check_retrace_args(self, call: ast.Call, fn: str) -> None:
        for arg in call.args:
            hazard: str | None = None
            if isinstance(arg, ast.Constant) \
                    and isinstance(arg.value, (bool, int, float)):
                hazard = f"Python scalar literal {arg.value!r}"
            elif isinstance(arg, ast.Call) \
                    and dotted_name(arg.func) == "len":
                hazard = "len(...) (varies with input size)"
            if hazard is None:
                continue
            line = arg.lineno
            end = getattr(arg, "end_lineno", line) or line
            if self.src.pragma_at(line, end, "static-ok") \
                    or self.src.pragma_at(call.lineno,
                                          getattr(call, "end_lineno", None),
                                          "static-ok"):
                continue
            self.findings.append(Finding(
                self.src.path, line, "recompile",
                f"{fn} is jitted without static_argnums but is passed "
                f"{hazard}: every new value retraces — make it static "
                f"or an array (in {self.qual})"))

    # -------------------------------------------------------------- visits
    def _statement(self, node: ast.stmt) -> None:
        """Reads are checked BEFORE this statement's own donation takes
        effect, so the donating call itself is not a use-after-donate."""
        self._check_reads(node)
        self._apply_stores(node)
        self._apply_calls(node)

    def visit(self, node: ast.AST) -> None:  # type: ignore[override]
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return                    # nested scope: separate analysis
        if isinstance(node, (ast.If, ast.For, ast.While, ast.With,
                             ast.Try)):
            # compound statement: check its header expression, then the
            # bodies in order (linear approximation of control flow)
            for field_ in ("test", "iter", "items", "subject"):
                sub = getattr(node, field_, None)
                if sub is not None:
                    subs = sub if isinstance(sub, list) else [sub]
                    for s in subs:
                        self._check_reads(s)
                        self._apply_calls(s)
            for body_field in ("body", "orelse", "finalbody"):
                for stmt in getattr(node, body_field, []) or []:
                    self.visit(stmt)
            for handler in getattr(node, "handlers", []) or []:
                for stmt in handler.body:
                    self.visit(stmt)
        elif isinstance(node, ast.stmt):
            self._statement(node)


class DonationPass:
    name = "donation"

    def run(self, src: SourceInfo) -> list[Finding]:
        jitted = collect_jitted(src.tree)
        if not jitted:
            return []
        findings: list[Finding] = []
        for qual, fn in self._functions(src.tree):
            walker = _ScopeWalker(src, jitted, qual)
            for stmt in fn.body:
                walker.visit(stmt)
            findings.extend(walker.findings)
        return findings

    @staticmethod
    def _functions(tree: ast.Module):
        for node in tree.body:
            if isinstance(node, ast.FunctionDef):
                yield node.name, node
            elif isinstance(node, ast.ClassDef):
                for item in node.body:
                    if isinstance(item, ast.FunctionDef):
                        yield f"{node.name}.{item.name}", item

"""Shared machinery for the cascade-lint passes: findings, pragmas, sources.

Pragma grammar (one per comment)::

    # lint: <name>(<arg>) [free-form justification]

``name`` is the suppression kind (``guarded-by``, ``allow-sync``,
``sync-site``, ``allow-donated-read``, ``static-ok``); ``arg`` is
kind-specific (a lock name for ``guarded-by``, otherwise the start of the
justification).  A pragma attaches to every line of the statement it sits
on; a pragma alone on a line attaches to the line below it (annotating
the statement it precedes).
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Iterator

PRAGMA_RE = re.compile(r"#\s*lint:\s*([a-z-]+)\(([^)]*)\)")


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str          # "lock-discipline" | "host-sync" | "donation" | "recompile"
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass(frozen=True)
class Pragma:
    name: str
    arg: str
    line: int          # the source line the pragma governs


@dataclass
class SourceInfo:
    """One parsed file: AST + per-line pragmas, shared by every pass."""

    path: str
    text: str
    tree: ast.Module
    pragmas: dict[int, list[Pragma]] = field(default_factory=dict)

    @classmethod
    def from_source(cls, text: str, path: str = "<string>") -> "SourceInfo":
        tree = ast.parse(text, filename=path)
        info = cls(path=path, text=text, tree=tree)
        lines = text.splitlines()
        for i, raw in enumerate(lines, start=1):
            m = PRAGMA_RE.search(raw)
            if not m:
                continue
            target = i
            # a standalone pragma line annotates the statement below it
            if raw.lstrip().startswith("#"):
                target = i + 1
            info.pragmas.setdefault(target, []).append(
                Pragma(name=m.group(1), arg=m.group(2).strip(), line=target))
        return info

    @classmethod
    def parse(cls, path: str) -> "SourceInfo":
        with open(path, encoding="utf-8") as f:
            return cls.from_source(f.read(), path)

    def pragma_at(self, first: int, last: int | None, name: str
                  ) -> Pragma | None:
        """The first ``name`` pragma attached to lines [first, last]."""
        for line in range(first, (last or first) + 1):
            for p in self.pragmas.get(line, ()):
                if p.name == name:
                    return p
        return None

    def all_pragmas(self, name: str) -> list[Pragma]:
        return [p for ps in self.pragmas.values() for p in ps
                if p.name == name]


def iter_python_files(paths: list[str]) -> Iterator[str]:
    """Expand files/directories into a sorted stream of .py paths."""
    seen: set[str] = set()
    for root in paths:
        if os.path.isfile(root):
            if root not in seen:
                seen.add(root)
                yield root
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames.sort()
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    p = os.path.join(dirpath, fn)
                    if p not in seen:
                        seen.add(p)
                        yield p


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def self_attr_root(node: ast.AST) -> str | None:
    """For a target/load path rooted at ``self`` — ``self.X...`` possibly
    through further attributes/subscripts — the first attribute ``X``."""
    cur = node
    attr: str | None = None
    while True:
        if isinstance(cur, ast.Attribute):
            attr = cur.attr
            cur = cur.value
        elif isinstance(cur, ast.Subscript):
            cur = cur.value
        else:
            break
    if isinstance(cur, ast.Name) and cur.id == "self" and attr is not None:
        return attr
    return None

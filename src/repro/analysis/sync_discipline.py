"""Pass 2 — host-sync discipline: one device→host sync site, statically.

The serving invariant ``stats.host_syncs == stats.ticks`` only trips at
test time; this pass enforces its precondition at lint time: in the fast
path packages (``serving/``, ``models/``) every device→host sync point is
flagged unless it sits inside THE allowlisted sync site.

Flagged constructs:

- ``jax.device_get(...)`` and ``jax.block_until_ready(...)`` calls,
- ``.block_until_ready()`` / ``.item()`` method calls,
- ``np.asarray``/``np.array`` whose argument mentions a *device-tainted*
  name, and ``float()``/``bool()``/``int()`` of a device-tainted name —
  implicit syncs that are invisible in a grep.

Taint is intra-function: names assigned from ``jnp.*``/``jax.*`` calls or
from calls of a *jitted callable* are device values; tainted-ness follows
simple assignment and subscripting.  Jitted callables are recognized per
module/class: ``NAME = jax.jit(...)``, ``self.NAME = jax.jit(...)``, and
functions decorated ``@jax.jit`` / ``@functools.partial(jax.jit, ...)``.

The allowlist is a ``# lint: sync-site(...)`` pragma on the function def:
every sync inside it is sanctioned, and the RUNNER enforces that at most
one sync site exists across the fast-path packages — a second pragma is
itself a violation, so the "single sync point" rule cannot erode one
annotation at a time.  Point suppressions use ``# lint: allow-sync(why)``.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

from .base import Finding, SourceInfo, dotted_name

_DEVICE_PRODUCER_PREFIXES = ("jnp.", "jax.numpy.", "jax.lax.")
_DEVICE_PRODUCERS = {"jax.device_put", "jax.eval_shape"}
_HOST_CONVERTERS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
_SCALAR_CONVERTERS = {"float", "bool", "int"}


@dataclass
class SyncSite:
    """A declared (pragma'd) sanctioned sync function."""
    path: str
    qualname: str
    line: int


@dataclass
class SyncReport:
    findings: list[Finding] = field(default_factory=list)
    sync_sites: list[SyncSite] = field(default_factory=list)


def _is_jit_call(value: ast.AST) -> bool:
    return (isinstance(value, ast.Call)
            and dotted_name(value.func) in ("jax.jit", "jit"))


def _jitted_decorated(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        dn = dotted_name(dec)
        if dn in ("jax.jit", "jit"):
            return True
        if isinstance(dec, ast.Call):
            dn = dotted_name(dec.func)
            if dn in ("jax.jit", "jit"):
                return True
            if dn in ("functools.partial", "partial") and dec.args \
                    and dotted_name(dec.args[0]) in ("jax.jit", "jit"):
                return True
    return False


class _FunctionTaint(ast.NodeVisitor):
    """In-order walk of one function: taint device names, flag sync points."""

    def __init__(self, src: SourceInfo, jitted: set[str], rule: str,
                 qual: str) -> None:
        self.src = src
        self.jitted = jitted          # names whose call yields device values
        self.rule = rule
        self.qual = qual
        self.tainted: set[str] = set()
        self.findings: list[Finding] = []

    # ------------------------------------------------------------- helpers
    def _produces_device_value(self, call: ast.Call) -> bool:
        dn = dotted_name(call.func)
        if dn is None:
            return False
        if dn in _DEVICE_PRODUCERS or dn in self.jitted:
            return True
        if dn == "jax.device_get":
            return False              # that IS the host transfer
        return dn.startswith(_DEVICE_PRODUCER_PREFIXES)

    def _mentions_tainted(self, node: ast.AST) -> bool:
        return any(isinstance(n, ast.Name) and n.id in self.tainted
                   for n in ast.walk(node))

    def _flag(self, node: ast.AST, msg: str) -> None:
        line = node.lineno
        end = getattr(node, "end_lineno", line) or line
        if self.src.pragma_at(line, end, "allow-sync"):
            return
        self.findings.append(Finding(self.src.path, line, self.rule,
                                     f"{msg} (in {self.qual})"))

    def _taint_target(self, target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            self.tainted.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._taint_target(el)

    # ------------------------------------------------------------- visits
    def visit_Assign(self, node: ast.Assign) -> None:
        self.visit(node.value)
        value = node.value
        taints = False
        if isinstance(value, ast.Call):
            taints = self._produces_device_value(value)
        elif isinstance(value, ast.Name):
            taints = value.id in self.tainted
        elif isinstance(value, ast.Subscript):
            taints = self._mentions_tainted(value.value)
        if taints:
            for t in node.targets:
                self._taint_target(t)
        else:
            # reassignment from a host expression clears the taint
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self.tainted.discard(t.id)

    def visit_Call(self, node: ast.Call) -> None:
        dn = dotted_name(node.func)
        if dn == "jax.device_get":
            self._flag(node, "jax.device_get is a device->host sync")
        elif dn in ("jax.block_until_ready",):
            self._flag(node, "jax.block_until_ready is a device->host sync")
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr == "block_until_ready":
            self._flag(node, ".block_until_ready() is a device->host sync")
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr == "item" and not node.args:
            self._flag(node, ".item() forces a device->host transfer")
        elif dn in _HOST_CONVERTERS and node.args \
                and self._mentions_tainted(node.args[0]):
            self._flag(node, f"{dn} of a device value syncs it to host")
        elif dn in _SCALAR_CONVERTERS and node.args \
                and self._mentions_tainted(node.args[0]):
            self._flag(node, f"{dn}() of a device value syncs it to host")
        self.generic_visit(node)


class SyncDisciplinePass:
    name = "host-sync"

    def run(self, src: SourceInfo) -> list[Finding]:
        return self.run_full(src).findings

    def run_full(self, src: SourceInfo) -> SyncReport:
        report = SyncReport()
        module_jitted = self._module_jitted(src.tree)
        for cls_name, fn, jitted in self._functions(src.tree, module_jitted):
            qual = f"{cls_name}.{fn.name}" if cls_name else fn.name
            deco_first = min([fn.lineno]
                             + [d.lineno for d in fn.decorator_list])
            if src.pragma_at(deco_first, fn.lineno, "sync-site"):
                report.sync_sites.append(
                    SyncSite(src.path, qual, fn.lineno))
                continue              # the sanctioned sync point
            walker = _FunctionTaint(src, jitted, self.name, qual)
            for stmt in fn.body:
                walker.visit(stmt)
            report.findings.extend(walker.findings)
        return report

    # -------------------------------------------------------------- scans
    @staticmethod
    def _module_jitted(tree: ast.Module) -> set[str]:
        jitted: set[str] = set()
        for node in tree.body:
            if isinstance(node, ast.Assign) and _is_jit_call(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        jitted.add(t.id)
            elif isinstance(node, ast.FunctionDef) \
                    and _jitted_decorated(node):
                jitted.add(node.name)
        return jitted

    @staticmethod
    def _functions(tree: ast.Module, module_jitted: set[str]):
        """Yield (class name | None, function, jitted-name set) triples."""
        for node in tree.body:
            if isinstance(node, ast.FunctionDef):
                yield None, node, set(module_jitted)
            elif isinstance(node, ast.ClassDef):
                cls_jitted = set(module_jitted)
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Assign) \
                            and _is_jit_call(sub.value):
                        for t in sub.targets:
                            dn = dotted_name(t)
                            if dn and dn.startswith("self."):
                                cls_jitted.add(dn)   # "self._mixed"
                for item in node.body:
                    if isinstance(item, ast.FunctionDef):
                        yield node.name, item, cls_jitted

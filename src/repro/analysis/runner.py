"""cascade-lint driver: run every pass over a tree, enforce global budgets.

Per-pass scope mirrors where each invariant lives:

- ``lock-discipline`` and ``donation``/``recompile`` run over the whole
  tree (any module may grow threads or jit calls);
- ``host-sync`` runs only over the fast-path packages (``serving/``,
  ``models/``) plus the named fast-path FILES in ``_FASTPATH_FILES`` —
  ``core/store.py`` joined when the spill pool put it on the preemption
  spill/unpark path (the rest of ``core`` is offline tooling and may sync
  freely).

One check is global rather than per-file: across the fast-path scope
there must be at most ONE ``sync-site`` pragma.  The invariant is "one
sync per tick", and a second sanctioned site would erode it one
annotation at a time.
"""
from __future__ import annotations

import argparse
import sys

from .base import Finding, SourceInfo, iter_python_files
from .donation import DonationPass
from .lock_discipline import LockDisciplinePass
from .sync_discipline import SyncDisciplinePass, SyncSite

ALL_PASSES = (LockDisciplinePass, SyncDisciplinePass, DonationPass)

_FASTPATH_PARTS = ("serving", "models")
# Individual fast-path files outside those packages.  core/store.py hosts
# the SpillPool the engine parks preempted KV into — it sits on the
# spill/unpark path, so it must stay inside the one-sync-site budget (it is
# pure host code: ZERO sync sites of its own) without dragging the whole
# offline ``core`` package into the sync pass.
_FASTPATH_FILES = ("core/store.py",)
MAX_SYNC_SITES = 1


def _in_fastpath(path: str) -> bool:
    norm = path.replace("\\", "/")
    parts = norm.split("/")
    return (any(p in parts for p in _FASTPATH_PARTS)
            or any(norm.endswith(f) for f in _FASTPATH_FILES))


def lint_paths(paths: list[str]) -> list[Finding]:
    """Run every pass over ``paths`` (files or directories)."""
    lock_pass = LockDisciplinePass()
    sync_pass = SyncDisciplinePass()
    donation_pass = DonationPass()

    findings: list[Finding] = []
    sync_sites: list[SyncSite] = []
    for path in iter_python_files(paths):
        try:
            src = SourceInfo.parse(path)
        except SyntaxError as exc:
            findings.append(Finding(path, exc.lineno or 1, "parse",
                                    f"cannot parse: {exc.msg}"))
            continue
        findings.extend(lock_pass.run(src))
        findings.extend(donation_pass.run(src))
        if _in_fastpath(path):
            report = sync_pass.run_full(src)
            findings.extend(report.findings)
            sync_sites.extend(report.sync_sites)

    if len(sync_sites) > MAX_SYNC_SITES:
        keep = sync_sites[0]
        for extra in sync_sites[1:]:
            findings.append(Finding(
                extra.path, extra.line, "host-sync",
                f"second `sync-site` pragma ({extra.qualname}): the fast "
                f"path allows exactly one sync site and it is already "
                f"{keep.qualname} ({keep.path}:{keep.line})"))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="cascade-lint",
        description="invariant checks: lock discipline, host-sync "
                    "discipline, donation/recompile hazards")
    parser.add_argument("paths", nargs="+",
                        help="files or directories to lint")
    args = parser.parse_args(argv)

    findings = lint_paths(args.paths)
    for f in findings:
        print(f.render())
    if findings:
        print(f"cascade-lint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("cascade-lint: clean", file=sys.stderr)
    return 0

"""Pass 1 — lock discipline: infer guarded-by, flag unguarded mutations.

For every class, the pass first finds its lock attributes (``self.X =
threading.Lock()`` / ``RLock()`` / ``Condition(...)``), then walks every
method recording each mutation of a ``self.Y`` attribute together with the
set of locks lexically held (``with self.X:`` blocks, plus local
with-contexts like ``with seq_lock:``).  The guarded-by relation is
INFERRED: an attribute mutated at least once while holding one of the
class's locks is considered guarded by the lock(s) held at *every* such
site.  Any other mutation of that attribute — outside the guard lock —
is flagged.

Deliberate exceptions are annotated in place::

    self._versions[vkey] = version   # lint: guarded-by(seq_lock) ...

The pragma must name the inferred guard lock OR a lock actually held at
the site (a class lock attribute or a local with-context variable) — a
wrong or stale lock name keeps the finding, so annotations cannot rot
silently.

Out of scope, deliberately: ``__init__``/``__post_init__``/``__del__``
(construction and teardown are single-threaded), attributes never mutated
under any lock (single-writer state — the engine's one-driver model), and
mutations through non-``self`` objects (cross-object discipline belongs
to the owning class).  Mutations inside nested ``def``s are analyzed with
an EMPTY held set: a closure runs at call time, when the enclosing
``with`` is long gone.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass

from .base import Finding, SourceInfo, dotted_name, self_attr_root

LOCK_FACTORIES = {
    "threading.Lock", "threading.RLock", "threading.Condition",
    "Lock", "RLock", "Condition",
}
# intrinsically thread-safe attributes: never part of the guarded-by relation
ATOMIC_FACTORIES = {
    "threading.Event", "Event", "threading.Semaphore", "Semaphore",
    "threading.BoundedSemaphore", "queue.SimpleQueue", "queue.Queue",
    "SimpleQueue", "Queue",
}
MUTATOR_METHODS = {
    "append", "appendleft", "extend", "insert", "pop", "popleft",
    "popitem", "remove", "clear", "add", "discard", "update",
    "setdefault", "sort", "reverse",
}
EXEMPT_METHODS = {"__init__", "__post_init__", "__new__", "__del__"}


@dataclass
class _Mutation:
    attr: str
    line: int
    end_line: int
    held_self: frozenset[str]     # class lock attrs held at the site
    held_local: frozenset[str]    # non-self with-contexts held at the site
    exempt: bool                  # __init__-family method


def _call_factory(value: ast.AST) -> str | None:
    if isinstance(value, ast.Call):
        return dotted_name(value.func)
    return None


class _MethodWalker(ast.NodeVisitor):
    """Collect self-attribute mutations with the lexically held lock set."""

    def __init__(self, lock_attrs: set[str], atomic_attrs: set[str],
                 exempt: bool) -> None:
        self.lock_attrs = lock_attrs
        self.atomic_attrs = atomic_attrs
        self.exempt = exempt
        self.held_self: list[str] = []
        self.held_local: list[str] = []
        self.mutations: list[_Mutation] = []

    # ------------------------------------------------------------ helpers
    def _record(self, target: ast.AST, node: ast.stmt) -> None:
        attr = self_attr_root(target)
        if attr is None or attr in self.lock_attrs \
                or attr in self.atomic_attrs:
            return
        self.mutations.append(_Mutation(
            attr=attr, line=node.lineno,
            end_line=getattr(node, "end_lineno", node.lineno) or node.lineno,
            held_self=frozenset(self.held_self),
            held_local=frozenset(self.held_local),
            exempt=self.exempt))

    # ----------------------------------------------------------- contexts
    def _visit_with(self, node: ast.With) -> None:
        pushed_self: list[str] = []
        pushed_local: list[str] = []
        for item in node.items:
            ctx = item.context_expr
            name = self_attr_root(ctx)
            if name is not None and name in self.lock_attrs:
                pushed_self.append(name)
                self.held_self.append(name)
            else:
                dn = dotted_name(ctx)
                if dn is not None:
                    pushed_local.append(dn)
                    self.held_local.append(dn)
        for stmt in node.body:
            self.visit(stmt)
        for _ in pushed_self:
            self.held_self.pop()
        for _ in pushed_local:
            self.held_local.pop()

    visit_With = _visit_with

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # nested def: the enclosing with-block is NOT held at call time
        saved_s, saved_l = self.held_self, self.held_local
        self.held_self, self.held_local = [], []
        for stmt in node.body:
            self.visit(stmt)
        self.held_self, self.held_local = saved_s, saved_l

    visit_AsyncFunctionDef = visit_FunctionDef

    # ---------------------------------------------------------- mutations
    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            for el in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                       else [t]):
                self._record(el, node)
        self.visit(node.value)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record(node.target, node)
        self.visit(node.value)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._record(node.target, node)
            self.visit(node.value)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            self._record(t, node)

    def visit_Call(self, node: ast.Call) -> None:
        # self.X...<mutator>(...) mutates X in place
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in MUTATOR_METHODS:
            attr = self_attr_root(node.func.value)
            if attr is not None:
                self._record(node.func.value, node)
        self.generic_visit(node)


class LockDisciplinePass:
    name = "lock-discipline"

    def run(self, src: SourceInfo) -> list[Finding]:
        findings: list[Finding] = []
        for cls in [n for n in ast.walk(src.tree)
                    if isinstance(n, ast.ClassDef)]:
            findings.extend(self._check_class(src, cls))
        return findings

    # ----------------------------------------------------------- per-class
    def _check_class(self, src: SourceInfo, cls: ast.ClassDef
                     ) -> list[Finding]:
        lock_attrs: set[str] = set()
        atomic_attrs: set[str] = set()
        for node in ast.walk(cls):
            if isinstance(node, ast.Assign):
                factory = _call_factory(node.value)
                for t in node.targets:
                    attr = self_attr_root(t)
                    if attr is None:
                        continue
                    if factory in LOCK_FACTORIES:
                        lock_attrs.add(attr)
                    elif factory in ATOMIC_FACTORIES:
                        atomic_attrs.add(attr)
        if not lock_attrs:
            return []

        mutations: list[_Mutation] = []
        for item in cls.body:
            if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            walker = _MethodWalker(lock_attrs, atomic_attrs,
                                   exempt=item.name in EXEMPT_METHODS)
            for stmt in item.body:
                walker.visit(stmt)
            mutations.extend(walker.mutations)

        # infer guarded-by: locks held at EVERY lock-holding mutation site
        guards: dict[str, frozenset[str]] = {}
        for m in mutations:
            if m.exempt:
                continue
            held = m.held_self & frozenset(lock_attrs)
            if not held:
                continue
            guards[m.attr] = (guards[m.attr] & held if m.attr in guards
                              else held)

        findings: list[Finding] = []
        for m in mutations:
            if m.exempt:
                continue
            guard = guards.get(m.attr)
            if not guard:
                continue          # never locked (single-writer) or consistent
            if guard & m.held_self:
                continue          # the guard lock is held
            pragma = src.pragma_at(m.line, m.end_line, "guarded-by")
            if pragma is not None:
                named = pragma.arg
                # the pragma must tell the truth: name the inferred guard
                # or a lock actually held at this site
                if named in guard or named in m.held_self \
                        or named in m.held_local:
                    continue
                findings.append(Finding(
                    src.path, m.line, self.name,
                    f"{cls.name}.{m.attr} is guarded by "
                    f"{self._fmt(guard)} but the pragma names "
                    f"{named!r}, which is neither the guard nor held "
                    f"here — fix the annotation or the code"))
                continue
            where = (f" while holding {self._fmt(m.held_self)}"
                     if m.held_self else " without any lock")
            hint = (f" (held local context {self._fmt(m.held_local)}: "
                    f"annotate with `# lint: guarded-by(...)` if it is "
                    f"the real guard)" if m.held_local else "")
            findings.append(Finding(
                src.path, m.line, self.name,
                f"{cls.name}.{m.attr} is mutated under "
                f"{self._fmt(guard)} elsewhere but mutated here"
                f"{where}{hint}"))
        return findings

    @staticmethod
    def _fmt(names: frozenset[str]) -> str:
        return "/".join(sorted(names))

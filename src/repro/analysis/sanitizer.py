"""Runtime sanitizers: lock-order tracking and device-sync call sites.

The static passes see one file at a time; these two see the process.

**LockOrderTracker** wraps ``threading.Lock``/``RLock`` *creation* (only
for locks created by ``repro.*`` modules — the caller frame is inspected
so jax/stdlib internals keep their native locks).  Every tracked acquire
records an edge ``held -> wanted`` in a global acquisition graph; an
acquire that closes a cycle in that graph is a lock-order inversion —
two threads interleaving those paths can deadlock — and is recorded as a
violation immediately, with both edge sites.  Blocking re-acquire of a
non-reentrant Lock already held by the same thread (guaranteed
self-deadlock) is also a violation.  Violations are collected, not
raised: the threaded tests assert ``tracker.violations == []`` at
teardown, so a latent inversion fails tier-1 even when the schedule that
would deadlock never ran.

**SyncSiteSanitizer** patches ``jax.device_get`` and checks the caller
stack: if the nearest ``repro.*`` frame is in the fast-path packages
(``repro.serving``/``repro.models``) and is not the allowlisted sync
site (``repro.serving.engine::_to_host``), the call is a violation —
the runtime twin of the static host-sync pass.  Calls from tests or
offline tooling (no fast-path frame) pass through untouched.
"""
from __future__ import annotations

import sys
import threading
from dataclasses import dataclass, field

_FASTPATH_PREFIXES = ("repro.serving", "repro.models")
ALLOWED_SYNC_SITES = {("repro.serving.engine", "_to_host")}


def _caller_module(depth: int) -> str:
    try:
        frame = sys._getframe(depth)
    except ValueError:
        return ""
    return frame.f_globals.get("__name__", "") or ""


@dataclass
class _Edge:
    src: str
    dst: str
    where: str           # "thread-name @ module" of the acquire that added it


class TrackedLock:
    """Lock/RLock proxy reporting acquire/release to a LockOrderTracker."""

    def __init__(self, tracker: "LockOrderTracker", inner,
                 name: str, reentrant: bool) -> None:
        self._tracker = tracker
        self._inner = inner
        self._name = name
        self._reentrant = reentrant

    # threading.Condition probes these via getattr on RLocks
    def __getattr__(self, item):
        return getattr(self._inner, item)

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        if blocking and self._tracker._before_acquire(self):
            # guaranteed self-deadlock: already recorded, fail fast
            # instead of hanging the suite
            raise RuntimeError(
                f"self-deadlock: re-acquire of held {self._name}")
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._tracker._acquired(self)
        return got

    def release(self) -> None:
        self._inner.release()
        self._tracker._released(self)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<TrackedLock {self._name}>"


class LockOrderTracker:
    """Global acquisition graph over all tracked locks, cycle = violation."""

    def __init__(self) -> None:
        self.violations: list[str] = []
        self._edges: dict[str, dict[str, _Edge]] = {}
        self._held = threading.local()
        self._graph_lock = threading.Lock()   # native: guards the graph
        self._installed = False
        self._orig_lock = None
        self._orig_rlock = None
        self._counter = 0

    # ------------------------------------------------------------ wrapping
    def wrap(self, inner=None, name: str | None = None,
             reentrant: bool = False) -> TrackedLock:
        with self._graph_lock:
            self._counter += 1
            n = self._counter
        if inner is None:
            inner = (self._orig_rlock or threading.RLock)() if reentrant \
                else (self._orig_lock or threading.Lock)()
        label = name or f"lock-{n}"
        return TrackedLock(self, inner, f"{label}#{n}", reentrant)

    def install(self, module_prefixes: tuple[str, ...] = ("repro.",)
                ) -> None:
        """Patch threading.Lock/RLock for locks created by our modules."""
        if self._installed:
            return
        self._orig_lock = threading.Lock
        self._orig_rlock = threading.RLock
        tracker = self

        def make_lock():
            mod = _caller_module(2)
            if mod.startswith(module_prefixes):
                return tracker.wrap(tracker._orig_lock(), name=mod,
                                    reentrant=False)
            return tracker._orig_lock()

        def make_rlock():
            mod = _caller_module(2)
            if mod.startswith(module_prefixes):
                return tracker.wrap(tracker._orig_rlock(), name=mod,
                                    reentrant=True)
            return tracker._orig_rlock()

        threading.Lock = make_lock          # type: ignore[assignment]
        threading.RLock = make_rlock        # type: ignore[assignment]
        self._installed = True

    def uninstall(self) -> None:
        if not self._installed:
            return
        threading.Lock = self._orig_lock    # type: ignore[assignment]
        threading.RLock = self._orig_rlock  # type: ignore[assignment]
        self._installed = False

    # ----------------------------------------------------------- recording
    def _stack(self) -> list[TrackedLock]:
        if not hasattr(self._held, "stack"):
            self._held.stack = []
        return self._held.stack

    def _before_acquire(self, lock: TrackedLock) -> bool:
        """Record edges; True iff this acquire would self-deadlock."""
        held = self._stack()
        if not held:
            return False
        if any(h is lock for h in held):
            if not lock._reentrant:
                with self._graph_lock:
                    self.violations.append(
                        f"self-deadlock: "
                        f"{threading.current_thread().name} blocking "
                        f"re-acquire of non-reentrant {lock._name} "
                        f"it already holds")
                return True
            return False
        where = (f"{threading.current_thread().name} @ "
                 f"{_caller_module(3)}")
        with self._graph_lock:
            for h in held:
                edges = self._edges.setdefault(h._name, {})
                if lock._name not in edges:
                    edges[lock._name] = _Edge(h._name, lock._name, where)
                cycle = self._find_path(lock._name, h._name)
                if cycle is not None:
                    self.violations.append(
                        f"lock-order inversion: acquiring {lock._name} "
                        f"while holding {h._name} ({where}), but the "
                        f"reverse order {' -> '.join(cycle)} was taken at "
                        f"{self._edges[cycle[0]][cycle[1]].where}")
        return False

    def _acquired(self, lock: TrackedLock) -> None:
        self._stack().append(lock)

    def _released(self, lock: TrackedLock) -> None:
        stack = self._stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is lock:
                del stack[i]
                return

    def _find_path(self, src: str, dst: str) -> list[str] | None:
        """DFS path src -> dst in the acquisition graph (caller holds
        the graph lock)."""
        seen: set[str] = set()
        path: list[str] = []

        def dfs(node: str) -> bool:
            if node == dst:
                path.append(node)
                return True
            if node in seen:
                return False
            seen.add(node)
            for nxt in self._edges.get(node, ()):
                if dfs(nxt):
                    path.append(node)
                    return True
            return False

        if dfs(src):
            return list(reversed(path))
        return None


class SyncSiteSanitizer:
    """Patch ``jax.device_get``: fast-path frames must be the sync site."""

    def __init__(self, allowed=ALLOWED_SYNC_SITES) -> None:
        self.allowed = set(allowed)
        self.violations: list[str] = []
        self._installed = False
        self._orig = None

    def install(self) -> None:
        if self._installed:
            return
        import jax
        self._orig = jax.device_get
        sanitizer = self

        def device_get(*args, **kwargs):
            site = sanitizer._fastpath_caller()
            if site is not None and site not in sanitizer.allowed:
                sanitizer.violations.append(
                    f"jax.device_get called from {site[0]}::{site[1]} — "
                    f"the fast path syncs only in "
                    f"{sorted(sanitizer.allowed)}")
            return sanitizer._orig(*args, **kwargs)

        jax.device_get = device_get
        self._installed = True

    def uninstall(self) -> None:
        if not self._installed:
            return
        import jax
        jax.device_get = self._orig
        self._installed = False

    @staticmethod
    def _fastpath_caller() -> tuple[str, str] | None:
        """Nearest ``repro.*`` frame, if it is a fast-path module."""
        depth = 2
        while True:
            try:
                frame = sys._getframe(depth)
            except ValueError:
                return None
            mod = frame.f_globals.get("__name__", "") or ""
            if mod.startswith("repro."):
                if mod.startswith(_FASTPATH_PREFIXES):
                    return (mod, frame.f_code.co_name)
                return None
            depth += 1

"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, q_pos, cache_pos, *,
                         window: int | None = None,
                         softcap: float | None = None,
                         scale: float | None = None):
    """q: (B,H,D) one new token per sequence.
    k_cache/v_cache: (B,S,K,D); cache_pos: (B,S) absolute positions (-1 empty);
    q_pos: (B,) absolute position of the new token.  Returns (B,H,D)."""
    B, H, D = q.shape
    K = k_cache.shape[2]
    G = H // K
    scale = D ** -0.5 if scale is None else scale
    qh = q.reshape(B, K, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = (cache_pos >= 0) & (cache_pos <= q_pos[:, None])
    if window is not None:
        mask &= (q_pos[:, None] - cache_pos) < window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)

"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .quant import dequantize_kv

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, q_pos, cache_pos, *,
                         window: int | None = None,
                         softcap: float | None = None,
                         scale: float | None = None):
    """q: (B,H,D) one new token per sequence.
    k_cache/v_cache: (B,S,K,D); cache_pos: (B,S) absolute positions (-1 empty);
    q_pos: (B,) absolute position of the new token.  Returns (B,H,D)."""
    B, H, D = q.shape
    K = k_cache.shape[2]
    G = H // K
    scale = D ** -0.5 if scale is None else scale
    qh = q.reshape(B, K, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = (cache_pos >= 0) & (cache_pos <= q_pos[:, None])
    if window is not None:
        mask &= (q_pos[:, None] - cache_pos) < window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


def densify_pool(k_pool, v_pool, block_tables):
    """Gather a paged pool into dense per-request caches.

    pools (N,bs,K,D); block_tables (B,nb) int32, -1 = unused (clamped to
    block 0).  Returns (k, v, cache_pos) with caches (B, nb*bs, K, D) and
    cache_pos (B, nb*bs) holding each slot's implicit absolute position
    (logical block j covers [j*bs, (j+1)*bs)), -1 for pad slots.

    THE canonical layout rule: the paged XLA fallback in models/attention.py
    and every parity test densify through here, so the -1-pad convention
    lives in one place."""
    N, bs, K, D = k_pool.shape
    B, nb = block_tables.shape
    bt = jnp.maximum(block_tables, 0)
    k = k_pool[bt].reshape(B, nb * bs, K, D)
    v = v_pool[bt].reshape(B, nb * bs, K, D)
    flat = jnp.arange(nb * bs, dtype=jnp.int32)[None, :]
    valid = jnp.repeat(block_tables >= 0, bs, axis=1)
    cache_pos = jnp.where(valid, flat, -1)
    return k, v, cache_pos


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, q_pos, *,
                               window: int | None = None,
                               softcap: float | None = None,
                               scale: float | None = None):
    """Oracle for the paged kernel: densify the block pool through the block
    tables, then run the dense oracle.

    q: (B,H,D); pools (N,bs,K,D); block_tables (B,nb) int32 (-1 = unused);
    q_pos (B,).  Logical block j of request b holds absolute positions
    [j*bs, (j+1)*bs)."""
    k, v, cache_pos = densify_pool(k_pool, v_pool, block_tables)
    return decode_attention_ref(q, k, v, q_pos, cache_pos, window=window,
                                softcap=softcap, scale=scale)


def ragged_paged_attention_ref(q, k_pool, v_pool, block_tables, row_ids,
                               token_pos, *, window: int | None = None,
                               softcap: float | None = None,
                               scale: float | None = None):
    """Oracle for the ragged kernel: expand the per-request block tables to
    per-TOKEN tables through ``row_ids``, then reuse the paged oracle — each
    packed token is a one-token "request" over its own request's blocks.

    q: (T,H,D) packed tokens (prefill-chunk tokens, decode tokens, and
    speculative multi-token VERIFY rows mixed — a row feeding k draft tokens
    at consecutive tail positions is just a k-token chunk to this oracle);
    block_tables (R,nb) int32 (-1 = unused); row_ids (T,) request row per
    token (-1 = pad); token_pos (T,) absolute positions (-1 = pad).  Pad
    lanes return exact zeros, matching the kernel's zero-l guard."""
    R = block_tables.shape[0]
    rows = jnp.clip(row_ids, 0, R - 1)
    bt_tok = jnp.where((jnp.asarray(row_ids) >= 0)[:, None],
                       jnp.asarray(block_tables)[rows], -1)   # (T, nb)
    out = paged_decode_attention_ref(q, k_pool, v_pool, bt_tok, token_pos,
                                     window=window, softcap=softcap,
                                     scale=scale)
    valid = (jnp.asarray(token_pos) >= 0) & (jnp.asarray(row_ids) >= 0)
    return jnp.where(valid[:, None, None], out, 0).astype(out.dtype)


def dequant_pool(k_pool, v_pool, k_scale, v_scale):
    """Dequantize quantized pool leaves back to f32 pools.

    pools (N,bs,K,D) int8/fp8; scales (N,bs,K) f32 — one scale per pool
    slot per kv-head (see quant.py for why granularity is per-slot)."""
    return dequantize_kv(k_pool, k_scale), dequantize_kv(v_pool, v_scale)


def paged_decode_attention_quant_ref(q, k_pool, v_pool, k_scale, v_scale,
                                     block_tables, q_pos, *,
                                     window: int | None = None,
                                     softcap: float | None = None,
                                     scale: float | None = None):
    """Quantized-pool oracle: dequantize, then run the paged oracle.

    Because dequantization is an elementwise `q * scale` in f32 here and
    in the kernel, kernel-vs-this-oracle parity stays at the same tight
    tolerance as the unquantized pair."""
    kd, vd = dequant_pool(k_pool, v_pool, k_scale, v_scale)
    return paged_decode_attention_ref(q, kd, vd, block_tables, q_pos,
                                      window=window, softcap=softcap,
                                      scale=scale)


def ragged_paged_attention_quant_ref(q, k_pool, v_pool, k_scale, v_scale,
                                     block_tables, row_ids, token_pos, *,
                                     window: int | None = None,
                                     softcap: float | None = None,
                                     scale: float | None = None):
    """Quantized-pool oracle for the ragged kernel (see above)."""
    kd, vd = dequant_pool(k_pool, v_pool, k_scale, v_scale)
    return ragged_paged_attention_ref(q, kd, vd, block_tables, row_ids,
                                      token_pos, window=window,
                                      softcap=softcap, scale=scale)

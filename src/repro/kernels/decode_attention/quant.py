"""KV-block quantization helpers shared by the pool writers, the Pallas
kernels, and the pure-JAX oracles.

The paged KV pool stores blocks in one of four dtypes
(``KV_DTYPES``): ``float32``/``bfloat16`` keep the historical unscaled
layout; ``int8``/``fp8_e4m3`` add per-(block, slot, kv-head) ``float32``
scale leaves (``k_scale``/``v_scale`` of shape ``(num_blocks,
block_size, n_kv_heads)`` alongside ``k``/``v``).

Scale granularity is deliberately per *token* (pool slot), not per
block: a per-block scale would make every stored value depend on which
other tokens currently share the block, so rewriting one slot (chunked
prefill, speculative rollback + rewrite, migration scatter into fresh
blocks) would requantize its neighbours and break the bit-stability
contract that failover/preemption replay relies on.  With per-slot
scales a written token's quantized bytes depend only on that token —
spill→adopt and preempt→resume round-trip exactly, and greedy streams
stay bit-identical at a fixed precision.

Quantization is symmetric absmax over the head dim:
``scale = amax(|x|) / qmax`` per (token, kv-head), zero-guarded so an
all-zero vector round-trips to zeros with scale 1.  int8 rounds to
nearest; fp8-e4m3 relies on the hardware cast's rounding.
"""
from __future__ import annotations

import jax.numpy as jnp

# Accepted ``kv_dtype`` knob values (None ≡ unquantized model dtype).
KV_DTYPES = ("float32", "bfloat16", "int8", "fp8_e4m3")

_QUANTIZED = {
    "int8": (jnp.int8, 127.0),
    "fp8_e4m3": (jnp.float8_e4m3fn, 448.0),
}

_ALIASES = {
    "fp32": "float32", "f32": "float32",
    "bf16": "bfloat16",
    "fp8": "fp8_e4m3", "float8_e4m3fn": "fp8_e4m3", "e4m3": "fp8_e4m3",
}


def resolve_kv_dtype(kv_dtype: str | None) -> str | None:
    """Canonicalise a ``kv_dtype`` knob value; None passes through."""
    if kv_dtype is None:
        return None
    name = _ALIASES.get(kv_dtype, kv_dtype)
    if name not in KV_DTYPES:
        raise ValueError(
            f"kv_dtype {kv_dtype!r} not in {KV_DTYPES} (or aliases "
            f"{sorted(_ALIASES)})")
    return name


def is_quantized(kv_dtype: str | None) -> bool:
    return resolve_kv_dtype(kv_dtype) in _QUANTIZED


def storage_dtype(kv_dtype: str | None, model_dtype) -> jnp.dtype:
    """The dtype pool ``k``/``v`` leaves are stored in."""
    name = resolve_kv_dtype(kv_dtype)
    if name is None:
        return jnp.dtype(model_dtype)
    if name in _QUANTIZED:
        return jnp.dtype(_QUANTIZED[name][0])
    return jnp.dtype(name)


def qmax(kv_dtype: str) -> float:
    return _QUANTIZED[resolve_kv_dtype(kv_dtype)][1]


def quantize_kv(x: jnp.ndarray, kv_dtype: str):
    """Quantize ``x`` (..., n_kv_heads, head_dim) → (q, scale).

    ``scale`` has shape ``x.shape[:-1]`` (one f32 scale per token per
    kv-head); ``q * scale[..., None]`` dequantizes.
    """
    dt, qm = _QUANTIZED[resolve_kv_dtype(kv_dtype)]
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.where(amax > 0.0, amax / qm, 1.0)
    scaled = x / scale[..., None]
    if dt == jnp.int8:
        q = jnp.clip(jnp.round(scaled), -qm, qm).astype(jnp.int8)
    else:
        q = jnp.clip(scaled, -qm, qm).astype(dt)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quantize_kv`: (..., K, D) × (..., K) → f32."""
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]

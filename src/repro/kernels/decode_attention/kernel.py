"""Flash-decoding Pallas TPU kernel: one query token vs a long KV cache.

This is THE serving hot spot (decode_32k / long_500k shapes): arithmetic
intensity is O(1) FLOP/byte — every cached K/V byte is read once per step —
so the kernel is HBM-bandwidth-bound and the design goal is to stream K/V
through VMEM at full bandwidth while keeping the softmax state in registers.

TPU adaptation: instead of CUDA's one-warp-per-split + shared-memory
reduction, we put the cache-sequence axis LAST in the grid — TPU executes it
sequentially per (batch, kv-head), so the online-softmax state (m, l, acc)
lives in VMEM scratch carried across sequence blocks, and no cross-block
reduction pass is needed.  All G = H/K query heads of a kv head are
processed together as a (G, D) tile so the (G, bk) score matmul feeds the
MXU/VPU with aligned shapes.

Ring-buffer semantics come for free: the cache's per-slot absolute positions
are streamed alongside K/V and masking is positional, so the same kernel
serves full caches, sliding-window rings, and partially-filled prefixes.

Grid: (B, K, num_kv_blocks); blocks: q (G,D), k/v (bk,D), pos (bk,).

Paged variant (``paged_decode_attention_fwd``): K/V live in a global block
pool (num_blocks, block_size, K, D) shared by every request; each request
brings a block table (its logical→physical block mapping).  The table and the
query positions are scalar-prefetch operands, so the BlockSpec index map
resolves ``table[b, j]`` BEFORE the kernel body runs and the DMA engine
streams exactly the blocks the request owns — no host gather, no densified
copy of the cache.  Slot positions are implicit (logical block j covers
absolute positions [j·bs, (j+1)·bs)), so causal masking doubles as validity
masking: padded table entries (clamped to block 0) always sit beyond the
query position.

Ragged variant (``ragged_paged_attention_fwd``): the serving engine's unified
token-budget tick packs prefill CHUNKS and decode rows into one fixed-shape
token batch, so the query axis is tokens, not requests — several consecutive
tokens may belong to one request while their neighbors belong to others.  A
third scalar-prefetch operand ``row_ids`` maps packed token t to its
request's row in the block table, so the index map gathers
``table[row_ids[t], j]`` per TOKEN and each token streams exactly its own
request's blocks.  Causality is per token (``kpos <= token_pos[t]``), which
is simultaneously the causal intra-chunk mask (a chunk token sees earlier
chunk tokens, written in this same dispatch), the cross-request isolation
(different requests own disjoint physical blocks), and the pad-lane kill
(pad tokens carry ``token_pos = -1`` so every position is masked and the
zero-l guard emits exact zeros).  Single-token paged decode is the special
case ``row_ids == arange(B)`` and is implemented that way.  Speculative
VERIFY rows (k fed tokens at consecutive tail positions of one request) are
the same packing as a k-token prefill chunk — no kernel changes needed for
speculative decoding; the engine's acceptance rule consumes the per-position
logits downstream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref, *rest, scale: float,
            softcap: float | None, window: int | None, num_kv_blocks: int,
            quantized: bool):
    # With a quantized cache two per-slot-per-head f32 scale operands ride
    # after K/V; dequant is an in-register (bk, 1) × (bk, D) broadcast.
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)                        # (G, D)
    k = k_ref[...].astype(jnp.float32)                        # (bk, D)
    if quantized:
        k = k * ks_ref[...].astype(jnp.float32)               # (bk,1) bcast
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kpos = pos_ref[...]                                       # (1, bk) int32
    qp = qpos_ref[0]
    mask = (kpos >= 0) & (kpos <= qp)
    if window is not None:
        mask &= (qp - kpos) < window
    s = jnp.where(mask, s, NEG_INF)                           # (G, bk) via bcast

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[...].astype(jnp.float32)
    if quantized:
        v = v * vs_ref[...].astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_fwd(q, k_cache, v_cache, q_pos, cache_pos, *,
                         scale: float, softcap: float | None,
                         window: int | None, block_k: int = 512,
                         k_scale=None, v_scale=None,
                         interpret: bool = False):
    """q: (B,H,D); caches (B,S,K,D); cache_pos (B,S); q_pos (B,).

    ``k_scale``/``v_scale`` (B,S,K) f32, when given, mark the caches as
    quantized (int8/fp8) and are applied in-register after the stream."""
    B, H, D = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    quantized = k_scale is not None
    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache_pos = jnp.pad(cache_pos, ((0, 0), (0, pad)), constant_values=-1)
        if quantized:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
    Sp = k_cache.shape[1]
    nk = Sp // block_k

    qh = q.reshape(B, K, G, D)
    kt = k_cache.transpose(0, 2, 1, 3)                        # (B,K,S,D)
    vt = v_cache.transpose(0, 2, 1, 3)
    pos2 = cache_pos[:, None, :]                              # (B,1,S)

    grid = (B, K, nk)
    kern = functools.partial(_kernel, scale=scale, softcap=softcap,
                             window=window, num_kv_blocks=nk,
                             quantized=quantized)
    in_specs = [
        pl.BlockSpec((1,), lambda b, h, ik: (b,)),                      # q_pos
        pl.BlockSpec((None, None, G, D), lambda b, h, ik: (b, h, 0, 0)),
        pl.BlockSpec((None, None, block_k, D), lambda b, h, ik: (b, h, ik, 0)),
        pl.BlockSpec((None, None, block_k, D), lambda b, h, ik: (b, h, ik, 0)),
        pl.BlockSpec((None, 1, block_k), lambda b, h, ik: (b, 0, ik)),  # pos
    ]
    operands = [q_pos, qh, kt, vt, pos2]
    if quantized:
        # (B,S,K) → (B,K,S,1): a (block_k, 1) tile broadcasting over D.
        in_specs += [
            pl.BlockSpec((None, None, block_k, 1),
                         lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((None, None, block_k, 1),
                         lambda b, h, ik: (b, h, ik, 0)),
        ]
        operands += [k_scale.transpose(0, 2, 1)[..., None],
                     v_scale.transpose(0, 2, 1)[..., None]]
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, None, G, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    return out.reshape(B, H, D)


def _ragged_kernel(rows_ref, bt_ref, qpos_ref, nblk_ref, q_ref, k_ref, v_ref,
                   *rest, scale: float, softcap: float | None,
                   window: int | None, block_size: int,
                   num_logical_blocks: int, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    t = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Per-token early-out: blocks past the row's live count are -1 table
    # entries — fully masked below, so their update is an exact identity
    # (p = 0, alpha = 1) and skipping the whole body is lossless.  Their
    # index map clamps to block 0 too, so the revolving input buffer sees
    # the same block every tail step and the DMA is elided: short rows in
    # a batch with one long row stop paying the long row's gather + QK.
    live = nblk_ref[rows_ref[t]]

    @pl.when(j < live)
    def _accumulate():
        q = q_ref[...].astype(jnp.float32)                    # (G, D)
        k = k_ref[...].astype(jnp.float32)                    # (bs, D)
        if quantized:
            k = k * ks_ref[...].astype(jnp.float32)           # (bs,1) bcast
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        # logical block j covers absolute positions [j*bs, (j+1)*bs): masking
        # is positional, so clamped pad blocks (positions beyond qp) vanish
        # here, as do pad tokens entirely (qp = -1 masks everything, and
        # live = 0 already skips them; l stays 0).
        kpos = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_size), 1)                    # (1, bs)
        qp = qpos_ref[t]
        mask = kpos <= qp
        if window is not None:
            mask &= (qp - kpos) < window
        s = jnp.where(mask, s, NEG_INF)                       # (G, bs) bcast

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        # explicit re-mask: when EVERY position is masked (window start of a
        # live block), s - m_new is NEG_INF - NEG_INF = 0 and exp would emit
        # 1s; zeroing by mask keeps l exact so finalize can guard on l == 0.
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)
        if quantized:
            v = v * vs_ref[...].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    @pl.when(j == num_logical_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)


# nb must stay "arbitrary": the online-softmax scratch (m, l, acc) is
# carried across the block axis, so those iterations are sequential by
# construction.  T and K carry no cross-iteration state and default to
# "parallel" so Mosaic may split them across megacore.
DEFAULT_DIMENSION_SEMANTICS = ("parallel", "parallel", "arbitrary")


def suggest_block_size(head_dim: int, group_size: int, *,
                       vmem_budget_bytes: int = 32 * 2 ** 20,
                       kv_itemsize: int = 4,
                       candidates: tuple = (512, 256, 128, 64, 32, 16, 8)
                       ) -> int:
    """Largest candidate block_size whose per-iteration VMEM working set
    (double-buffered K/V tiles + scale columns + q tile + softmax scratch,
    all f32 in-register) fits ``vmem_budget_bytes``.

    A tuning hook, not an oracle: real-TPU block_size also trades gather
    granularity against pool fragmentation, so callers treat this as the
    upper bound and benchmark downward."""
    for bs in candidates:
        kv_tiles = 2 * 2 * bs * head_dim * kv_itemsize      # K+V, 2x buffered
        scale_cols = 2 * 2 * bs * 4                         # k/v scale tiles
        q_tile = group_size * head_dim * 4
        scratch = group_size * (head_dim + 2) * 4           # m, l, acc
        if kv_tiles + scale_cols + q_tile + scratch <= vmem_budget_bytes:
            return bs
    return candidates[-1]


def ragged_paged_attention_fwd(q, k_pool, v_pool, block_tables, row_ids,
                               token_pos, *, scale: float,
                               softcap: float | None, window: int | None,
                               k_scale=None, v_scale=None,
                               dimension_semantics: tuple | None = None,
                               interpret: bool = False):
    """q: (T,H,D) packed tokens; pools (N,bs,K,D); block_tables (R,nb) int32,
    -1 = unused; row_ids (T,) request row of each token (-1 = pad lane);
    token_pos (T,) absolute position of each token (-1 = pad lane).

    Grid (T, K, nb): the per-token row gather happens in the BlockSpec index
    map — ``bt[rows[t], j]`` — so the DMA engine streams, for every packed
    token, exactly the blocks of the request that token belongs to.  Pad
    lanes (row -1 / pos -1) clamp to request row 0 / the null block and are
    fully masked, producing exact zeros.

    ``k_scale``/``v_scale`` (N,bs,K) f32, when given, mark the pool as
    quantized (int8/fp8 leaves): the kernel dequantizes in-register after
    the block-table gather, so HBM only ever streams the narrow bytes.

    A fourth scalar-prefetch operand carries per-row live-block counts
    (``sum(block_tables >= 0, axis=1)`` — tables are dense prefixes), and
    the kernel skips the zero-contribution tail of the nb axis per token."""
    T, H, D = q.shape
    N, bs, K, _ = k_pool.shape
    G = H // K
    nb = block_tables.shape[1]
    quantized = k_scale is not None
    # -1 pads clamp to block 0 (the engine's reserved null block); their
    # implicit positions j*bs+p exceed token_pos, so the causal mask kills
    # them.  Pad ROWS clamp to row 0; token_pos = -1 masks every position.
    bt = jnp.maximum(block_tables, 0).astype(jnp.int32)
    rows = jnp.clip(row_ids, 0, block_tables.shape[0] - 1).astype(jnp.int32)
    # Per-row live-block counts for the early-out (tables are dense
    # prefixes: valid entries precede every -1).
    nblk = jnp.sum(block_tables >= 0, axis=1).astype(jnp.int32)

    qh = q.reshape(T, K, G, D)
    kt = k_pool.transpose(0, 2, 1, 3)                         # (N,K,bs,D)
    vt = v_pool.transpose(0, 2, 1, 3)

    kern = functools.partial(_ragged_kernel, scale=scale, softcap=softcap,
                             window=window, block_size=bs,
                             num_logical_blocks=nb, quantized=quantized)
    in_specs = [
        pl.BlockSpec((None, None, G, D),
                     lambda t, h, j, rows, bt, qp, nblk: (t, h, 0, 0)),  # q
        pl.BlockSpec((None, None, bs, D),
                     lambda t, h, j, rows, bt, qp, nblk:
                     (bt[rows[t], j], h, 0, 0)),                         # k
        pl.BlockSpec((None, None, bs, D),
                     lambda t, h, j, rows, bt, qp, nblk:
                     (bt[rows[t], j], h, 0, 0)),                         # v
    ]
    operands = [qh, kt, vt]
    if quantized:
        # (N,bs,K) → (N,K,bs,1): a (bs, 1) tile gathered by the same block
        # index, broadcasting over D in the kernel.
        in_specs += [
            pl.BlockSpec((None, None, bs, 1),
                         lambda t, h, j, rows, bt, qp, nblk:
                         (bt[rows[t], j], h, 0, 0)),
            pl.BlockSpec((None, None, bs, 1),
                         lambda t, h, j, rows, bt, qp, nblk:
                         (bt[rows[t], j], h, 0, 0)),
        ]
        operands += [k_scale.transpose(0, 2, 1)[..., None],
                     v_scale.transpose(0, 2, 1)[..., None]]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,                        # rows, bt, qp, nblk
        grid=(T, K, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, None, G, D),
                               lambda t, h, j, rows, bt, qp, nblk:
                               (t, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=(dimension_semantics
                                 or DEFAULT_DIMENSION_SEMANTICS))
    out = pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, K, G, D), q.dtype),
        interpret=interpret, **kwargs,
    )(rows, bt, token_pos.astype(jnp.int32), nblk, *operands)
    return out.reshape(T, H, D)


def paged_decode_attention_fwd(q, k_pool, v_pool, block_tables, q_pos, *,
                               scale: float, softcap: float | None,
                               window: int | None, k_scale=None,
                               v_scale=None, interpret: bool = False):
    """q: (B,H,D); pools (N,bs,K,D); block_tables (B,nb) int32, -1 = unused;
    q_pos (B,) absolute position of the query token.

    Single-token decode is the ragged kernel's degenerate packing: one token
    per request, ``row_ids == arange(B)``."""
    B = q.shape[0]
    return ragged_paged_attention_fwd(
        q, k_pool, v_pool, block_tables, jnp.arange(B, dtype=jnp.int32),
        q_pos, scale=scale, softcap=softcap, window=window,
        k_scale=k_scale, v_scale=v_scale, interpret=interpret)

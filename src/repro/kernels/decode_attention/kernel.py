"""Flash-decoding Pallas TPU kernel: one query token vs a long KV cache.

This is THE serving hot spot (decode_32k / long_500k shapes): arithmetic
intensity is O(1) FLOP/byte — every cached K/V byte is read once per step —
so the kernel is HBM-bandwidth-bound and the design goal is to stream K/V
through VMEM at full bandwidth while keeping the softmax state in registers.

TPU adaptation: instead of CUDA's one-warp-per-split + shared-memory
reduction, we put the cache-sequence axis LAST in the grid — TPU executes it
sequentially per (batch, kv-head), so the online-softmax state (m, l, acc)
lives in VMEM scratch carried across sequence blocks, and no cross-block
reduction pass is needed.  All G = H/K query heads of a kv head are
processed together as a (G, D) tile so the (G, bk) score matmul feeds the
MXU/VPU with aligned shapes.

Ring-buffer semantics come for free: the cache's per-slot absolute positions
are streamed alongside K/V and masking is positional, so the same kernel
serves full caches, sliding-window rings, and partially-filled prefixes.

Grid: (B, K, num_kv_blocks); blocks: q (G,D), k/v (bk,D), pos (bk,).

Paged variant (``paged_decode_attention_fwd``): K/V live in a global block
pool (num_blocks, block_size, K, D) shared by every request; each request
brings a block table (its logical→physical block mapping).  The table and the
query positions are scalar-prefetch operands, so the BlockSpec index map
resolves ``table[b, j]`` BEFORE the kernel body runs and the DMA engine
streams exactly the blocks the request owns — no host gather, no densified
copy of the cache.  Slot positions are implicit (logical block j covers
absolute positions [j·bs, (j+1)·bs)), so causal masking doubles as validity
masking: padded table entries (clamped to block 0) always sit beyond the
query position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, softcap: float | None,
            window: int | None, num_kv_blocks: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)                        # (G, D)
    k = k_ref[...].astype(jnp.float32)                        # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kpos = pos_ref[...]                                       # (1, bk) int32
    qp = qpos_ref[0]
    mask = (kpos >= 0) & (kpos <= qp)
    if window is not None:
        mask &= (qp - kpos) < window
    s = jnp.where(mask, s, NEG_INF)                           # (G, bk) via bcast

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[...].astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_fwd(q, k_cache, v_cache, q_pos, cache_pos, *,
                         scale: float, softcap: float | None,
                         window: int | None, block_k: int = 512,
                         interpret: bool = False):
    """q: (B,H,D); caches (B,S,K,D); cache_pos (B,S); q_pos (B,)."""
    B, H, D = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache_pos = jnp.pad(cache_pos, ((0, 0), (0, pad)), constant_values=-1)
    Sp = k_cache.shape[1]
    nk = Sp // block_k

    qh = q.reshape(B, K, G, D)
    kt = k_cache.transpose(0, 2, 1, 3)                        # (B,K,S,D)
    vt = v_cache.transpose(0, 2, 1, 3)
    pos2 = cache_pos[:, None, :]                              # (B,1,S)

    grid = (B, K, nk)
    kern = functools.partial(_kernel, scale=scale, softcap=softcap,
                             window=window, num_kv_blocks=nk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ik: (b,)),                      # q_pos
            pl.BlockSpec((None, None, G, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((None, None, block_k, D), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((None, None, block_k, D), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((None, 1, block_k), lambda b, h, ik: (b, 0, ik)),  # pos
        ],
        out_specs=pl.BlockSpec((None, None, G, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, qh, kt, vt, pos2)
    return out.reshape(B, H, D)


def _paged_kernel(bt_ref, qpos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float,
                  softcap: float | None, window: int | None,
                  block_size: int, num_logical_blocks: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)                        # (G, D)
    k = k_ref[...].astype(jnp.float32)                        # (bs, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    # logical block j covers absolute positions [j*bs, (j+1)*bs): masking is
    # positional, so clamped pad blocks (positions beyond qp) vanish here.
    kpos = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)                        # (1, bs)
    qp = qpos_ref[b]
    mask = kpos <= qp
    if window is not None:
        mask &= (qp - kpos) < window
    s = jnp.where(mask, s, NEG_INF)                           # (G, bs) via bcast

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[...].astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = m_new

    @pl.when(j == num_logical_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_attention_fwd(q, k_pool, v_pool, block_tables, q_pos, *,
                               scale: float, softcap: float | None,
                               window: int | None, interpret: bool = False):
    """q: (B,H,D); pools (N,bs,K,D); block_tables (B,nb) int32, -1 = unused;
    q_pos (B,) absolute position of the query token."""
    B, H, D = q.shape
    N, bs, K, _ = k_pool.shape
    G = H // K
    nb = block_tables.shape[1]
    # -1 pads clamp to block 0 (the engine's reserved null block); their
    # implicit positions j*bs+p exceed q_pos, so the causal mask kills them.
    bt = jnp.maximum(block_tables, 0).astype(jnp.int32)

    qh = q.reshape(B, K, G, D)
    kt = k_pool.transpose(0, 2, 1, 3)                         # (N,K,bs,D)
    vt = v_pool.transpose(0, 2, 1, 3)

    kern = functools.partial(_paged_kernel, scale=scale, softcap=softcap,
                             window=window, block_size=bs,
                             num_logical_blocks=nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                                # bt, q_pos
        grid=(B, K, nb),
        in_specs=[
            pl.BlockSpec((None, None, G, D),
                         lambda b, h, j, bt, qp: (b, h, 0, 0)),       # q
            pl.BlockSpec((None, None, bs, D),
                         lambda b, h, j, bt, qp: (bt[b, j], h, 0, 0)),  # k
            pl.BlockSpec((None, None, bs, D),
                         lambda b, h, j, bt, qp: (bt[b, j], h, 0, 0)),  # v
        ],
        out_specs=pl.BlockSpec((None, None, G, D),
                               lambda b, h, j, bt, qp: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        interpret=interpret,
    )(bt, q_pos.astype(jnp.int32), qh, kt, vt)
    return out.reshape(B, H, D)

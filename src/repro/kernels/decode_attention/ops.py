"""Jit'd public wrappers for the decode-attention kernels (dense + paged).

Quantized KV pools: every paged wrapper takes optional ``k_scale``/
``v_scale`` operands (per-(block, slot, kv-head) f32, shape
``(num_blocks, block_size, K)``).  Passing them flips the kernel into
dequantize-in-register mode — the int8/fp8 pool leaves are the only K/V
bytes streamed from HBM.  Presence of the operands is the switch, so one
jitted wrapper serves every ``kv_dtype`` without retracing on value.
"""
from __future__ import annotations

import functools

import jax

from .kernel import (decode_attention_fwd, paged_decode_attention_fwd,
                     ragged_paged_attention_fwd, suggest_block_size)
from .quant import (KV_DTYPES, dequantize_kv, is_quantized, quantize_kv,
                    resolve_kv_dtype)
from .ref import (decode_attention_ref, paged_decode_attention_quant_ref,
                  paged_decode_attention_ref,
                  ragged_paged_attention_quant_ref,
                  ragged_paged_attention_ref)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, q_pos, cache_pos, *,
                     k_scale=None, v_scale=None,
                     window: int | None = None, softcap: float | None = None,
                     scale: float | None = None, block_k: int = 512,
                     interpret: bool = False):
    """One-token decode attention.  q: (B,H,D); caches (B,S,K,D);
    optional quantized-cache scales (B,S,K)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    return decode_attention_fwd(q, k_cache, v_cache, q_pos, cache_pos,
                                scale=scale, softcap=softcap, window=window,
                                block_k=block_k, k_scale=k_scale,
                                v_scale=v_scale, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "interpret"))
def paged_decode_attention(q, k_pool, v_pool, block_tables, q_pos, *,
                           k_scale=None, v_scale=None,
                           window: int | None = None,
                           softcap: float | None = None,
                           scale: float | None = None,
                           interpret: bool = False):
    """One-token decode attention over a paged KV pool.

    q: (B,H,D); pools (num_blocks, block_size, K, D); block_tables (B,nb)
    int32 physical block ids (-1 = unused); q_pos (B,) absolute positions.
    The kernel streams each request's blocks straight out of the shared pool
    via scalar-prefetched table lookups (no densifying gather)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    return paged_decode_attention_fwd(q, k_pool, v_pool, block_tables, q_pos,
                                      scale=scale, softcap=softcap,
                                      window=window, k_scale=k_scale,
                                      v_scale=v_scale, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "dimension_semantics",
                                             "interpret"))
def ragged_paged_attention(q, k_pool, v_pool, block_tables, row_ids,
                           token_pos, *, k_scale=None, v_scale=None,
                           window: int | None = None,
                           softcap: float | None = None,
                           scale: float | None = None,
                           dimension_semantics: tuple | None = None,
                           interpret: bool = False):
    """Mixed prefill-chunk + decode attention over a paged KV pool.

    q: (T,H,D) packed tokens; pools (num_blocks, block_size, K, D);
    block_tables (R,nb) int32 physical block ids (-1 = unused); row_ids (T,)
    request row of each packed token (-1 = pad lane); token_pos (T,) absolute
    positions (-1 = pad lane).  One dispatch serves prefill chunks and decode
    rows alike: every token streams its own request's blocks via a per-token
    scalar-prefetched table gather and is causally masked at its own
    position, so intra-chunk causality, cross-request isolation, and pad-lane
    suppression are all the same mask.

    Multi-token VERIFY rows (speculative decoding) are the same packing: a
    row that feeds k tokens at consecutive tail positions [P, P+k) is
    indistinguishable from a k-token prefill chunk — K/V for all k positions
    is written before any token reads, and each token attends causally at
    its own position, which is exactly the draft-verification semantics the
    engine's acceptance rule needs.  k = 1 degenerates to today's
    single-token decode (``paged_decode_attention`` is literally this kernel
    with ``row_ids = arange(B)``).

    ``k_scale``/``v_scale`` (num_blocks, block_size, K) f32 mark the pool
    as quantized; ``dimension_semantics`` is the real-TPU tuning hook (nb
    must stay sequential — see kernel.DEFAULT_DIMENSION_SEMANTICS)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    return ragged_paged_attention_fwd(q, k_pool, v_pool, block_tables,
                                      row_ids, token_pos, scale=scale,
                                      softcap=softcap, window=window,
                                      k_scale=k_scale, v_scale=v_scale,
                                      dimension_semantics=dimension_semantics,
                                      interpret=interpret)


__all__ = ["decode_attention", "decode_attention_ref",
           "paged_decode_attention", "paged_decode_attention_ref",
           "paged_decode_attention_quant_ref",
           "ragged_paged_attention", "ragged_paged_attention_ref",
           "ragged_paged_attention_quant_ref",
           "KV_DTYPES", "resolve_kv_dtype", "is_quantized",
           "quantize_kv", "dequantize_kv", "suggest_block_size"]

"""Jit'd public wrapper for the decode-attention kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import decode_attention_fwd
from .ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, q_pos, cache_pos, *,
                     window: int | None = None, softcap: float | None = None,
                     scale: float | None = None, block_k: int = 512,
                     interpret: bool = False):
    """One-token decode attention.  q: (B,H,D); caches (B,S,K,D)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    return decode_attention_fwd(q, k_cache, v_cache, q_pos, cache_pos,
                                scale=scale, softcap=softcap, window=window,
                                block_k=block_k, interpret=interpret)


__all__ = ["decode_attention", "decode_attention_ref"]

"""Jit'd public wrappers for the decode-attention kernels (dense + paged)."""
from __future__ import annotations

import functools

import jax

from .kernel import decode_attention_fwd, paged_decode_attention_fwd
from .ref import decode_attention_ref, paged_decode_attention_ref


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, q_pos, cache_pos, *,
                     window: int | None = None, softcap: float | None = None,
                     scale: float | None = None, block_k: int = 512,
                     interpret: bool = False):
    """One-token decode attention.  q: (B,H,D); caches (B,S,K,D)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    return decode_attention_fwd(q, k_cache, v_cache, q_pos, cache_pos,
                                scale=scale, softcap=softcap, window=window,
                                block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "interpret"))
def paged_decode_attention(q, k_pool, v_pool, block_tables, q_pos, *,
                           window: int | None = None,
                           softcap: float | None = None,
                           scale: float | None = None,
                           interpret: bool = False):
    """One-token decode attention over a paged KV pool.

    q: (B,H,D); pools (num_blocks, block_size, K, D); block_tables (B,nb)
    int32 physical block ids (-1 = unused); q_pos (B,) absolute positions.
    The kernel streams each request's blocks straight out of the shared pool
    via scalar-prefetched table lookups (no densifying gather)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    return paged_decode_attention_fwd(q, k_pool, v_pool, block_tables, q_pos,
                                      scale=scale, softcap=softcap,
                                      window=window, interpret=interpret)


__all__ = ["decode_attention", "decode_attention_ref",
           "paged_decode_attention", "paged_decode_attention_ref"]

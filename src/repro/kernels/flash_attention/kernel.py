"""Flash attention Pallas TPU kernel: causal + sliding-window + softcap + GQA.

TPU adaptation notes (vs the CUDA flash-attention the paper-era GPU stacks
use): the MXU wants 128-aligned matmul dims and the VPU operates on
(8,128) vregs, so we tile queries and keys into (block_q, head_dim) and
(block_k, head_dim) VMEM blocks with head_dim untiled (≤ 256).  TPU grids
execute sequentially over the *last* grid axis, so the online-softmax
running state (m, l, acc) lives in VMEM scratch and is carried across the
kv-block axis of the grid; the output is finalized when the kv axis hits its
last iteration.  Causal/window skipping uses pl.when on whole blocks —
the same work-skipping a CUDA kernel gets from early-exit loops.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks).
  q block:   (block_q, head_dim)      — indexed by (b, h, iq)
  k/v block: (block_k, head_dim)      — indexed by (b, h // group, ik)
  out block: (block_q, head_dim)      — written at the final ik
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, softcap: float | None, window: int | None,
            block_q: int, block_k: int, num_kv_blocks: int, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # Whole-block skip: block fully masked if its oldest key is beyond the
    # window of the newest query, or all keys are in the future.
    newest_q = q_start + block_q - 1
    oldest_q = q_start
    in_causal = k_start <= newest_q
    in_window = True
    if window is not None:
        in_window = (k_start + block_k - 1) >= (oldest_q - window + 1)

    @pl.when(in_causal & in_window)
    def _compute():
        q = q_ref[...].astype(jnp.float32)                    # (bq, D)
        k = k_ref[...].astype(jnp.float32)                    # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = (kpos <= qpos) & (kpos < seq_len)
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                   # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                                # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                       # (bq, 1)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)                    # (bk, D)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, scale: float, softcap: float | None,
                        window: int | None, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False):
    """q: (B,S,H,D); k,v: (B,S,K,D).  Returns (B,S,H,D)."""
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    pad_q = (-S) % block_q
    pad_k = (-S) % block_k
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq, Sk = q.shape[1], k.shape[1]
    nq, nk = Sq // block_q, Sk // block_k

    # (B,S,H,D) -> (B,H,S,D) for head-major blocking
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, nq, nk)
    kern = functools.partial(
        _kernel, scale=scale, softcap=softcap, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk, seq_len=S)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((None, None, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((None, None, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m: running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # l: running denominator
            pltpu.VMEM((block_q, D), jnp.float32),   # acc: running numerator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :S]

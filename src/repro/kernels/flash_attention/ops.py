"""Jit'd public wrapper for the flash-attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd
from .ref import attention_ref


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, positions=None, window: int | None = None,
                    softcap: float | None = None, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """Causal flash attention.  q: (B,S,H,D); k,v: (B,S,K,D).

    ``positions`` is accepted for interface parity with the XLA path but the
    kernel assumes contiguous positions 0..S-1 (true for train/prefill).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    return flash_attention_fwd(q, k, v, scale=scale, softcap=softcap,
                               window=window, block_q=block_q, block_k=block_k,
                               interpret=interpret)


__all__ = ["flash_attention", "attention_ref"]

"""Pure-jnp oracle for the flash-attention kernel.

Materializes the full (S,S) score matrix — only usable at test shapes, which
is the point: the kernel must match this bit-for-bit up to accumulation
order.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(q, k, v, *, window: int | None = None,
                  softcap: float | None = None, scale: float | None = None,
                  causal: bool = True):
    """q: (B,S,H,D); k,v: (B,S,K,D) with H % K == 0.  Returns (B,S,H,D)."""
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    scale = D ** -0.5 if scale is None else scale
    qh = q.reshape(B, S, K, G, D)
    s = jnp.einsum("btkgd,bskd->bkgts", qh.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window is not None:
        mask &= (i - j) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)

"""Mamba-2 SSD chunked-scan Pallas TPU kernel [arXiv:2405.21060].

The SSD insight: the SSM recurrence over a chunk of Q steps can be computed
as a small attention-like quadratic form (MXU work) plus a rank-N state
carried between chunks (sequential, but only S/Q steps).  On GPU the
original implementation fuses this into a Triton kernel with warp-level
scans; the TPU-native mapping is:

- grid = (batch, heads, num_chunks) with the CHUNK axis last → sequential on
  TPU, so the inter-chunk state h (P×N) lives in VMEM scratch and is carried
  across grid steps, exactly like the flash-attention softmax state;
- intra-chunk work is three MXU matmuls (C·Bᵀ, masked-decay weighted score ×
  x, and C·h for the inter-chunk term) over (Q,N)/(Q,P) tiles — Q,P,N are
  chosen 64..128 so every matmul is MXU-aligned;
- the cumulative-decay vectors are VPU element-wise work in f32.

Blocks per grid step: x (Q,P), dt (Q,1), B/C (Q,N); scratch h (P,N) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, dterm_ref, y_ref, hout_ref,
            h_scr, *, num_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[...].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[...].astype(jnp.float32)        # (Q, 1)
    B = b_ref[...].astype(jnp.float32)          # (Q, N)
    C = c_ref[...].astype(jnp.float32)          # (Q, N)
    A = a_ref[0]                                # scalar (this head's A)

    a = dt * A                                  # (Q,1) log-decay
    cum_a = jnp.cumsum(a, axis=0)               # (Q,1)
    Q = x.shape[0]

    # intra-chunk: scores[i,j] = C_i·B_j · exp(cum_a_i - cum_a_j) · dt_j, j<=i
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    rel = cum_a - cum_a.reshape(1, Q)           # (Q,Q) i rows, j cols
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(ii >= jj, jnp.exp(rel), 0.0)
    scores = CB * decay * dt.reshape(1, Q)      # (Q,Q)
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q,P)

    # inter-chunk: y += exp(cum_a) * (C @ h_prevᵀ)
    h_prev = h_scr[...]                         # (P,N)
    y += jnp.exp(cum_a) * jax.lax.dot_general(
        C, h_prev, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    # D-term passthrough
    y += dterm_ref[0] * x

    # state update: h = exp(Σa)·h_prev + xᵀ @ (B · exp(cum_a_end - cum_a) · dt)
    seg = jnp.exp(cum_a[Q - 1 : Q] - cum_a)     # (Q,1) decay j→end
    Bw = B * seg * dt                           # (Q,N)
    h_new = jnp.exp(cum_a[Q - 1, 0]) * h_prev + jax.lax.dot_general(
        x, Bw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    h_scr[...] = h_new

    y_ref[...] = y.astype(y_ref.dtype)

    @pl.when(ic == num_chunks - 1)
    def _final():
        hout_ref[...] = h_new.astype(hout_ref.dtype)


def ssd_fwd(x, dt, A, B_, C_, D=None, *, chunk: int = 128,
            interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); B_,C_: (B,S,N).
    Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    NC = Sp // Q
    if D is None:
        D = jnp.zeros((H,), jnp.float32)

    xt = x.transpose(0, 2, 1, 3)                # (B,H,S,P)
    dtt = dt.transpose(0, 2, 1)[..., None]      # (B,H,S,1)

    grid = (Bb, H, NC)
    kern = functools.partial(_kernel, num_chunks=NC)
    y, h_final = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ic: (h,)),                       # A
            pl.BlockSpec((None, None, Q, P), lambda b, h, ic: (b, h, ic, 0)),  # x
            pl.BlockSpec((None, None, Q, 1), lambda b, h, ic: (b, h, ic, 0)),  # dt
            pl.BlockSpec((None, Q, N), lambda b, h, ic: (b, ic, 0)),         # B
            pl.BlockSpec((None, Q, N), lambda b, h, ic: (b, ic, 0)),         # C
            pl.BlockSpec((1,), lambda b, h, ic: (h,)),                       # D
        ],
        out_specs=[
            pl.BlockSpec((None, None, Q, P), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((None, None, P, N), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, H, Sp, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(A.astype(jnp.float32), xt, dtt, B_, C_, D.astype(jnp.float32))
    y = y.transpose(0, 2, 1, 3)[:, :S]
    return y, h_final

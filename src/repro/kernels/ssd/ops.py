"""Jit'd public wrapper for the SSD kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import ssd_fwd
from .ref import ssd_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B_, C_, D=None, *, chunk: int = 128, interpret: bool = False):
    """Chunked SSD scan.  See kernel.py for layout."""
    return ssd_fwd(x, dt, A, B_, C_, D, chunk=chunk, interpret=interpret)


__all__ = ["ssd", "ssd_ref"]

"""Pure-jnp oracle for the SSD kernel: naive sequential recurrence.

h_t = exp(dt_t·A) h_{t-1} + dt_t · B_t ⊗ x_t ;  y_t = C_t · h_t + D · x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B_, C_, D=None, h0=None):
    """x: (B,S,H,P); dt: (B,S,H) (post-softplus); A: (H,) negative;
    B_,C_: (B,S,N).  Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B_.astype(jnp.float32)
    Cf = C_.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), jnp.float32)

    def step(h, t):
        dec = jnp.exp(dtf[:, t] * A)                          # (B,H)
        dtx = dtf[:, t][..., None] * xf[:, t]                 # (B,H,P)
        h = h * dec[:, :, None, None] + dtx[..., None] * Bf[:, t][:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, Cf[:, t])
        return h, y

    h_final, ys = jax.lax.scan(step, h0, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1)                                # (B,S,H,P)
    if D is not None:
        y = y + D[None, None, :, None] * xf
    return y.astype(x.dtype), h_final

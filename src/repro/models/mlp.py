"""Gated MLP (SwiGLU/GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, dtype_of


def mlp_init(key, cfg: ModelConfig, *, d_in: int | None = None,
             d_out: int | None = None, d_ff: int | None = None) -> dict:
    d_in = d_in or cfg.d_model
    d_out = d_out or cfg.d_model
    d_ff = d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_in, d_ff), dt),
        "w_up": dense_init(k2, (d_in, d_ff), dt),
        "w_down": dense_init(k3, (d_ff, d_out), dt),
    }


def mlp_axes() -> dict:
    return {"w_gate": ("embed", "ffn"),
            "w_up": ("embed", "ffn"),
            "w_down": ("ffn", "embed")}


def mlp(params: dict, x: jax.Array, *, activation: str = "silu") -> jax.Array:
    g = jnp.einsum("btd,df->btf", x, params["w_gate"])
    u = jnp.einsum("btd,df->btf", x, params["w_up"])
    act = jax.nn.gelu(g) if activation == "gelu" else jax.nn.silu(g)
    return jnp.einsum("btf,fd->btd", act * u, params["w_down"])

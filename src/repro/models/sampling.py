"""In-dispatch samplers: plain sampling-with-scores and speculative verify.

Both run INSIDE the engine's jitted step so the host never sees logits —
only token ids plus per-token ``[log p(token), entropy]`` scores computed
from the same log-softmax the sampler needs anyway (cascade gates read
them; see serving/cluster.CascadeGate).

``speculative_verify`` is the acceptance rule of speculative decoding
(Leviathan et al.: rejection-sample the target distribution through a
cheap draft).  The serving engine packs a decode row's fed tokens
``[t_last, d_1, .., d_m]`` at positions ``[P, .., P+m]`` into the unified
ragged dispatch; the target model then scores all m+1 positions in that
ONE step, and this function turns the resulting ``(R, K+1, V)`` logits
into the row's emitted tokens:

- ``logits[r, i]`` is the target's next-token distribution after consuming
  fed token i — i.e. the distribution draft ``d_{i+1}`` is a guess from.
- Drafts here are POINT MASSES (a self-draft / cascade draft proposes one
  token, not a distribution), so the acceptance probability
  ``min(1, p(d)/q(d))`` reduces to ``p_target(d_i)`` and the residual
  ``(p - q)+`` to the target distribution with ``d_i`` masked out,
  renormalized.  Accept-or-residual then emits EXACTLY the target
  distribution at every position: ``P(emit d) = p(d)`` and
  ``P(emit x != d) = (1 - p(d)) * p(x) / (1 - p(d)) = p(x)``.
- Greedy (``temperature <= 0``) degenerates to: accept while the draft
  matches the argmax, emit the argmax at the first mismatch — the emitted
  stream is bit-identical to non-speculative greedy decode.

The emitted tokens are the accepted draft prefix plus one correction
(the residual sample at the first rejection) or, when every draft is
accepted, one bonus token from the final position — so a row always emits
``n_accept + 1`` tokens, between 1 and K+1.  ``draft_len == 0`` rows
(plain decode, prefill boundaries) fall through to ordinary sampling at
position 0, which is how the engine runs ONE code path for both modes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _scores(logp: jax.Array, tokens: jax.Array) -> jax.Array:
    """Per-token [log p(token), entropy(p)] from an UNTEMPERED log-softmax
    (the engine's scoring convention: confidence is measured under the
    model's own distribution even when sampling is tempered)."""
    tok_logp = jnp.take_along_axis(logp, tokens[..., None], axis=-1)[..., 0]
    ent = -jnp.sum(jnp.exp(logp) * logp, axis=-1)
    return jnp.stack([tok_logp, ent], axis=-1)


def sample_with_scores(logits: jax.Array, seed, temperature: float
                       ) -> tuple[jax.Array, jax.Array]:
    """Sample + score one token per row.  logits (B, V); returns
    (tokens (B,) int32, scores (B, 2))."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    if temperature <= 0:
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        key = jax.random.PRNGKey(seed)
        tok = jax.random.categorical(key, logits / temperature)
        tok = tok.astype(jnp.int32)
    return tok, _scores(logp, tok)


def speculative_verify(logits: jax.Array, draft_tokens: jax.Array,
                       draft_len: jax.Array, seed, temperature: float
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Rejection-sampling acceptance over a row's verified draft positions.

    logits (R, K+1, V): row r's target logits at its fed positions (index i
    = after consuming fed token i; see module docstring).  draft_tokens
    (R, K) int32 (garbage past ``draft_len``); draft_len (R,) int32 in
    [0, K].  Returns

    - tokens (R, K+1) int32 — emitted token j of row r is ``tokens[r, j]``;
      only j <= n_accept[r] are meaningful,
    - n_accept (R,) int32 — accepted draft count (leading-run),
    - scores (R, K+1, 2) — [logprob, entropy] per emitted position.

    Rows with ``draft_len == 0`` reduce to ``sample_with_scores`` on their
    position-0 logits (n_accept = 0, one emitted token).
    """
    R, K1, V = logits.shape
    K = K1 - 1
    lf = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lf, axis=-1)
    idx = jnp.arange(K1, dtype=jnp.int32)[None, :]             # (1, K+1)
    live = idx[:, :K] < draft_len[:, None]                     # (R, K)
    if temperature <= 0:
        # greedy: accept while the draft IS the argmax; candidates double as
        # both the correction (first mismatch) and the bonus (full accept),
        # and equal the accepted drafts wherever acceptance holds.
        cand = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (R, K+1)
        acc = (draft_tokens == cand[:, :K]) & live
    else:
        key = jax.random.PRNGKey(seed)
        k_u, k_cand = jax.random.split(key)
        tl = lf / temperature
        if K > 0:
            p = jax.nn.softmax(tl[:, :K, :], axis=-1)
            pd = jnp.take_along_axis(
                p, draft_tokens[..., None].astype(jnp.int32), axis=-1)[..., 0]
            u = jax.random.uniform(k_u, (R, K))
            # point-mass draft: accept with prob p_target(d)
            acc = (u < pd) & live
            # residual (p - q)+ ∝ target with the draft token masked out —
            # but only where a draft exists; bonus/plain positions sample
            # the unmodified target.
            dmask = (jax.nn.one_hot(draft_tokens, V, dtype=jnp.bool_)
                     & live[..., None])
            tl = tl.at[:, :K, :].set(
                jnp.where(dmask, NEG_INF, tl[:, :K, :]))
        else:
            acc = jnp.zeros((R, 0), jnp.bool_)
        cand = jax.random.categorical(k_cand, tl, axis=-1).astype(jnp.int32)
    n_accept = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1) \
        if K > 0 else jnp.zeros((R,), jnp.int32)
    if K > 0:
        drafts_pad = jnp.concatenate(
            [draft_tokens.astype(jnp.int32), jnp.zeros((R, 1), jnp.int32)],
            axis=1)
        tokens = jnp.where(idx < n_accept[:, None], drafts_pad, cand)
    else:
        tokens = cand
    return tokens, n_accept.astype(jnp.int32), _scores(logp, tokens)

"""Unified decoder LM over the segment/pattern layout.

One code path serves all 10 assigned architectures: the stack is a tuple of
segments, each segment scans over `repeat` stacked copies of its layer
pattern (see config.layout()).  Shared-attention blocks (zamba2) keep their
parameters OUTSIDE the scan (closure constants) while their KV caches are
scanned — one cache per application.

Public API:
  init_params(key, cfg)                        -> params
  param_axes(cfg)                              -> logical sharding axes (same tree)
  forward(params, inputs, positions, cfg)      -> (logits, aux)       [train/score]
  init_decode_caches(cfg, batch, max_len)      -> caches
  prefill(params, inputs, positions, cfg, max_len) -> (last_logits, caches)
  decode_step(params, caches, inputs, positions, cfg) -> (logits, caches)

`inputs` is token ids (B,S) int32 for input_mode="tokens", or precomputed
frontend embeddings (B,S,d) for "embeds" (audio/vlm stubs).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mamba2 as mamba_mod
from .config import LayerSpec, ModelConfig, Segment
from .layers import (dtype_of, embed_axes, embed_init, embed_lookup, rmsnorm,
                     rmsnorm_axes, rmsnorm_init, softcap, stack_init, unembed)
from .mlp import mlp, mlp_axes, mlp_init
from .moe import moe, moe_axes, moe_init


# ====================================================================== init
def _block_init(key, cfg: ModelConfig, spec: LayerSpec) -> dict:
    dt = dtype_of(cfg)
    d = cfg.d_model
    if spec.kind == "mamba":
        k1, k2 = jax.random.split(key)
        return {"norm": rmsnorm_init(d, dt), "mamba": mamba_mod.mamba_init(k1, cfg)}
    if spec.kind == "shared_attn":
        return {}  # parameters live in params["shared_attn"], not per layer
    k1, k2 = jax.random.split(key)
    p = {
        "norm_attn": rmsnorm_init(d, dt),
        "attn": attn_mod.attn_init(k1, cfg),
        "norm_mlp": rmsnorm_init(d, dt),
    }
    if cfg.post_norm:
        p["post_norm_attn"] = rmsnorm_init(d, dt)
        p["post_norm_mlp"] = rmsnorm_init(d, dt)
    if spec.kind == "attn_moe":
        p["moe"] = moe_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k2, cfg)
    return p


def _block_axes(cfg: ModelConfig, spec: LayerSpec) -> dict:
    if spec.kind == "mamba":
        return {"norm": rmsnorm_axes(), "mamba": mamba_mod.mamba_axes(cfg)}
    if spec.kind == "shared_attn":
        return {}
    p = {
        "norm_attn": rmsnorm_axes(),
        "attn": attn_mod.attn_axes(cfg),
        "norm_mlp": rmsnorm_axes(),
    }
    if cfg.post_norm:
        p["post_norm_attn"] = rmsnorm_axes()
        p["post_norm_mlp"] = rmsnorm_axes()
    if spec.kind == "attn_moe":
        p["moe"] = moe_axes(cfg)
    else:
        p["mlp"] = mlp_axes()
    return p


def _shared_attn_init(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    return {
        "norm_attn": rmsnorm_init(2 * d, dt),
        "attn": attn_mod.attn_init(k1, cfg, d_in=2 * d, d_out=d),
        "norm_mlp": rmsnorm_init(2 * d, dt),
        "mlp": mlp_init(k2, cfg, d_in=2 * d, d_out=d),
    }


def _shared_attn_axes(cfg: ModelConfig) -> dict:
    return {
        "norm_attn": rmsnorm_axes(),
        "attn": attn_mod.attn_axes(cfg),
        "norm_mlp": rmsnorm_axes(),
        "mlp": mlp_axes(),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, len(cfg.layout()) + 3)
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype_of(cfg)),
        "final_norm": rmsnorm_init(cfg.d_model, dtype_of(cfg)),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(keys[1], cfg.vocab_size, cfg.d_model, dtype_of(cfg))
    segments = []
    for si, seg in enumerate(cfg.layout()):
        seg_keys = jax.random.split(keys[2 + si], len(seg.pattern))
        pos_params = []
        for pi, spec in enumerate(seg.pattern):
            init_one = functools.partial(_block_init, cfg=cfg, spec=spec)
            pos_params.append(stack_init(init_one, seg_keys[pi], seg.repeat))
        segments.append(tuple(pos_params))
    params["segments"] = tuple(segments)
    if any(s.kind == "shared_attn" for seg in cfg.layout() for s in seg.pattern):
        params["shared_attn"] = _shared_attn_init(keys[-1], cfg)
    return params


def param_axes(cfg: ModelConfig) -> dict:
    """Logical axes tree mirroring init_params; scan adds a leading 'layers'
    axis to every per-segment leaf."""
    def add_layer_axis(tree):
        return jax.tree.map(lambda a: ("layers",) + tuple(a), tree,
                            is_leaf=lambda x: isinstance(x, tuple) and
                            all(isinstance(e, (str, type(None))) for e in x))

    axes: dict[str, Any] = {
        "embed": embed_axes(),
        "final_norm": rmsnorm_axes(),
    }
    if not cfg.tie_embeddings:
        axes["head"] = embed_axes()
    segments = []
    for seg in cfg.layout():
        pos_axes = []
        for spec in seg.pattern:
            pos_axes.append(add_layer_axis(_block_axes(cfg, spec)))
        segments.append(tuple(pos_axes))
    axes["segments"] = tuple(segments)
    if any(s.kind == "shared_attn" for seg in cfg.layout() for s in seg.pattern):
        axes["shared_attn"] = _shared_attn_axes(cfg)
    return axes


# ==================================================================== blocks
def _barrier(y, cfg: ModelConfig):
    """Keep the TP all-reduce on this (bf16) tensor instead of letting XLA
    fuse the downstream f32 norm-upcast into it (see config.comm_bf16_barrier)."""
    if cfg.comm_bf16_barrier:
        return jax.lax.optimization_barrier(y)
    return y


def _apply_block(block_params, x, positions, *, cfg: ModelConfig,
                 spec: LayerSpec, cache, shared_params, embeds0, mode: str,
                 block_table=None, row_ids=None):
    """One layer. Returns (x, new_cache, aux).

    With ``block_table`` set, ``cache`` is the layer's slice of the paged KV
    pool and attention goes through the paged path (suffix prefill, paged
    decode, or — with ``row_ids`` — the packed ragged mixed step); only
    pure-attention layer kinds support it (see supports_paged).
    """
    aux = jnp.zeros((), jnp.float32)
    if block_table is not None and spec.kind not in ("attn_mlp", "attn_moe"):
        raise NotImplementedError(
            f"paged KV cache does not support layer kind {spec.kind!r}")
    if spec.kind == "mamba":
        h = rmsnorm(block_params["norm"], x)
        y, new_cache = mamba_mod.mamba_block(block_params["mamba"], h, cfg=cfg,
                                             cache=cache)
        return x + _barrier(y, cfg), new_cache, aux

    if spec.kind == "shared_attn":
        p = shared_params
        u = jnp.concatenate([x, embeds0], axis=-1)
        h = rmsnorm(p["norm_attn"], u)
        if mode == "prefill":
            y, new_cache = attn_mod.prefill_cache(
                p["attn"], h, positions, cfg=cfg, spec=spec,
                max_len=cache["pos"].shape[1])
        else:
            y, new_cache = attn_mod.attention(p["attn"], h, positions, cfg=cfg,
                                              spec=spec, cache=cache)
        x = x + _barrier(y, cfg)
        v = jnp.concatenate([x, embeds0], axis=-1)
        x = x + _barrier(mlp(p["mlp"], rmsnorm(p["norm_mlp"], v)), cfg)
        return x, new_cache, aux

    # attn_mlp / attn_moe
    h = rmsnorm(block_params["norm_attn"], x)
    if block_table is not None:
        y, new_cache = attn_mod.paged_attention(
            block_params["attn"], h, positions, cfg=cfg, spec=spec,
            pool=cache, block_table=block_table, row_ids=row_ids)
    elif mode == "prefill":
        y, new_cache = attn_mod.prefill_cache(
            block_params["attn"], h, positions, cfg=cfg, spec=spec,
            max_len=cache["pos"].shape[1])
    else:
        y, new_cache = attn_mod.attention(block_params["attn"], h, positions,
                                          cfg=cfg, spec=spec, cache=cache)
    if cfg.post_norm:
        y = rmsnorm(block_params["post_norm_attn"], y)
    x = x + _barrier(y, cfg)
    h = rmsnorm(block_params["norm_mlp"], x)
    if spec.kind == "attn_moe":
        y, aux = moe(block_params["moe"], h, cfg=cfg)
    else:
        y = mlp(block_params["mlp"], h)
    if cfg.post_norm:
        y = rmsnorm(block_params["post_norm_mlp"], y)
    return x + _barrier(y, cfg), new_cache, aux


def _run_segment(seg_params, x, positions, *, cfg: ModelConfig, seg: Segment,
                 caches, shared_params, embeds0, mode: str, block_table=None,
                 row_ids=None):
    """Scan over the segment's `repeat` axis.

    caches: tuple per pattern position of stacked (R,...) cache trees, or
    None (train/score).  block_table (paged serving) is one (B,nb) mapping
    shared by every layer — each layer owns its own pool slice but the
    logical→physical block mapping is per-request, not per-layer.  row_ids
    (packed ragged step) maps each token of the single packed row to its
    request's block-table row; it too is layer-invariant.
    Returns (x, aux_sum, new_caches|None).
    """
    with_cache = caches is not None

    def body(carry, xs):
        x, aux = carry
        layer_params, layer_caches = xs
        new_caches = []
        for i, spec in enumerate(seg.pattern):
            c_i = layer_caches[i] if with_cache else None
            x, nc, aux_i = _apply_block(layer_params[i], x, positions, cfg=cfg,
                                        spec=spec, cache=c_i,
                                        shared_params=shared_params,
                                        embeds0=embeds0, mode=mode,
                                        block_table=block_table,
                                        row_ids=row_ids)
            aux = aux + aux_i
            new_caches.append(nc if with_cache else jnp.zeros((), jnp.int8))
        return (x, aux), tuple(new_caches)

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body)

    if with_cache:
        xs = (seg_params, caches)
    else:
        dummy = jnp.zeros((seg.repeat,), jnp.int8)
        xs = (seg_params, tuple(dummy for _ in seg.pattern))
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs,
        unroll=seg.repeat if cfg.scan_unroll else 1)
    return x, aux, (new_caches if with_cache else None)


# =================================================================== forward
def _embed_inputs(params, inputs, cfg: ModelConfig):
    if cfg.input_mode == "embeds":
        return inputs.astype(dtype_of(cfg))
    return embed_lookup(params["embed"], inputs, scale=cfg.embed_scale,
                        d=cfg.d_model)


def _head(params, x, cfg: ModelConfig):
    x = rmsnorm(params["final_norm"], x)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = unembed(table, x)
    return softcap(logits, cfg.final_logit_softcap)


def forward(params, inputs, positions, cfg: ModelConfig, *, mode: str = "train"):
    """Full-sequence forward (no caches). Returns (logits, aux)."""
    x = _embed_inputs(params, inputs, cfg)
    embeds0 = x
    aux = jnp.zeros((), jnp.float32)
    for seg, seg_params in zip(cfg.layout(), params["segments"]):
        x, aux_s, _ = _run_segment(seg_params, x, positions, cfg=cfg, seg=seg,
                                   caches=None,
                                   shared_params=params.get("shared_attn"),
                                   embeds0=embeds0, mode=mode)
        aux = aux + aux_s
    return _head(params, x, cfg), aux


# ===================================================================== cache
def _block_cache_init(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int):
    if spec.kind == "mamba":
        return mamba_mod.mamba_cache_init(cfg, batch)
    return attn_mod.init_cache(cfg, spec, batch, max_len)


def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Tuple per segment of tuple per pattern position of stacked caches."""
    caches = []
    for seg in cfg.layout():
        pos_caches = []
        for spec in seg.pattern:
            one = _block_cache_init(cfg, spec, batch, max_len)
            stacked = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (seg.repeat,) + a.shape), one)
            pos_caches.append(stacked)
        caches.append(tuple(pos_caches))
    return tuple(caches)


def cache_axes(cfg: ModelConfig):
    """Logical axes for the cache tree (leading 'layers' from stacking)."""
    def add_layer(tree):
        return jax.tree.map(lambda a: ("layers",) + tuple(a), tree,
                            is_leaf=lambda x: isinstance(x, tuple) and
                            all(isinstance(e, (str, type(None))) for e in x))

    out = []
    for seg in cfg.layout():
        pos = []
        for spec in seg.pattern:
            if spec.kind == "mamba":
                pos.append(add_layer(mamba_mod.mamba_cache_axes()))
            else:
                pos.append(add_layer(attn_mod.cache_axes()))
        out.append(tuple(pos))
    return tuple(out)


def prefill(params, inputs, positions, cfg: ModelConfig, *, max_len: int):
    """Run the prompt, build caches.  Returns (last-token logits, caches)."""
    x = _embed_inputs(params, inputs, cfg)
    embeds0 = x
    caches = init_decode_caches(cfg, x.shape[0], max_len)
    new_caches = []
    for seg, seg_params, seg_caches in zip(cfg.layout(), params["segments"], caches):
        x, _, nc = _run_segment(seg_params, x, positions, cfg=cfg, seg=seg,
                                caches=seg_caches,
                                shared_params=params.get("shared_attn"),
                                embeds0=embeds0, mode="prefill")
        new_caches.append(nc)
    logits = _head(params, x[:, -1:, :], cfg)
    return logits[:, 0, :], tuple(new_caches)


# ===================================================================== paged
def supports_paged(cfg: ModelConfig) -> bool:
    """Paged KV serving needs token inputs (the prefix trie is keyed by
    token blocks) and pure-attention layers (SSM/conv state is O(1) per
    request and carries the whole history — it cannot be block-shared)."""
    return cfg.input_mode == "tokens" and all(
        s.kind in ("attn_mlp", "attn_moe")
        for seg in cfg.layout() for s in seg.pattern)


def supports_speculative(cfg: ModelConfig) -> bool:
    """Speculative (multi-token verify) decode rows need the paged path:
    a k-token row rides the packed ragged dispatch as k+1 fed tokens, and
    rejected-draft K/V is undone by truncating the row's block table
    (serving/kvcache.rollback_writes).  The dense slot cache has no write
    watermark to rewind, and SSM/conv state folds the whole history into
    O(1) per request — it cannot drop the last j tokens at all.  Mirrors
    ``supports_paged``; engines gate on this exactly as they gate paging."""
    return supports_paged(cfg)


def init_paged_pools(cfg: ModelConfig, num_blocks: int, block_size: int,
                     kv_dtype: str | None = None):
    """Global KV block pool, same tree layout as init_decode_caches but with
    (num_blocks, block_size) replacing the (batch, seq) plane.

    ``kv_dtype`` (default ``cfg.kv_dtype``) selects the storage precision;
    quantized pools carry per-(block, slot, kv-head) scale leaves that ride
    the same tree through donation, spill/adopt, and sharding."""
    kv_dtype = cfg.kv_dtype if kv_dtype is None else kv_dtype
    pools = []
    for seg in cfg.layout():
        pos_pools = []
        for spec in seg.pattern:
            one = attn_mod.init_paged_pool(cfg, num_blocks, block_size,
                                           kv_dtype=kv_dtype)
            stacked = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (seg.repeat,) + a.shape), one)
            pos_pools.append(stacked)
        pools.append(tuple(pos_pools))
    return tuple(pools)


def paged_pool_axes(cfg: ModelConfig, kv_dtype: str | None = None):
    """Logical axes tree mirroring ``init_paged_pools`` (the leading
    'layers' axis comes from stacking, exactly as in ``cache_axes``)."""
    def add_layer(tree):
        return jax.tree.map(lambda a: ("layers",) + tuple(a), tree,
                            is_leaf=lambda x: isinstance(x, tuple) and
                            all(isinstance(e, (str, type(None))) for e in x))

    out = []
    for seg in cfg.layout():
        pos = []
        for spec in seg.pattern:
            pos.append(add_layer(attn_mod.paged_pool_axes(cfg,
                                                          kv_dtype=kv_dtype)))
        out.append(tuple(pos))
    return tuple(out)


def paged_prefill(params, pools, block_tables, inputs, positions,
                  cfg: ModelConfig):
    """Prefill a (possibly block-aligned-truncated) prompt suffix against the
    paged pool.  inputs (B,T) are the suffix tokens, positions (B,T) their
    absolute positions (row b starts at its reused prefix length L_b); the
    suffix attends to the reused prefix KV through the block table without
    recomputing it.  Returns (last-token logits, new pools)."""
    x = _embed_inputs(params, inputs, cfg)
    embeds0 = x
    new_pools = []
    for seg, seg_params, seg_pools in zip(cfg.layout(), params["segments"],
                                          pools):
        x, _, np_ = _run_segment(seg_params, x, positions, cfg=cfg, seg=seg,
                                 caches=seg_pools,
                                 shared_params=params.get("shared_attn"),
                                 embeds0=embeds0, mode="prefill",
                                 block_table=block_tables)
        new_pools.append(np_)
    logits = _head(params, x[:, -1:, :], cfg)
    return logits[:, 0, :], tuple(new_pools)


def paged_mixed_step(params, pools, block_tables, tokens, positions, row_ids,
                     sample_idx, cfg: ModelConfig):
    """ONE fixed-shape step over a packed ragged token batch: prefill chunks
    and decode rows share the dispatch (the serving engine's unified
    token-budget tick).

    tokens (T,) int32 packed tokens; positions (T,) absolute positions (-1 =
    pad lane); row_ids (T,) block-table row per token (-1 = pad);
    block_tables (R, nb); sample_idx the packed index each request row
    samples from — its decode token, or the final token of the prefill chunk
    that completed its prompt (rows with no boundary this tick point anywhere
    and their logits are ignored host-side).  With sample_idx (R,) one
    boundary token is gathered per row and logits are (R, V).  Speculative
    decoding passes sample_idx (R, J): row r's J fed tokens (its last
    committed token plus J-1 verified draft tokens, lanes repeated for rows
    with fewer), and logits are (R, J, V) — the target distributions the
    acceptance rule (models.sampling.speculative_verify) consumes, all from
    this same single dispatch.

    Every layer writes ALL packed K/V before attending, so a chunk token
    sees its same-dispatch predecessors AND any same-tick sibling's shared
    prefix blocks; the head runs only on the gathered boundary tokens, not
    the full packed row.  Returns (logits, new pools)."""
    x = _embed_inputs(params, tokens[None], cfg)              # (1, T, d)
    embeds0 = x
    new_pools = []
    for seg, seg_params, seg_pools in zip(cfg.layout(), params["segments"],
                                          pools):
        x, _, np_ = _run_segment(seg_params, x, positions[None], cfg=cfg,
                                 seg=seg, caches=seg_pools,
                                 shared_params=params.get("shared_attn"),
                                 embeds0=embeds0, mode="mixed",
                                 block_table=block_tables, row_ids=row_ids)
        new_pools.append(np_)
    xb = jnp.take(x[0], sample_idx, axis=0)          # (R, d) or (R, J, d)
    if sample_idx.ndim == 1:
        return _head(params, xb[None], cfg)[0], tuple(new_pools)
    return _head(params, xb, cfg), tuple(new_pools)


def paged_decode_step(params, pools, block_tables, inputs, positions,
                      cfg: ModelConfig):
    """One decode step over the paged pool. inputs: (B,) tokens;
    positions (B,1).  Returns (logits (B,V), new pools)."""
    if inputs.ndim == 1:
        inputs = inputs[:, None]
    x = _embed_inputs(params, inputs, cfg)
    embeds0 = x
    new_pools = []
    for seg, seg_params, seg_pools in zip(cfg.layout(), params["segments"],
                                          pools):
        x, _, np_ = _run_segment(seg_params, x, positions, cfg=cfg, seg=seg,
                                 caches=seg_pools,
                                 shared_params=params.get("shared_attn"),
                                 embeds0=embeds0, mode="decode",
                                 block_table=block_tables)
        new_pools.append(np_)
    logits = _head(params, x, cfg)
    return logits[:, 0, :], tuple(new_pools)


def decode_step(params, caches, inputs, positions, cfg: ModelConfig):
    """One decode step. inputs: (B,) tokens or (B,1,d) embeds; positions (B,1).
    Returns (logits (B,V), new caches)."""
    if cfg.input_mode == "tokens" and inputs.ndim == 1:
        inputs = inputs[:, None]
    x = _embed_inputs(params, inputs, cfg)
    embeds0 = x
    new_caches = []
    for seg, seg_params, seg_caches in zip(cfg.layout(), params["segments"], caches):
        x, _, nc = _run_segment(seg_params, x, positions, cfg=cfg, seg=seg,
                                caches=seg_caches,
                                shared_params=params.get("shared_attn"),
                                embeds0=embeds0, mode="decode")
        new_caches.append(nc)
    logits = _head(params, x, cfg)
    return logits[:, 0, :], tuple(new_caches)

"""GQA attention with per-layer window / softcap / qk-norm, plus KV caches.

Three execution backends:
- ``xla``              — chunked (flash-style) pure-JAX path: scan over query
                          chunks so the (S×S) score matrix is never
                          materialized; this is what the dry-run lowers.
- ``pallas``           — the Pallas TPU kernel (kernels/flash_attention).
- ``pallas_interpret`` — same kernel, interpret mode (CPU validation).

Cache layout: ``{"k": (B, S_c, K, D), "v": (B, S_c, K, D), "pos": (B, S_c)}``
where ``pos`` holds the absolute position stored in each slot (-1 = empty).
Local-window layers use a ring buffer (S_c = window); the pos array makes
ring semantics trivial: a slot is visible iff 0 ≤ q_pos - slot_pos < window.
RoPE is applied before caching, so cached keys are already rotated.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import quant as da_quant

from .config import LayerSpec, ModelConfig
from .layers import dense_init, dtype_of, rmsnorm, rmsnorm_axes, rmsnorm_init, rope, softcap

NEG_INF = -1e30


# ------------------------------------------------------------------ params
def attn_init(key, cfg: ModelConfig, *, d_in: int | None = None,
              d_out: int | None = None) -> dict:
    d_in = d_in or cfg.d_model
    d_out = d_out or cfg.d_model
    H, K, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = dtype_of(cfg)
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, (d_in, H, D), dt),
        "wk": dense_init(kk, (d_in, K, D), dt),
        "wv": dense_init(kv, (d_in, K, D), dt),
        "wo": dense_init(ko, (H, D, d_out), dt, in_axis=0),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(D, dt)
        p["k_norm"] = rmsnorm_init(D, dt)
    return p


def attn_axes(cfg: ModelConfig) -> dict:
    p = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("heads", None, "embed"),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_axes()
        p["k_norm"] = rmsnorm_axes()
    return p


# ------------------------------------------------------------- core attend
def _gqa_scores(q: jax.Array, k: jax.Array, scale: float,
                cap: float | None) -> jax.Array:
    """q: (B,T,K,G,D), k: (B,S,K,D) → scores (B,K,G,T,S) in f32."""
    s = jnp.einsum("btkgd,bskd->bkgts", q, k,
                   preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    return s


def _masked_softmax(scores: jax.Array, mask: jax.Array) -> jax.Array:
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - jax.lax.stop_gradient(m))
    z = jnp.sum(e, axis=-1, keepdims=True)
    return e / z


def _attend(q, k, v, q_pos, k_pos, *, window: int | None, cap: float | None,
            scale: float) -> jax.Array:
    """q: (B,T,H,D) vs k/v: (B,S,K,D); positions give causality + window.
    Returns (B,T,H,D)."""
    B, T, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qh = q.reshape(B, T, K, G, D)
    scores = _gqa_scores(qh, k, scale, cap)                       # (B,K,G,T,S)
    mask = (k_pos[:, None, :] <= q_pos[:, :, None]) & (k_pos[:, None, :] >= 0)
    if window is not None:
        mask &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    probs = _masked_softmax(scores, mask[:, None, None, :, :])
    out = jnp.einsum("bkgts,bskd->btkgd", probs.astype(v.dtype), v)
    return out.reshape(B, T, H, D)


def _attend_chunked(q, k, v, q_pos, k_pos, *, window, cap, scale, q_chunk):
    """Scan over query chunks — flash-style memory behavior in pure XLA."""
    B, S, H, D = q.shape
    if S <= q_chunk:
        return _attend(q, k, v, q_pos, k_pos, window=window, cap=cap, scale=scale)
    pad = (-S) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = q.shape[1] // q_chunk
    qs = q.reshape(B, n_chunks, q_chunk, H, D).transpose(1, 0, 2, 3, 4)
    ps = q_pos.reshape(B, n_chunks, q_chunk).transpose(1, 0, 2)

    def body(_, qc):
        qi, pi = qc
        # NOTE: with a static window we could slice k/v around the chunk; we
        # keep full-K per chunk for GSPMD friendliness and mask instead.
        out = _attend(qi, k, v, pi, k_pos, window=window, cap=cap, scale=scale)
        return None, out

    # Flash-attention memory discipline: recompute chunk scores/probs in the
    # backward instead of letting scan stash the (B,H,qc,S) f32 probs for
    # EVERY chunk (which costs ~n_chunks × score-matrix per layer and was the
    # dominant train-step buffer — §Perf llama4 iteration C3).
    body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, None, (qs, ps))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * q_chunk, H, D)
    return out[:, :S]


def _ragged_attend_chunked(q, kd, vd, k_pos, q_pos, rows, *, window, cap,
                           scale, q_chunk):
    """Packed-token attention over per-request densified caches, scanned in
    token chunks so the (chunk, L, K, D) per-token KV gather — not the full
    (T, L, K, D) expansion — is the largest buffer.

    q: (T,H,D) packed tokens; kd/vd: (R,L,K,D) densified per request row;
    k_pos: (R,L) absolute positions (-1 empty); q_pos (T,); rows (T,) request
    row per token, already clamped to [0,R).  Pad lanes (q_pos = -1) mask
    every position and emit garbage that callers ignore."""
    T, H, D = q.shape
    if T <= q_chunk:
        return _attend(q[:, None], kd[rows], vd[rows], q_pos[:, None],
                       k_pos[rows], window=window, cap=cap, scale=scale)[:, 0]
    pad = (-T) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=-1)
        rows = jnp.pad(rows, (0, pad))
    n_chunks = q.shape[0] // q_chunk
    qs = q.reshape(n_chunks, q_chunk, H, D)
    ps = q_pos.reshape(n_chunks, q_chunk)
    rs = rows.reshape(n_chunks, q_chunk)

    def body(_, xs):
        qi, pi, ri = xs
        out = _attend(qi[:, None], kd[ri], vd[ri], pi[:, None], k_pos[ri],
                      window=window, cap=cap, scale=scale)
        return None, out[:, 0]

    _, outs = jax.lax.scan(body, None, (qs, ps, rs))
    return outs.reshape(n_chunks * q_chunk, H, D)[:T]


# ------------------------------------------------------------------- cache
def init_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int) -> dict:
    S_c = min(spec.window, max_len) if spec.window else max_len
    K, D = cfg.n_kv_heads, cfg.head_dim
    dt = dtype_of(cfg)
    return {
        "k": jnp.zeros((batch, S_c, K, D), dtype=dt),
        "v": jnp.zeros((batch, S_c, K, D), dtype=dt),
        "pos": jnp.full((batch, S_c), -1, dtype=jnp.int32),
    }


def cache_axes() -> dict:
    return {"k": ("cache_batch", "cache_seq", "kv_heads", None),
            "v": ("cache_batch", "cache_seq", "kv_heads", None),
            "pos": ("cache_batch", "cache_seq")}


def _cache_write(cache: dict, k_new, v_new, positions) -> dict:
    """Scatter T new entries at slots pos % S_c (ring for local layers)."""
    B, S_c = cache["pos"].shape
    T = positions.shape[1]
    slots = positions % S_c                                  # (B, T)
    bidx = jnp.arange(B)[:, None]
    return {
        "k": cache["k"].at[bidx, slots].set(k_new),
        "v": cache["v"].at[bidx, slots].set(v_new),
        "pos": cache["pos"].at[bidx, slots].set(positions),
    }


# ------------------------------------------------------------------ paging
def init_paged_pool(cfg: ModelConfig, num_blocks: int, block_size: int,
                    kv_dtype: str | None = None) -> dict:
    """One layer's share of the global KV block pool.

    Unlike the dense per-slot cache there is no batch axis and no "pos" leaf:
    blocks are a flat pool shared by every request, and the absolute position
    of slot p in a request's logical block j is implicit (j·bs + p), fixed by
    the request's block table.  Local-window layers use the same full-length
    pool and mask positionally (a paged ring would forbid block sharing).

    ``kv_dtype`` (default ``cfg.kv_dtype``) picks the storage dtype; int8 /
    fp8_e4m3 add ``k_scale``/``v_scale`` leaves — one f32 scale per (block,
    slot, kv-head), quantize-on-write in the block writers below.  Scales
    init to 1 so untouched blocks (incl. the reserved null block) dequantize
    to exact zeros."""
    K, D = cfg.n_kv_heads, cfg.head_dim
    kv_dtype = cfg.kv_dtype if kv_dtype is None else kv_dtype
    dt = da_quant.storage_dtype(kv_dtype, dtype_of(cfg))
    pool = {"k": jnp.zeros((num_blocks, block_size, K, D), dtype=dt),
            "v": jnp.zeros((num_blocks, block_size, K, D), dtype=dt)}
    if da_quant.is_quantized(kv_dtype):
        pool["k_scale"] = jnp.ones((num_blocks, block_size, K), jnp.float32)
        pool["v_scale"] = jnp.ones((num_blocks, block_size, K), jnp.float32)
    return pool


def paged_pool_axes(cfg: ModelConfig, kv_dtype: str | None = None) -> dict:
    """Logical axes for one layer's paged-pool leaves (mirrors
    ``init_paged_pool``): the block and slot dims stay UNSHARDED — block
    tables are host-side and every device must be able to scatter any
    (block, slot) — so ``kv_heads`` is the one shardable dim, the same
    model-axis split the attention weights use.  Scale leaves carry the
    same (block, slot, kv-head) layout minus the head_dim."""
    kv_dtype = cfg.kv_dtype if kv_dtype is None else kv_dtype
    kv = (None, None, "kv_heads", None)
    axes = {"k": kv, "v": kv}
    if da_quant.is_quantized(kv_dtype):
        axes["k_scale"] = (None, None, "kv_heads")
        axes["v_scale"] = (None, None, "kv_heads")
    return axes


def _dequant_pool_leaves(pool: dict):
    """f32 K/V leaves for the XLA densify fallback (identity when the pool
    is unquantized).  The fallback materializes a dequantized pool copy —
    acceptable off-TPU; the Pallas path dequantizes in-register instead."""
    if "k_scale" not in pool:
        return pool["k"], pool["v"]
    return (da_quant.dequantize_kv(pool["k"], pool["k_scale"]),
            da_quant.dequantize_kv(pool["v"], pool["v_scale"]))


def _quantize_for_pool(pool: dict, k_new, v_new):
    """Quantize new K/V entries to the pool's storage dtype (identity for
    unquantized pools).  Per-token-per-head scales mean a written token's
    bytes depend only on that token — rewrites (chunked prefill, rollback,
    migration scatter) never requantize neighbours, which is what keeps
    greedy streams bit-identical across spill/adopt/preempt/resume."""
    if "k_scale" not in pool:
        return k_new, v_new, None, None
    name = "int8" if pool["k"].dtype == jnp.int8 else "fp8_e4m3"
    kq, ks = da_quant.quantize_kv(k_new, name)
    vq, vs = da_quant.quantize_kv(v_new, name)
    return kq, vq, ks, vs


def _paged_write(pool: dict, k_new, v_new, positions, block_table) -> dict:
    """Scatter T new K/V entries into pool blocks via the block table.

    positions: (B,T) absolute; block_table: (B,nb) physical ids, -1 unused.
    Pad entries clamp to block 0 — the allocator's reserved null block — so
    masked rows (inactive decode slots) scribble harmlessly there."""
    bs = pool["k"].shape[1]
    blk = jnp.take_along_axis(block_table, positions // bs, axis=1)
    blk = jnp.maximum(blk, 0)                                # (B, T)
    slot = positions % bs
    k_new, v_new, ks, vs = _quantize_for_pool(pool, k_new, v_new)
    out = {"k": pool["k"].at[blk, slot].set(k_new.astype(pool["k"].dtype)),
           "v": pool["v"].at[blk, slot].set(v_new.astype(pool["v"].dtype))}
    if ks is not None:
        out["k_scale"] = pool["k_scale"].at[blk, slot].set(ks)
        out["v_scale"] = pool["v_scale"].at[blk, slot].set(vs)
    return out


def _ragged_paged_write(pool: dict, k_new, v_new, positions, block_table,
                        row_ids) -> dict:
    """Scatter a PACKED token batch's K/V into pool blocks: token t lands in
    its own request's block, resolved per token through ``row_ids``.

    k_new/v_new: (T,K,D); positions (T,) absolute (-1 = pad); block_table
    (R,nb); row_ids (T,) request row per token (-1 = pad).  Pad lanes clamp
    to block 0 (the reserved null block) and scribble harmlessly there."""
    bs = pool["k"].shape[1]
    rows = jnp.clip(row_ids, 0, block_table.shape[0] - 1)
    posc = jnp.maximum(positions, 0)
    blk = block_table[rows, posc // bs]                      # (T,)
    valid = (row_ids >= 0) & (positions >= 0)
    blk = jnp.where(valid, jnp.maximum(blk, 0), 0)
    slot = jnp.where(valid, posc % bs, 0)
    k_new, v_new, ks, vs = _quantize_for_pool(pool, k_new, v_new)
    out = {"k": pool["k"].at[blk, slot].set(k_new.astype(pool["k"].dtype)),
           "v": pool["v"].at[blk, slot].set(v_new.astype(pool["v"].dtype))}
    if ks is not None:
        out["k_scale"] = pool["k_scale"].at[blk, slot].set(ks)
        out["v_scale"] = pool["v_scale"].at[blk, slot].set(vs)
    return out


# ------------------------------------------------------------------- apply
def _qkv(params: dict, x: jax.Array, positions: jax.Array, *,
         cfg: ModelConfig, spec: LayerSpec):
    """Shared projection + qk-norm + RoPE front end of every attention path."""
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    q = rope(q, positions, spec.rope_theta)
    k = rope(k, positions, spec.rope_theta)
    return q, k, v


def attention(params: dict, x: jax.Array, positions: jax.Array, *,
              cfg: ModelConfig, spec: LayerSpec,
              cache: dict | None = None) -> tuple[jax.Array, dict | None]:
    """Full attention block (projections included).

    Without a cache: causal self-attention over x (train / scoring).
    With a cache: write this step's k/v then attend over the cache
    (decode: T=1; prefill-into-cache: T=S).
    """
    B, T, _ = x.shape
    q, k, v = _qkv(params, x, positions, cfg=cfg, spec=spec)
    scale = cfg.head_dim ** -0.5
    cap = cfg.attn_logit_softcap

    if cache is not None:
        cache = _cache_write(cache, k, v, positions)
        out = _attend(q, cache["k"], cache["v"], positions, cache["pos"],
                      window=spec.window, cap=cap, scale=scale)
    else:
        backend = cfg.attn_backend
        if backend in ("pallas", "pallas_interpret"):
            from repro.kernels.flash_attention import ops as fa_ops
            out = fa_ops.flash_attention(
                q, k, v, positions=positions, window=spec.window,
                softcap=cap, scale=scale,
                interpret=(backend == "pallas_interpret"))
        else:
            out = _attend_chunked(q, k, v, positions, positions,
                                  window=spec.window, cap=cap, scale=scale,
                                  q_chunk=cfg.q_chunk)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, cache


def prefill_cache(params: dict, x: jax.Array, positions: jax.Array, *,
                  cfg: ModelConfig, spec: LayerSpec, max_len: int
                  ) -> tuple[jax.Array, dict]:
    """Run attention over the prompt AND build the layer's decode cache."""
    B, S, _ = x.shape
    cache = init_cache(cfg, spec, B, max_len)
    q, k, v = _qkv(params, x, positions, cfg=cfg, spec=spec)
    out = _attend_chunked(q, k, v, positions, positions,
                          window=spec.window, cap=cfg.attn_logit_softcap,
                          scale=cfg.head_dim ** -0.5, q_chunk=cfg.q_chunk)
    cache = _cache_write(cache, k, v, positions)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, cache


def paged_attention(params: dict, x: jax.Array, positions: jax.Array, *,
                    cfg: ModelConfig, spec: LayerSpec, pool: dict,
                    block_table: jax.Array,
                    row_ids: jax.Array | None = None
                    ) -> tuple[jax.Array, dict]:
    """Attention against the paged KV pool: write x's K/V into this request's
    blocks, then attend over everything the block table maps — which includes
    any prefix blocks shared with other requests.

    Batched mode (``row_ids is None``, x row b ↔ block_table row b):
    - suffix prefill (T = S - reused_len): tokens enter at positions starting
      past the reused prefix and attend to the cached prefix KV for free;
    - decode (T = 1): the Pallas block-gather kernel when cfg.attn_backend is
      pallas/pallas_interpret, else an XLA gather + masked softmax.

    Ragged mode (``row_ids`` given): x is ONE packed row (B = 1) of mixed
    prefill-chunk and decode tokens — the engine's unified token-budget tick.
    Token t belongs to request row ``row_ids[t]`` of the block table (-1 =
    pad lane); all K/V is written first, then every token attends causally at
    its own position, so a chunk token sees its same-dispatch predecessors
    and any same-tick sibling's shared prefix blocks, while pad lanes scribble
    only the null block.
    """
    B, T, _ = x.shape
    K, D = cfg.n_kv_heads, cfg.head_dim
    q, k, v = _qkv(params, x, positions, cfg=cfg, spec=spec)
    scale = D ** -0.5
    cap = cfg.attn_logit_softcap
    backend = cfg.attn_backend
    if row_ids is not None:
        pool = _ragged_paged_write(pool, k[0], v[0], positions[0],
                                   block_table, row_ids)
        if backend in ("pallas", "pallas_interpret"):
            from repro.kernels.decode_attention import ops as da_ops
            out = da_ops.ragged_paged_attention(
                q[0], pool["k"], pool["v"], block_table, row_ids,
                positions[0], k_scale=pool.get("k_scale"),
                v_scale=pool.get("v_scale"), window=spec.window,
                softcap=cap, scale=scale,
                interpret=(backend == "pallas_interpret"))[None]
        else:
            from repro.kernels.decode_attention.ref import densify_pool
            kp, vp = _dequant_pool_leaves(pool)
            kd, vd, kpos = densify_pool(kp, vp, block_table)
            rows = jnp.clip(row_ids, 0, block_table.shape[0] - 1)
            out = _ragged_attend_chunked(
                q[0], kd, vd, kpos, positions[0], rows, window=spec.window,
                cap=cap, scale=scale, q_chunk=cfg.q_chunk)[None]
        y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
        return y, pool
    pool = _paged_write(pool, k, v, positions, block_table)
    if T == 1 and backend in ("pallas", "pallas_interpret"):
        from repro.kernels.decode_attention import ops as da_ops
        out = da_ops.paged_decode_attention(
            q[:, 0], pool["k"], pool["v"], block_table, positions[:, 0],
            k_scale=pool.get("k_scale"), v_scale=pool.get("v_scale"),
            window=spec.window, softcap=cap, scale=scale,
            interpret=(backend == "pallas_interpret"))[:, None]
    else:
        from repro.kernels.decode_attention.ref import densify_pool
        kp, vp = _dequant_pool_leaves(pool)
        kd, vd, kpos = densify_pool(kp, vp, block_table)
        # chunked for suffix prefill (T may approach max_len, and the full
        # (B,K,G,T,nb*bs) f32 score tensor is the dominant buffer exactly as
        # in dense prefill); decode's T=1 short-circuits to plain _attend
        out = _attend_chunked(q, kd, vd, positions, kpos, window=spec.window,
                              cap=cap, scale=scale, q_chunk=cfg.q_chunk)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, pool

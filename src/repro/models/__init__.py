from .config import LayerSpec, ModelConfig, Segment
from .lm import (cache_axes, decode_step, forward, init_decode_caches,
                 init_params, param_axes, prefill)

__all__ = ["LayerSpec", "ModelConfig", "Segment", "cache_axes", "decode_step",
           "forward", "init_decode_caches", "init_params", "param_axes",
           "prefill"]

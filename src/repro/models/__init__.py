from .config import LayerSpec, ModelConfig, Segment
from .lm import (cache_axes, decode_step, forward, init_decode_caches,
                 init_paged_pools, init_params, paged_decode_step,
                 paged_mixed_step, paged_pool_axes, paged_prefill,
                 param_axes, prefill, supports_paged, supports_speculative)
from .sampling import sample_with_scores, speculative_verify

__all__ = ["LayerSpec", "ModelConfig", "Segment", "cache_axes", "decode_step",
           "forward", "init_decode_caches", "init_paged_pools", "init_params",
           "paged_decode_step", "paged_mixed_step", "paged_pool_axes",
           "paged_prefill", "param_axes", "prefill", "sample_with_scores",
           "speculative_verify", "supports_paged", "supports_speculative"]

"""Model configuration + layer layout.

A model is: embedding → a stack of *segments* → final norm → LM head.
Each segment is a repeated *pattern* of layers; the pattern is unrolled in
the scan body and the segment scans over ``repeat`` stacked parameter copies.
This keeps compiled HLO small (one body per segment) while supporting
heterogeneous stacks (gemma's local:global alternation, llama4's
dense:MoE interleave, zamba2's mamba+shared-attention hybrid).

Every per-layer attribute that affects program structure (window size,
softcap, block kind) is **static** within a pattern position, so kernels can
specialize; anything repeated is scanned.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal

BlockKind = Literal["attn_mlp", "attn_moe", "mamba", "shared_attn"]


@dataclass(frozen=True)
class LayerSpec:
    kind: BlockKind = "attn_mlp"
    window: int | None = None          # None = global attention
    rope_theta: float = 10_000.0


@dataclass(frozen=True)
class Segment:
    pattern: tuple[LayerSpec, ...]
    repeat: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeat


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                         # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 → d_model // n_heads
    # --- attention structure ---
    window: int | None = None           # sliding window (None = full attention)
    local_global_pattern: int = 0       # k>0: k local layers then 1 global
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_local: float | None = None  # gemma3: separate theta for local layers
    post_norm: bool = False             # gemma2: post-norms around attn/mlp
    embed_scale: bool = False           # gemma: embeddings × sqrt(d_model)
    tie_embeddings: bool = True
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                   # routed-expert hidden (0 → d_ff)
    moe_every: int = 1                  # MoE layer every k-th layer
    first_layer_dense: bool = False     # deepseek: layer 0 is dense
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_impl: str = "einsum"            # "einsum" (GShard dispatch) | "scatter"
    # Mesh axis the experts are sharded over.  When set, the einsum dispatch
    # pins xe/ye to expert-sharded layouts (tokens all-to-all TO the expert
    # shards) — without it GSPMD may all-gather the expert WEIGHTS instead,
    # which for 400B-class MoE is a ~100GiB/chip explosion (§Perf llama4).
    moe_ep_axis: str | None = None
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0          # shared attn block after every k layers
    # --- frontend ---
    input_mode: str = "tokens"          # tokens | embeds (audio/vlm stubs)
    # --- numerics / impl ---
    optimizer: str = "adamw"            # adamw | adafactor
    dtype: str = "bfloat16"
    attn_backend: str = "xla"           # xla | pallas | pallas_interpret
    # Paged KV pool storage dtype: None = model dtype; "int8"/"fp8_e4m3"
    # add per-(block, slot, kv-head) f32 scale leaves and quantize-on-write
    # (kernels dequantize in-register after the block-table gather).
    kv_dtype: str | None = None         # None | float32 | bfloat16 | int8 | fp8_e4m3
    q_chunk: int = 512                  # query chunking for the xla flash path
    remat: bool = True
    # Pin block outputs with an optimization barrier so GSPMD's TP all-reduce
    # stays in bf16 instead of being fused with the downstream f32 norm
    # upcast (halves activation collective bytes; §Perf deepseek iteration).
    comm_bf16_barrier: bool = False
    max_target_length: int = 4096       # default positions horizon (RoPE tables)
    # roofline calibration: override each layout segment's repeat count
    # (cost_analysis counts while-loop bodies once; the dry-run lowers
    # repeat=1/2 variants and scales the diff by the true trip count).
    layout_repeats: tuple | None = None
    scan_unroll: bool = False           # unroll layer scans (calibration only)
    notes: str = ""

    # ------------------------------------------------------------------ dims
    def __post_init__(self) -> None:
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_experts and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    @property
    def d_inner(self) -> int:           # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # -------------------------------------------------------------- layout
    def layout(self) -> tuple[Segment, ...]:
        """The segment/pattern decomposition of the stack."""
        segs = self._layout_base()
        if self.layout_repeats is not None:
            assert len(self.layout_repeats) == len(segs)
            segs = tuple(Segment(s.pattern, r)
                         for s, r in zip(segs, self.layout_repeats))
        return segs

    def _layout_base(self) -> tuple[Segment, ...]:
        th, thl = self.rope_theta, (self.rope_theta_local or self.rope_theta)
        glob = LayerSpec("attn_mlp", None, th)
        loc = LayerSpec("attn_mlp", self.window, thl)

        if self.family == "ssm":
            return (Segment((LayerSpec("mamba"),), self.n_layers),)

        if self.family == "hybrid":
            k = self.shared_attn_every
            assert k and self.n_layers % k == 0, "hybrid needs n_layers % shared_attn_every == 0"
            pattern = tuple([LayerSpec("mamba")] * k + [LayerSpec("shared_attn", None, th)])
            return (Segment(pattern, self.n_layers // k),)

        if self.n_experts:  # MoE families
            moe = LayerSpec("attn_moe", self.window, th)
            dense = LayerSpec("attn_mlp", self.window, th)
            segs: list[Segment] = []
            n = self.n_layers
            if self.first_layer_dense:
                segs.append(Segment((dense,), 1))
                n -= 1
            if self.moe_every == 1:
                segs.append(Segment((moe,), n))
            else:
                assert n % self.moe_every == 0
                pat = tuple([dense] * (self.moe_every - 1) + [moe])
                segs.append(Segment(pat, n // self.moe_every))
            return tuple(segs)

        # dense transformers
        if self.local_global_pattern:
            k = self.local_global_pattern
            per = k + 1
            full, rem = divmod(self.n_layers, per)
            segs = [Segment(tuple([loc] * k + [glob]), full)]
            if rem:
                segs.append(Segment((loc,), rem))
            return tuple(segs)
        if self.window is not None:
            return (Segment((loc,), self.n_layers),)
        return (Segment((glob,), self.n_layers),)

    # ---------------------------------------------------------- accounting
    def param_count(self) -> int:
        """Exact parameter count from the layout (used for 6·N·D roofline)."""
        d, hd = self.d_model, self.head_dim
        n = 0
        has_shared = False
        for seg in self.layout():
            per_pattern = 0
            for spec in seg.pattern:
                if spec.kind == "mamba":
                    di, ds = self.d_inner, self.ssm_state
                    nh = self.ssm_heads
                    conv_dim = di + 2 * ds
                    per_pattern += d * (2 * di + 2 * ds + nh)       # in_proj
                    per_pattern += conv_dim * (self.conv_width + 1)  # conv w + b
                    per_pattern += 2 * nh + nh                       # A, D, dt_bias
                    per_pattern += di                                # out norm
                    per_pattern += di * d                            # out_proj
                    per_pattern += d                                 # pre-norm
                elif spec.kind == "shared_attn":
                    has_shared = True                  # ONE param set, counted below
                else:
                    per_pattern += d * (self.n_heads * hd)           # q
                    per_pattern += 2 * d * (self.n_kv_heads * hd)    # k, v
                    per_pattern += (self.n_heads * hd) * d           # o
                    per_pattern += (4 * d if self.post_norm else 2 * d)
                    if self.qk_norm:
                        per_pattern += 2 * hd
                    if spec.kind == "attn_moe":
                        e, ff = self.n_experts, self.moe_d_ff
                        per_pattern += d * e                         # router
                        per_pattern += e * 3 * d * ff                # experts
                        if self.n_shared_experts:
                            per_pattern += 3 * d * (self.n_shared_experts * ff)
                    else:
                        per_pattern += 3 * d * self.d_ff
            n += per_pattern * seg.repeat
        if has_shared:
            din = 2 * d
            n += din * (self.n_heads * hd)                   # q
            n += 2 * din * (self.n_kv_heads * hd)            # k, v
            n += (self.n_heads * hd) * d                     # o (to d)
            n += 2 * din * self.d_ff + self.d_ff * d         # gated mlp (out to d)
            n += 2 * din                                     # norms
        n += self.vocab_size * d                                     # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        n += d                                                       # final norm
        return n

    def active_param_count(self) -> int:
        """Per-token active params (MoE: routed top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        e, ff, d = self.n_experts, self.moe_d_ff, self.d_model
        n_moe_layers = sum(
            sum(1 for s in seg.pattern if s.kind == "attn_moe") * seg.repeat
            for seg in self.layout())
        inactive = n_moe_layers * (e - self.top_k) * 3 * d * ff
        return full - inactive

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

"""Shared layer primitives: norms, RoPE, embeddings, initializers.

Functional-params convention: every module is a pair of functions
``init(key, cfg, ...) -> params`` and ``apply(params, x, ...) -> y`` where
``params`` is a nested dict of arrays.  ``axes(...)`` mirrors ``init`` and
returns the logical sharding axes for every leaf (kept adjacent so they
cannot drift; a test asserts structural equality).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------- RMSNorm
def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype=jnp.float32)}


def rmsnorm_axes() -> dict:
    return {"scale": (None,)}


def rmsnorm(params: dict, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    # (1 + scale): gemma-style zero-centered scale; at init this is identity.
    return (y * (1.0 + params["scale"])).astype(dt)


# -------------------------------------------------------------------- RoPE
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None, None].astype(jnp.float32) * freq  # (...,S,1,half)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# -------------------------------------------------------------- embeddings
def embed_init(key, vocab: int, d: int, dtype) -> dict:
    scale = 1.0
    tbl = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * scale
    return {"table": tbl.astype(dtype)}


def embed_axes() -> dict:
    return {"table": ("vocab", "embed")}


def embed_lookup(params: dict, tokens: jax.Array, *, scale: bool, d: int) -> jax.Array:
    x = params["table"][tokens]
    if scale:
        x = x * jnp.asarray(np.sqrt(d), dtype=x.dtype)
    return x


def unembed(params: dict, x: jax.Array) -> jax.Array:
    """Tied head: logits = x @ table.T (f32 accumulation)."""
    return jnp.einsum("...d,vd->...v", x, params["table"],
                      preferred_element_type=jnp.float32)


def softcap(logits: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return logits
    return (cap * jnp.tanh(logits / cap)).astype(logits.dtype)


# ------------------------------------------------------------ initializers
def dense_init(key, shape: tuple[int, ...], dtype, *, in_axis: int = 0) -> jax.Array:
    fan_in = shape[in_axis]
    w = jax.random.normal(key, shape, dtype=jnp.float32) / np.sqrt(fan_in)
    return w.astype(dtype)


def stack_init(init_fn, key, repeat: int):
    """Initialize ``repeat`` stacked copies of a layer (for scan)."""
    keys = jax.random.split(key, repeat)
    return jax.vmap(init_fn)(keys)

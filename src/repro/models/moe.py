"""Mixture-of-Experts FFN: top-k routing with two dispatch implementations.

- ``einsum``  — GShard/T5X-style dense dispatch/combine one-hot einsums with
  per-group capacity.  This is the well-understood baseline; its dispatch
  einsums burn real MXU FLOPs proportional to E·C per token.
- ``scatter`` — permutation-based dispatch: tokens are scattered into per-
  expert capacity buffers (`.at[].add` with mode="drop") and gathered back.
  Near-zero dispatch FLOPs; this is the beyond-baseline §Perf variant.

Both produce identical outputs for the same routing decisions (tested), and
both respect per-expert capacity  C = ceil(tokens·k / E) · capacity_factor
with dropped tokens passing through on the residual stream (standard
capacity semantics).  Shared experts (DeepSeek) are a dense gated MLP.
Router aux loss is the Switch load-balancing loss  E · Σ_e f_e · P_e.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, dtype_of
from .mlp import mlp, mlp_axes, mlp_init


# ------------------------------------------------------------------ params
def moe_init(key, cfg: ModelConfig) -> dict:
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    dt = dtype_of(cfg)
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(kr, (d, E), jnp.float32),
        "w_gate": dense_init(kg, (E, d, ff), dt),
        "w_up": dense_init(ku, (E, d, ff), dt),
        "w_down": dense_init(kd, (E, ff, d), dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks, cfg, d_ff=cfg.n_shared_experts * ff)
    return p


def moe_axes(cfg: ModelConfig) -> dict:
    p = {
        "router": ("embed", None),
        "w_gate": ("expert", "embed", "ffn"),
        "w_up": ("expert", "embed", "ffn"),
        "w_down": ("expert", "ffn", "embed"),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_axes()
    return p


# ----------------------------------------------------------------- routing
def _route(params, x_flat: jax.Array, cfg: ModelConfig):
    """x_flat: (N, d) → (weights (N,k), idx (N,k), aux_loss)."""
    logits = jnp.einsum("nd,de->ne", x_flat.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.top_k > 1:
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch aux loss: fraction routed vs mean prob, per expert.
    E = cfg.n_experts
    f = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    P = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * P)
    return weights, idx, aux


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(4, c)


# --------------------------------------------------------- expert compute
def _expert_ffn(params, xe: jax.Array) -> jax.Array:
    """xe: (E, C, d) → (E, C, d), gated SiLU per expert."""
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["w_down"])


# ------------------------------------------------------------ impl: einsum
def _moe_einsum(params, x_flat, cfg: ModelConfig):
    N, d = x_flat.shape
    E, k = cfg.n_experts, cfg.top_k
    G = max(1, N // max(1, cfg_group_size(cfg)))
    T = N // G
    xg = x_flat[: G * T].reshape(G, T, d)
    weights, idx, aux = _route(params, x_flat[: G * T], cfg)
    weights = weights.reshape(G, T, k)
    idx = idx.reshape(G, T, k)
    C = _capacity(T, cfg)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)            # (G,T,k,E)
    flat = onehot.reshape(G, T * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                         # (G,T*k,E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(G, T, k).astype(jnp.int32)
    keep = (pos < C).astype(jnp.float32)
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32)            # (G,T,k,C)
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot * keep[..., None], pos_oh)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec",
                         onehot * keep[..., None], pos_oh, weights)

    ep = cfg.moe_ep_axis

    def _pin(t, spec):
        if ep is None:
            return t
        return jax.lax.with_sharding_constraint(
            t, jax.sharding.PartitionSpec(*spec))

    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x_flat.dtype), xg)
    # Two-phase dispatch: compute xe with groups LOCAL (no token gather),
    # then reshard group-sharded → expert-sharded, which GSPMD lowers to an
    # all-to-all of the dispatched tokens (~capacity_factor × token bytes).
    # Without the double pin GSPMD may instead all-gather the tokens — or
    # worse, the expert WEIGHTS (§Perf llama4).
    xe = _pin(xe, (ep, None, None, None))
    xe = _pin(xe, (None, ep, None, None))
    ye = jax.vmap(lambda xg_: _expert_ffn(params, xg_))(xe)       # (G,E,C,d)
    ye = _pin(ye, (None, ep, None, None))
    ye = _pin(ye, (ep, None, None, None))     # all-to-all back to groups
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(ye.dtype), ye)
    y = y.reshape(G * T, d)
    if G * T < N:  # ragged tail passes through (residual handles it)
        y = jnp.concatenate([y, jnp.zeros((N - G * T, d), y.dtype)], axis=0)
    return y, aux


def cfg_group_size(cfg: ModelConfig) -> int:
    return getattr(cfg, "moe_group_size", 512) or 512


# ----------------------------------------------------------- impl: scatter
def _moe_scatter(params, x_flat, cfg: ModelConfig):
    N, d = x_flat.shape
    E, k = cfg.n_experts, cfg.top_k
    weights, idx, aux = _route(params, x_flat, cfg)
    C = _capacity(N, cfg)

    flat_e = idx.reshape(-1)                                      # (N*k,)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)               # (N*k,E)
    pos = (jnp.cumsum(oh, axis=0) - oh)                           # rank per expert
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0] # (N*k,)
    tok = jnp.repeat(jnp.arange(N), k)

    # Scatter into capacity buffers; over-capacity entries are dropped by
    # the out-of-bounds scatter mode (no branch, no sort).
    safe_pos = jnp.where(pos < C, pos, C + 1)                     # OOB → drop
    buf = jnp.zeros((E, C, d), x_flat.dtype)
    buf = buf.at[flat_e, safe_pos].set(x_flat[tok], mode="drop")

    ye = _expert_ffn(params, buf)                                 # (E,C,d)

    gathered = ye.at[flat_e, safe_pos].get(mode="fill", fill_value=0)  # (N*k,d)
    w = weights.reshape(-1)[:, None].astype(gathered.dtype)
    y = jnp.sum((gathered * w).reshape(N, k, d), axis=1)
    return y, aux


# ------------------------------------------------------------------- apply
def moe(params: dict, x: jax.Array, *, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, T, d) → (y, aux_loss)."""
    B, T, d = x.shape
    x_flat = x.reshape(B * T, d)
    if cfg.moe_impl == "scatter":
        y, aux = _moe_scatter(params, x_flat, cfg)
    else:
        y, aux = _moe_einsum(params, x_flat, cfg)
    y = y.reshape(B, T, d)
    if cfg.n_shared_experts:
        y = y + mlp(params["shared"], x)
    return y, aux

"""Mamba-2 block: SSD (state-space duality) with chunked scan [arXiv:2405.21060].

Layout conventions:
  d_inner = expand · d_model;  heads H = d_inner / head_dim P;  state N.
  in_proj emits [z (d_inner) | x (d_inner) | B (N) | C (N) | dt (H)];
  (x|B|C) pass through a causal depthwise conv (width W) + SiLU;
  SSD recurrence  h_t = exp(dt·A)·h_{t-1} + dt·B_t ⊗ x_t,   y_t = C_t·h_t + D·x_t;
  output: rmsnorm(y · silu(z)) → out_proj.  (n_groups = 1: B/C shared by heads.)

The chunked scan computes, per chunk of Q steps, the intra-chunk quadratic
part and a per-chunk state, then runs a tiny sequential scan over chunk
states — O(S·Q) work instead of O(S²), MXU-friendly.  The same math has a
Pallas kernel in kernels/ssd; this file is the pure-JAX reference/XLA path.

Decode carries {"conv": (B, W-1, conv_dim), "ssm": (B, H, P, N)} — O(1) state,
which is why SSM archs run the 500k-context cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, dtype_of, rmsnorm, rmsnorm_axes, rmsnorm_init


# ------------------------------------------------------------------ params
def mamba_init(key, cfg: ModelConfig) -> dict:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    W = cfg.conv_width
    conv_dim = di + 2 * N
    dt = dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(k1, (d, 2 * di + 2 * N + H), dt),
        "conv_w": dense_init(k2, (W, conv_dim), dt),
        "conv_b": jnp.zeros((conv_dim,), dtype=dt),
        "A_log": jnp.zeros((H,), dtype=jnp.float32),      # A = -exp(A_log) = -1
        "D": jnp.ones((H,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((H,), dtype=jnp.float32),
        "out_norm": rmsnorm_init(di, dt),
        "out_proj": dense_init(k4, (di, d), dt),
    }


def mamba_axes(cfg: ModelConfig) -> dict:
    return {
        "in_proj": ("embed", "ffn"),
        "conv_w": (None, "ffn"),
        "conv_b": ("ffn",),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "out_norm": rmsnorm_axes(),
        "out_proj": ("ffn", "embed"),
    }


def mamba_cache_init(cfg: ModelConfig, batch: int) -> dict:
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    conv_dim = di + 2 * N
    dt = dtype_of(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype=dt),
        "ssm": jnp.zeros((batch, H, P, N), dtype=jnp.float32),
    }


def mamba_cache_axes() -> dict:
    return {"conv": ("cache_batch", None, "ffn"),
            "ssm": ("cache_batch", "heads", None, None)}


# ------------------------------------------------------------------- split
def _split_proj(cfg: ModelConfig, proj: jax.Array):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di : 2 * di + 2 * N]
    dt = proj[..., 2 * di + 2 * N :]
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """(B,S,C) depthwise causal conv, width W."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xBC.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


# --------------------------------------------------------------- SSD scan
def ssd_chunked(x, dt, A, B_, C_, *, chunk: int, h0: jax.Array | None = None):
    """SSD over a full sequence.

    x: (B,S,H,P)  dt: (B,S,H) (already softplus'd)  A: (H,) negative
    B_, C_: (B,S,N).  Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    NC = Sp // Q
    xc = x.reshape(Bb, NC, Q, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bb, NC, Q, H).astype(jnp.float32)
    Bc = B_.reshape(Bb, NC, Q, N).astype(jnp.float32)
    Cc = C_.reshape(Bb, NC, Q, N).astype(jnp.float32)

    a = dtc * A                                           # (B,NC,Q,H) log-decay
    cum_a = jnp.cumsum(a, axis=2)
    dtx = dtc[..., None] * xc                             # (B,NC,Q,H,P)

    # intra-chunk (quadratic within chunk)
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)            # (B,NC,Q,Q)
    rel = cum_a[:, :, :, None, :] - cum_a[:, :, None, :, :]   # (B,NC,Q,Q,H) i,j
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    decay = jnp.where(causal, jnp.exp(rel), 0.0)
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", CB, decay, dtx)

    # per-chunk states
    seg = jnp.exp(cum_a[:, :, -1:, :] - cum_a)            # decay from j to end
    S_chunk = jnp.einsum("bckn,bckh,bckhp->bchpn", Bc, seg, dtx)

    # inter-chunk sequential scan (NC steps)
    chunk_decay = jnp.exp(cum_a[:, :, -1, :])             # (B,NC,H)
    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), dtype=jnp.float32)

    def step(h, inp):
        dec, s_new = inp                                  # (B,H), (B,H,P,N)
        h_prev = h
        h = h * dec[:, :, None, None] + s_new
        return h, h_prev

    hs_in = (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S_chunk, 1, 0))
    h_final, h_prevs = jax.lax.scan(step, h0, hs_in)
    prev_states = jnp.moveaxis(h_prevs, 0, 1)             # (B,NC,H,P,N)

    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, prev_states,
                         jnp.exp(cum_a))
    y = (y_intra + y_inter).reshape(Bb, Sp, H, P)[:, :S]
    return y, h_final


def ssd_decode_step(h, x, dt, A, B_, C_):
    """One token. h: (B,H,P,N); x: (B,H,P); dt: (B,H); B_,C_: (B,N)."""
    dec = jnp.exp(dt * A)                                 # (B,H)
    dtx = (dt[..., None] * x).astype(jnp.float32)         # (B,H,P)
    h = h * dec[:, :, None, None] + dtx[..., None] * B_[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, C_.astype(jnp.float32))
    return y, h


# ------------------------------------------------------------------- block
def mamba_block(params: dict, x: jax.Array, *, cfg: ModelConfig,
                cache: dict | None = None) -> tuple[jax.Array, dict | None]:
    """x: (B,S,d).  cache=None → full-sequence; cache → single-step decode
    (S must be 1) or prefill-with-state-capture (S>1)."""
    Bb, S, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    A = -jnp.exp(params["A_log"])
    proj = jnp.einsum("bsd,dk->bsk", x, params["in_proj"])
    z, xBC, dt_raw = _split_proj(cfg, proj)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])

    if cache is not None and S == 1:
        # decode: conv via ring window
        win = jnp.concatenate([cache["conv"], xBC], axis=1)       # (B,W,conv)
        conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", win, params["conv_w"])
                               + params["conv_b"])[:, None, :]
        new_conv = win[:, 1:, :]
        xs = conv_out[..., :di].reshape(Bb, H, P)
        B_ = conv_out[:, 0, di : di + N]
        C_ = conv_out[:, 0, di + N :]
        y, h = ssd_decode_step(cache["ssm"], xs, dt[:, 0], A, B_, C_)
        y = y + params["D"][None, :, None] * xs
        y = y.reshape(Bb, 1, di).astype(x.dtype)
        new_cache = {"conv": new_conv, "ssm": h}
    else:
        conv_out = _causal_conv(xBC, params["conv_w"], params["conv_b"])
        xs = conv_out[..., :di].reshape(Bb, S, H, P)
        B_ = conv_out[..., di : di + N]
        C_ = conv_out[..., di + N :]
        h0 = cache["ssm"] if cache is not None else None
        y, h_final = ssd_chunked(xs, dt, A, B_, C_, chunk=cfg.ssm_chunk, h0=h0)
        y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(Bb, S, di).astype(x.dtype)
        new_cache = None
        if cache is not None:
            new_cache = {"conv": xBC[:, -(cfg.conv_width - 1):, :], "ssm": h_final}

    y = y * jax.nn.silu(z)
    y = rmsnorm(params["out_norm"], y)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    return out, new_cache

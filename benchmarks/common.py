"""Shared benchmark helpers."""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass


def now_us() -> float:
    return time.monotonic_ns() / 1e3


@dataclass
class LatencyStats:
    name: str
    samples_us: list

    def row(self) -> str:
        s = sorted(self.samples_us)
        n = len(s)
        p = lambda q: s[min(n - 1, int(q * n))]
        return (f"{self.name},{statistics.median(s):.1f},"
                f"p10={p(0.10):.1f} p90={p(0.90):.1f} p99={p(0.99):.1f} "
                f"mean={statistics.mean(s):.1f} n={n}")


def measure(name: str, fn, *, n: int = 200, warmup: int = 20) -> LatencyStats:
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(n):
        t0 = now_us()
        fn()
        samples.append(now_us() - t0)
    return LatencyStats(name, samples)


def payload(nbytes: int) -> bytes:
    return b"x" * nbytes


SIZES = {"10KB": 10_240, "1MB": 1_048_576}

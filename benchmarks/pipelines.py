"""Paper §5.2 (Fig 10, smart farming) and §5.3 (Fig 11, collision detection)
as real two-/three-stage ML pipelines over tiny JAX models, plus the
multi-replica LM serving cluster on the same fast path.

Claims: model compute dominates e2e latency (data movement is a small
fraction); throughput scales with per-stage shard sizes (1,1)<(1,2)<(2,3);
platform overhead is low and consistent across workload sizes; the serving
cluster's decode tick does exactly one device→host transfer regardless of
batch occupancy.
"""
from __future__ import annotations

import statistics
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BrokerPipeline, CascadeService, DFG, Persistence,
                        Vertex)

from .common import now_us


def _tiny_models():
    """filter (binary) + bcs (scorer) conv-ish models, jitted."""
    key = jax.random.PRNGKey(0)
    w1 = jax.random.normal(key, (768, 64)) / 28.0
    w2 = jax.random.normal(key, (64, 2)) / 8.0
    w3 = jax.random.normal(key, (768, 128)) / 28.0
    w4 = jax.random.normal(key, (128, 5)) / 12.0

    @jax.jit
    def filter_model(x):
        h = jnp.maximum(x.reshape(-1, 768) @ w1, 0)
        return jnp.argmax(h @ w2, axis=-1)

    @jax.jit
    def bcs_model(x):
        h = jnp.maximum(x.reshape(-1, 768) @ w3, 0)
        return jnp.argmax(h @ w4, axis=-1)

    x = np.random.randn(16, 768).astype(np.float32)
    filter_model(x).block_until_ready()
    bcs_model(x).block_until_ready()
    return filter_model, bcs_model


def bench_farming(out) -> dict:
    """Fig 10: filter→bcs→store on Cascade vs broker; shard-size scaling."""
    filter_model, bcs_model = _tiny_models()
    frame = np.random.randn(16, 768).astype(np.float32)  # "photo" tensor
    results = {}

    def build(svc, frontend_workers, compute_workers):
        dfg = DFG(name="sf")
        dfg.add_vertex(Vertex("filter", "/sf/detect_animal",
                              shard_workers=tuple(frontend_workers)))
        dfg.add_vertex(Vertex("bcs", "/sf/assess_bcs",
                              shard_workers=tuple(compute_workers)))
        dfg.add_vertex(Vertex("store", "/sf/save_image",
                              persistence=Persistence.VOLATILE, replication=2))
        dfg.add_edge("filter", "bcs")
        dfg.add_edge("bcs", "store")
        done = {"evt": None, "stamps": {}}

        def lam_filter(ctx, obj):
            done["stamps"]["filter_start"] = now_us()
            keep = int(filter_model(obj.payload)[0]) >= 0  # always true; real compute
            done["stamps"]["filter_end"] = now_us()
            if keep:
                ctx.emit(obj.key.rsplit("/", 1)[-1], obj.payload, trigger=True)

        def lam_bcs(ctx, obj):
            done["stamps"]["bcs_start"] = now_us()
            score = np.asarray(bcs_model(obj.payload))
            done["stamps"]["bcs_end"] = now_us()
            ctx.emit(obj.key.rsplit("/", 1)[-1], score)
            done["evt"].set()

        svc.deploy(dfg, {"filter": lam_filter, "bcs": lam_bcs})
        return done

    # latency breakdown at light load (Fig 10a)
    with tempfile.TemporaryDirectory() as d:
        svc = CascadeService(n_workers=6, log_dir=d)
        done = build(svc, [0], [1])
        lat, fwd_frac = [], []
        for i in range(40):
            done["evt"] = threading.Event()
            t0 = now_us()
            svc.trigger_put("/sf/detect_animal/f", frame)
            assert done["evt"].wait(10)
            e2e = now_us() - t0
            st = done["stamps"]
            compute = (st["filter_end"] - st["filter_start"]) + \
                      (st["bcs_end"] - st["bcs_start"])
            lat.append(e2e)
            fwd_frac.append(max(0.0, e2e - compute) / e2e)
        med = statistics.median(lat)
        frac = statistics.median(fwd_frac)
        out(f"fig10a/cascade_e2e,{med:.1f},forwarding_frac={frac:.2f}")
        results["forward_frac"] = frac
        svc.close()

    # broker comparison with the identical lambdas (Fig 10a yellow bars).
    # Reported, not asserted: on a 1-core host the comparison measures GIL
    # scheduling, not the data path (see EXPERIMENTS.md §Paper-claims).
    bp = BrokerPipeline([
        lambda x: (filter_model(x).block_until_ready(), x)[1],
        lambda x: np.asarray(bcs_model(x)),
    ])
    lat_b = []
    for i in range(40):
        _, us = bp.roundtrip(frame)
        lat_b.append(us)
    bp.stop()
    med_b = statistics.median(lat_b)
    out(f"fig10a/broker_e2e,{med_b:.1f},vs_cascade={med_b/med:.2f}x")
    results["broker_ratio"] = med_b / med
    # paper claim that CAN be tested host-scale: data forwarding is a minor
    # share of e2e latency (paper: ~17%)
    assert results["forward_frac"] < 0.5, "forwarding dominates e2e"
    out("fig10a/CLAIM compute-dominates,PASS,ordinal")

    # throughput scaling over (frontend, compute) shard sizes (Fig 10b).
    # Completion counted with a latch; fps reported (1-core host cannot show
    # parallel speedup — the paper's 4-40 core servers can).
    for conf in ((1, 1), (1, 2), (2, 2), (2, 3)):
        fw = list(range(conf[0]))
        cw = list(range(conf[0], conf[0] + conf[1]))
        with tempfile.TemporaryDirectory() as d:
            svc = CascadeService(n_workers=6, log_dir=d)
            done = build(svc, fw, cw)
            n = 120
            latch = threading.Semaphore(0)
            done["evt"] = type("E", (), {"set": lambda self=None: latch.release(),
                                         "wait": lambda *a, **k: True})()
            t0 = time.monotonic()
            for i in range(n):
                svc.trigger_put(f"/sf/detect_animal/f{i}", frame)
            for i in range(n):
                assert latch.acquire(timeout=30), "pipeline stalled"
            dt = time.monotonic() - t0
            fps = n / dt
            out(f"fig10b/cascade_fps_{conf[0]}_{conf[1]},{dt/n*1e6:.1f},fps={fps:.0f}")
            results[f"fps_{conf}"] = fps
            svc.close()
    return results


def bench_serve_cluster(out) -> dict:
    """Multi-replica LM serving through the Cascade store/dispatcher:
    TTFT / TPOT p50/p99 per replica count.

    Claims: requests flow as trigger_puts through the fast path (nothing
    stored, references only); the unified tick performs EXACTLY one
    device→host transfer no matter how many decode rows and prefill chunks
    it packs (``host_syncs == ticks``, asserted);
    absolute latencies are host-scale (single process, ONE CPU device backing
    every "replica", so added replicas add dispatch overhead without adding
    hardware — the paper's 4-40 core servers can scale, this host cannot),
    so replica scaling is reported, not asserted.
    """
    from repro.core.pools import DispatchPolicy
    from repro.models import init_params
    from repro.models.config import ModelConfig
    from repro.serving.cluster import ServeCluster
    from repro.serving.engine import EngineStats

    cfg = ModelConfig(name="bench", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype="float32", q_chunk=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    lengths = (4, 8)
    results = {}
    for n_replicas in (1, 2):
        cluster = ServeCluster(cfg, params, n_replicas=n_replicas, n_slots=4,
                               max_len=64, policy=DispatchPolicy.ROUND_ROBIN)
        # Warm the ONE fixed-shape mixed-step program (shared across
        # replicas), then reset stats so the compile stays out of the tails.
        for L in lengths:
            cluster.submit("warm", f"w{L}",
                           (np.arange(L) % cfg.vocab_size).astype(np.int32),
                           max_new_tokens=2)
        cluster.run_until_drained()
        for eng in cluster.engines:
            eng.stats = EngineStats()

        n = 32
        t0 = time.monotonic()
        for i in range(n):
            prompt = rng.integers(0, cfg.vocab_size,
                                  (lengths[i % len(lengths)],)).astype(np.int32)
            cluster.submit(f"sess-{i % 8}", f"r{i}", prompt, max_new_tokens=8)
        cluster.run_until_drained()
        dt = time.monotonic() - t0
        st = cluster.stats()
        assert st["requests"] == n
        # the fast-path invariant this benchmark exists to witness:
        assert st["host_syncs"] == st["ticks"], \
            "a unified tick made more than one device→host transfer"
        tput = st["tokens_out"] / dt
        out(f"serve_cluster/replicas{n_replicas},{st['ttft_p50_s']*1e6:.1f},"
            f"ttft_p99_us={st['ttft_p99_s']*1e6:.1f} "
            f"tpot_p50_us={st['tpot_p50_s']*1e6:.1f} "
            f"tpot_p99_us={st['tpot_p99_s']*1e6:.1f} "
            f"tok_per_s={tput:.0f}")
        results[f"replicas_{n_replicas}"] = {
            "ttft_p50_us": st["ttft_p50_s"] * 1e6,
            "ttft_p99_us": st["ttft_p99_s"] * 1e6,
            "tpot_p50_us": st["tpot_p50_s"] * 1e6,
            "tpot_p99_us": st["tpot_p99_s"] * 1e6,
            "tok_per_s": tput,
        }
        cluster.close()
    out("serve_cluster/CLAIM one-sync-per-unified-tick,PASS,exact")
    return results


def bench_collision(out) -> dict:
    """Fig 11: mot→ynet→detect; per-frame latency breakdown by #agents."""
    key = jax.random.PRNGKey(1)
    w_mot = jax.random.normal(key, (512, 64)) / 23.0
    w_ynet = jax.random.normal(key, (16, 48)) / 4.0   # 8 past points (x,y) → 24 future

    @jax.jit
    def mot(frame):           # frame → agent tracks
        h = jnp.tanh(frame.reshape(-1, 512) @ w_mot)
        return h

    @jax.jit
    def ynet(tracks):         # per-agent trajectory prediction
        return jnp.tanh(tracks.reshape(-1, 16) @ w_ynet)

    def detect(preds):        # linear interpolation + crossing check (numpy)
        p = np.asarray(preds).reshape(-1, 24, 2)
        n = p.shape[0]
        hits = 0
        for i in range(n):
            for j in range(i + 1, n):
                d = np.linalg.norm(p[i] - p[j], axis=-1)
                hits += int((d < 0.05).any())
        return hits

    mot(np.random.randn(1, 512).astype(np.float32)).block_until_ready()
    ynet(np.random.randn(4, 16).astype(np.float32)).block_until_ready()

    results = {}
    with tempfile.TemporaryDirectory() as d:
        svc = CascadeService(n_workers=6, log_dir=d)
        dfg = DFG(name="rcd")
        dfg.add_vertex(Vertex("mot", "/rcd/frames", shard_workers=(0, 1)))
        dfg.add_vertex(Vertex("ynet", "/rcd/tracks", shard_workers=(2, 3)))
        dfg.add_vertex(Vertex("detect", "/rcd/preds", shard_workers=(4,)))
        dfg.add_vertex(Vertex("store", "/rcd/out", replication=1))
        dfg.add_edge("mot", "ynet")
        dfg.add_edge("ynet", "detect")
        dfg.add_edge("detect", "store")
        done = {"evt": None, "stamps": {}}

        def lam_mot(ctx, obj):
            done["stamps"]["mot_s"] = now_us()
            tracks = np.asarray(mot(obj.payload["frame"]))
            n_agents = obj.payload["agents"]
            done["stamps"]["mot_e"] = now_us()
            ctx.emit(obj.key.rsplit("/", 1)[-1],
                     np.random.randn(n_agents, 16).astype(np.float32),
                     trigger=True)

        def lam_ynet(ctx, obj):
            done["stamps"]["ynet_s"] = now_us()
            preds = np.asarray(ynet(obj.payload))
            done["stamps"]["ynet_e"] = now_us()
            ctx.emit(obj.key.rsplit("/", 1)[-1], preds, trigger=True)

        def lam_detect(ctx, obj):
            done["stamps"]["det_s"] = now_us()
            hits = detect(obj.payload)
            done["stamps"]["det_e"] = now_us()
            ctx.emit(obj.key.rsplit("/", 1)[-1], np.int64(hits))
            done["evt"].set()

        svc.deploy(dfg, {"mot": lam_mot, "ynet": lam_ynet, "detect": lam_detect})
        frame = np.random.randn(1, 512).astype(np.float32)
        for agents in (5, 10, 15):
            lat, overhead = [], []
            for i in range(25):
                done["evt"] = threading.Event()
                t0 = now_us()
                svc.trigger_put(f"/rcd/frames/f{i}",
                                {"frame": frame, "agents": agents})
                assert done["evt"].wait(10)
                e2e = now_us() - t0
                st = done["stamps"]
                compute = (st["mot_e"] - st["mot_s"]) + (st["ynet_e"] - st["ynet_s"]) \
                    + (st["det_e"] - st["det_s"])
                lat.append(e2e)
                overhead.append(max(0.0, e2e - compute))
            med = statistics.median(lat)
            ovh = statistics.median(overhead)
            out(f"fig11/agents{agents},{med:.1f},platform_overhead_us={ovh:.1f}")
            results[f"overhead_{agents}"] = ovh
        svc.close()
    # claim: platform overhead consistent (doesn't scale with workload)
    assert results["overhead_15"] < results["overhead_5"] * 5 + 2000
    out("fig11/CLAIM overhead-consistent,PASS,ordinal")
    return results

"""Paper Table 1 + Fig 3 + Fig 4/9: K/V store latency, throughput, saturation.

Absolute numbers are host-Python-scale, not RDMA-scale; the paper's CLAIMS
under test are ordinal: trig ≪ vola ≪ pers put latency; timed get ≈ vola put
and staleness-insensitive; small-object throughput flat in shard size; trig
throughput scales best; latency flat vs offered rate until saturation.
"""
from __future__ import annotations

import statistics
import tempfile
import time

from repro.core import CascadeService, DispatchPolicy, Persistence, PoolSpec

from .common import SIZES, LatencyStats, measure, now_us, payload


def bench_kv_latency(out) -> dict:
    """Table 1: put latency by persistence level + time-indexed gets."""
    results = {}
    with tempfile.TemporaryDirectory() as d:
        svc = CascadeService(n_workers=3, log_dir=d)
        svc.store.create_pool(PoolSpec(path="/trig", persistence=Persistence.TRANSIENT))
        svc.store.create_pool(PoolSpec(path="/vola", replication=3))
        svc.store.create_pool(PoolSpec(path="/pers", replication=3,
                                       persistence=Persistence.PERSISTENT))
        for size_name, nbytes in SIZES.items():
            data = payload(nbytes)
            n = 150 if nbytes < 100_000 else 40
            for pool in ("trig", "vola", "pers"):
                if pool == "trig":
                    fn = lambda: svc.trigger_put(f"/trig/k", data)
                else:
                    fn = lambda p=pool: svc.put(f"/{p}/k", data)
                st = measure(f"table1/put_{pool}_{size_name}", fn, n=n, warmup=5)
                out(st.row())
                results[f"put_{pool}_{size_name}"] = statistics.median(st.samples_us)
            # time-indexed gets at varying staleness (10ms versions)
            for i in range(30):
                svc.put("/pers/t", data)
            fresh = svc.get("/pers/t").timestamp_ns
            for label, back_ns in (("fresh", 0), ("stale", int(5e6))):
                st = measure(f"table1/get_time_{label}_{size_name}",
                             lambda: svc.store.get_time("/pers/t", fresh - back_ns),
                             n=n, warmup=5)
                out(st.row())
                results[f"get_{label}_{size_name}"] = statistics.median(st.samples_us)
        svc.close()
    # ordinal claims
    for s in SIZES:
        assert results[f"put_trig_{s}"] < results[f"put_vola_{s}"], "trig !< vola"
        assert results[f"put_vola_{s}"] < results[f"put_pers_{s}"], "vola !< pers"
    out("table1/CLAIM trig<vola<pers,PASS,ordinal")
    return results


def bench_kv_throughput(out) -> dict:
    """Fig 3: put throughput vs shard size (replication)."""
    results = {}
    for size_name, nbytes in SIZES.items():
        data = payload(nbytes)
        n = 400 if nbytes < 100_000 else 60
        for repl in (1, 2, 3):
            with tempfile.TemporaryDirectory() as d:
                svc = CascadeService(n_workers=3, log_dir=d)
                svc.store.create_pool(PoolSpec(path="/v", replication=repl))
                svc.store.create_pool(PoolSpec(path="/t",
                                               persistence=Persistence.TRANSIENT))
                t0 = time.monotonic()
                for i in range(n):
                    svc.put(f"/v/k{i % 7}", data)
                dt = time.monotonic() - t0
                mbps = n * nbytes / dt / 2**20
                out(f"fig3/vola_put_{size_name}_shard{repl},{dt/n*1e6:.1f},"
                    f"MBps={mbps:.0f}")
                results[f"vola_{size_name}_r{repl}"] = mbps
                t0 = time.monotonic()
                for i in range(n):
                    svc.trigger_put(f"/t/k{i % 7}", data)
                dt = time.monotonic() - t0
                results[f"trig_{size_name}_r{repl}"] = n * nbytes / dt / 2**20
                svc.close()
        out(f"fig3/trig_put_{size_name},"
            f"{results[f'trig_{size_name}_r1']:.0f},MBps_shard1")
    # claim: trigger put beats replicated volatile put on throughput
    assert results["trig_1MB_r1"] > results["vola_1MB_r3"]
    out("fig3/CLAIM trig>vola3 throughput,PASS,ordinal")
    return results


def bench_saturation(out) -> dict:
    """Fig 4/9: latency vs offered rate — flat, then queueing blow-up."""
    import threading

    results = {}
    with tempfile.TemporaryDirectory() as d:
        svc = CascadeService(n_workers=3, log_dir=d)
        svc.store.create_pool(PoolSpec(path="/v", replication=3))
        data = payload(SIZES["10KB"])
        # calibrate max rate
        t0 = time.monotonic()
        for i in range(200):
            svc.put("/v/k", data)
        max_rate = 200 / (time.monotonic() - t0)
        for frac in (0.2, 0.5, 0.8, 1.2):
            rate = max_rate * frac
            period = 1.0 / rate
            lat = []
            next_t = time.monotonic()
            backlog_lat = 0.0
            for i in range(150):
                next_t += period
                t0 = time.monotonic()
                svc.put("/v/k", data)
                lat.append((time.monotonic() - t0) * 1e6)
                sleep = next_t - time.monotonic()
                if sleep > 0:
                    time.sleep(sleep)
            med = statistics.median(lat)
            p99 = sorted(lat)[int(0.99 * len(lat))]
            out(f"fig4/vola_10KB_rate{frac:.1f},{med:.1f},p99={p99:.1f}")
            results[f"rate_{frac}"] = med
        svc.close()
    return results

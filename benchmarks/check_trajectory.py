"""Perf-trajectory gate: diff a fresh BENCH_serve.json against the last
committed run and fail on p99 regressions beyond a noise band.

The committed BENCH_serve.json is the recorded trajectory of the serving
fast path; this script is the first step toward continuous perf-regression
tracking (ROADMAP): CI copies the committed file aside, reruns the smoke
benchmarks, then diffs.

Comparison rules:

- only fields named ``*_p99_us`` / ``*_p99_s`` are gated (tail latency is
  the contract; means and p50s wobble too much on shared runners);
- a current value worse than ``band`` × baseline fails (the band absorbs
  runner noise and smoke-vs-full config drift — pass ``--band`` to tune);
- a baseline field MISSING from the current run FAILS: a benchmark that
  silently stops emitting its p99s (renamed field, dropped bench, empty
  percentile pool collapsing to NaN) would otherwise pass the gate by
  vanishing.  Retiring a field deliberately is ``--allow-missing PATH``
  (repeatable; a dotted-path prefix matches its whole subtree);
- fields present only in the current run are reported as new, not failed
  (new benchmarks add fields; the next committed run baselines them);
- non-finite values (NaN from an empty percentile pool) are dropped on
  BOTH sides before comparison — so a baseline field that goes NaN counts
  as missing, not as skipped.

Exit status: 0 clean, 1 on any regression beyond the band or any
disappeared field not covered by --allow-missing.

Usage: python -m benchmarks.check_trajectory BASELINE.json CURRENT.json
       [--band 2.0] [--allow-missing PATH ...]
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Sequence


def _p99_fields(tree: dict, prefix: str = "") -> dict[str, float]:
    """Flatten ``tree`` to {dotted.path: value} keeping only finite p99s."""
    out: dict[str, float] = {}
    for key, val in tree.items():
        path = f"{prefix}.{key}" if prefix else key
        if isinstance(val, dict):
            out.update(_p99_fields(val, path))
        elif isinstance(val, list):
            for i, item in enumerate(val):     # e.g. per-turn rows
                if isinstance(item, dict):
                    out.update(_p99_fields(item, f"{path}[{i}]"))
        elif (isinstance(val, (int, float))
              and (key.endswith("_p99_us") or key.endswith("_p99_s"))
              and math.isfinite(val)):
            out[path] = float(val)
    return out


def compare(baseline: dict, current: dict, band: float,
            allow_missing: Sequence[str] = ()
            ) -> tuple[list[str], list[str]]:
    """Returns (regressions, report_lines).  Disappeared baseline fields
    count as regressions unless matched by an ``allow_missing`` prefix."""
    base = _p99_fields(baseline)
    cur = _p99_fields(current)
    regressions: list[str] = []
    lines: list[str] = []
    for path in sorted(base):
        if path not in cur:
            if any(path == a or path.startswith(a + ".")
                   for a in allow_missing):
                lines.append(f"  retired  {path} (--allow-missing)")
            else:
                lines.append(f"  MISSING  {path} (in baseline, absent from "
                             f"current run — a silently-vanished bench "
                             f"field; retire it with --allow-missing)")
                regressions.append(path)
            continue
        b, c = base[path], cur[path]
        ratio = c / b if b > 0 else float("inf") if c > 0 else 1.0
        verdict = "ok" if ratio <= band else "REGRESSION"
        lines.append(f"  {verdict:>10}  {path}: {b:.1f} -> {c:.1f} "
                     f"({ratio:.2f}x, band {band:.2f}x)")
        if ratio > band:
            regressions.append(path)
    for path in sorted(set(cur) - set(base)):
        lines.append(f"  new   {path} = {cur[path]:.1f} (no baseline)")
    return regressions, lines


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="check_trajectory",
        description="fail on serving p99 regressions vs a committed run")
    ap.add_argument("baseline", help="committed BENCH_serve.json")
    ap.add_argument("current", help="freshly produced BENCH_serve.json")
    ap.add_argument("--band", type=float, default=2.0,
                    help="allowed ratio current/baseline before failing "
                         "(default 2.0: smoke runs on shared runners are "
                         "noisy; tighten for dedicated hardware)")
    ap.add_argument("--allow-missing", action="append", default=[],
                    metavar="PATH",
                    help="dotted field path (or prefix) whose disappearance "
                         "from the current run is a deliberate retirement, "
                         "not a failure; repeatable")
    args = ap.parse_args(argv)

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError) as exc:
        print(f"# no usable baseline ({exc}); nothing to gate")
        return 0
    with open(args.current) as f:
        current = json.load(f)

    regressions, lines = compare(baseline, current, args.band,
                                 args.allow_missing)
    print(f"# perf trajectory: {args.current} vs {args.baseline}")
    for line in lines:
        print(line)
    if regressions:
        print(f"check_trajectory: {len(regressions)} p99 regression(s)/"
              f"disappearance(s) beyond the {args.band:.2f}x band: "
              f"{', '.join(regressions)}", file=sys.stderr)
        return 1
    print("check_trajectory: within band")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Serving-path benchmarks beyond the paper's figures: the unified
token-budget tick on the paged KV / prefix-reuse fast path.

``serve_prefix_reuse``: multi-turn chat sessions over FIFO affinity — every
turn's prompt extends the session's full history, so with the per-replica
prefix trie each warm turn prefills only the suffix past the last cached
block.  The jitted mixed step is warmed up BEFORE timing and the compile
time is reported as its own field, so TTFT percentiles measure steady state
instead of XLA compiles (the step's packed shape is fixed, so there is
exactly ONE compile to exclude).  Reports TTFT p50/p99 per turn round, the
token-level prefix hit rate, and the skipped-block count; asserts the
fast-path invariants (one device→host sync per tick, ``host_syncs ==
ticks``; warm turns reuse > 0 tokens and prefill strictly fewer than they
carry).

``serve_mixed_tick``: long prefills injected into an ACTIVE decode pool.
With a bounded token budget the prompt spreads over budget-sized chunks that
ride in each tick's remainder, so decoding sessions keep emitting one token
per tick and the inter-token stall is bounded by the chunk budget.  The
baseline is the SAME engine with a monolithic budget (the whole prompt packs
into one tick) — i.e. the head-of-line behavior of the old phase-separated
tick, where a long prefill takes the tick hostage.  Reports decode TPOT
p50/p99 over the contention window for both; the chunked p99 must beat the
monolithic p99 (asserted outside smoke mode).

``serve_multi_model``: one ``ServeNode`` hosting a paged attention LIGHT
model and a dense SSM HEAVY model side by side, with a ``CascadeRoute``
between them, driven into overload.  The cascade gate's logprob threshold is
CALIBRATED (median of light-model mean logprobs over probe requests) so the
escalation rate is a property of the gate, not a lucky constant.  Records
the escalation rate at the gate, shed/redirect counts once the light tier's
per-replica queues hit the watermark (MultiTASC++-style bounded admission),
and p50/p99 TTFT/TPOT per deployment; asserts each deployment's own
host-sync discipline (paged: ``host_syncs == ticks``; dense SSM:
``host_syncs == decode_ticks + prefill_batches``) and that every request is
answered — shed at the light tier fails over to the heavy tier, never into
silence.

``serve_speculative``: decode TPOT with speculative decoding on the unified
tick.  Three passes over identical prompts at the SAME token budget (the
step's packed shape — and so its per-dispatch cost — is fixed either way):
a non-speculative baseline; a speculative pass whose requests carry the
baseline's own output as drafts (the self-drafting cascade's perfect-
drafter limit — exactly what a ``CascadeRoute`` plants on escalation when
light and heavy agree); and a speculative pass drafting only from the
request's own history (n-gram prompt lookup).  Records decode TPOT p50/p99,
acceptance rate, and the drafted/accepted/rolled-back counters.  Asserts —
always — that greedy outputs are IDENTICAL across all three passes
(rejection sampling is lossless), that accepted <= drafted with
rolled-back making up the difference, that the perfect-drafter acceptance
rate is >= 0.5, and ``host_syncs == ticks`` with speculation on; outside
smoke mode the speculative TPOT p50 must beat the baseline (one sync
amortized over multiple accepted tokens).

``serve_overload``: a diurnal-style overload — a batch flood parks on a
single watermarked replica, then an interactive trickle arrives on top.
Pass A is the shed-only FIFO baseline (no SLO classes, no preemption): the
trickle either sheds at the watermark or queues behind the flood.  Pass B
runs the same workload with SLO classes and ``preempt=True``: over-watermark
interactive arrivals admit via preempt-before-shed, and the engine's EDF
preemption spills a batch victim's KV to the host-side pool to issue them
immediately.  Asserts — always, including smoke — that B's interactive p99
TTFT beats A's, that B serves at least as many interactive requests, that
every batch request in B still completes (EDF aging: absolute virtual
deadlines bound starvation), zero stranded requests in both passes, and the
sync discipline per pass (A strict ``host_syncs == ticks``; B ``host_syncs
== ticks + spill_syncs``).

Set ``BENCH_SMOKE=1`` for a tiny-config, few-tick variant of all of these
(CI runs this on every PR).  Results land in BENCH_serve.json so the
serving perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import math
import os
import time

import jax
import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                          "BENCH_serve.json")


def _smoke() -> bool:
    return os.environ.get("BENCH_SMOKE", "") == "1"


def _write_results(key: str, results: dict, out) -> None:
    """Merge one benchmark's results into BENCH_serve.json."""
    data = {}
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            data = {}
    if not all(isinstance(v, dict) and ("turns" in v or "chunked" in v
                                        or "total" in v or "route" in v
                                        or "baseline" in v or "faults" in v)
               for v in data.values()):
        data = {}                     # pre-PR3 flat schema: start fresh
    data[key] = results
    with open(BENCH_JSON, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    out(f"# wrote {BENCH_JSON}[{key}]")


def _pct(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))] if xs else float("nan")


def bench_serve_prefix_reuse(out) -> dict:
    from repro.core.pools import DispatchPolicy
    from repro.models import init_params
    from repro.models.config import ModelConfig
    from repro.serving.cluster import ServeCluster
    from repro.serving.engine import EngineStats

    cfg = ModelConfig(name="bench", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype="float32", q_chunk=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    n_sessions, n_turns = (2, 2) if _smoke() else (6, 4)
    block_size = 16
    new_tokens_per_turn, decode_budget = 24, 8
    results: dict = {"turns": []}

    with ServeCluster(cfg, params, n_replicas=2, n_slots=4, max_len=256,
                      policy=DispatchPolicy.FIFO, block_size=block_size) as c:
        # Warm up the ONE fixed-shape jitted mixed step before timing, then
        # reset stats: TTFT percentiles below are steady state and the
        # compile cost is its own field.
        t0 = time.monotonic()
        c.submit("warmup", "w0", rng.integers(0, cfg.vocab_size,
                                              (8,)).astype(np.int32),
                 max_new_tokens=2)
        c.run_until_drained()
        compile_s = time.monotonic() - t0
        for e in c.engines:
            e.stats = EngineStats()
        results["compile_s"] = compile_s
        out(f"serve_prefix_reuse/compile,{compile_s*1e6:.1f},"
            f"one_time_jit_cost")

        history = {f"s{i}": rng.integers(0, cfg.vocab_size,
                                         (new_tokens_per_turn,)).astype(np.int32)
                   for i in range(n_sessions)}
        prev_hits = 0
        for turn in range(n_turns):
            marks = {e: (len(e.stats.ttft_s),
                         e.stats.prefix_hit_tokens, e.stats.prompt_tokens)
                     for e in c.engines}
            t0 = time.monotonic()
            for sess, hist in history.items():
                c.submit(sess, f"{sess}-t{turn}", hist,
                         max_new_tokens=decode_budget)
            c.run_until_drained()
            dt = time.monotonic() - t0
            ttft = sorted(t for e in c.engines
                          for t in e.stats.ttft_s[marks[e][0]:])
            hit = sum(e.stats.prefix_hit_tokens - marks[e][1]
                      for e in c.engines)
            prompt = sum(e.stats.prompt_tokens - marks[e][2]
                         for e in c.engines)
            row = {
                "turn": turn,
                "ttft_p50_us": _pct(ttft, 0.50) * 1e6,
                "ttft_p99_us": _pct(ttft, 0.99) * 1e6,
                "prompt_tokens": prompt,
                "prefix_hit_tokens": hit,
                "hit_rate": hit / max(1, prompt),
                "skipped_blocks": hit // block_size,
                "wall_s": dt,
            }
            results["turns"].append(row)
            out(f"serve_prefix_reuse/turn{turn},{row['ttft_p50_us']:.1f},"
                f"ttft_p99_us={row['ttft_p99_us']:.1f} "
                f"hit_rate={row['hit_rate']:.2f} "
                f"skipped_blocks={row['skipped_blocks']}")
            if turn > 0:
                assert hit > prev_hits or hit > 0, \
                    "warm turns must reuse cached prefix blocks"
            prev_hits = hit
            # next turn: history grows by this turn's output + new user text
            for sess in history:
                res = c.result(f"{sess}-t{turn}")
                assert res is not None
                history[sess] = np.concatenate(
                    [history[sess], np.asarray(res, np.int32),
                     rng.integers(0, cfg.vocab_size,
                                  (new_tokens_per_turn,)).astype(np.int32)])

        st = c.stats()
        assert st["host_syncs"] == st["ticks"], \
            "a unified tick made more than one device→host transfer"
        assert st["prefix_hit_tokens"] > 0, "no prefix reuse over warm turns"
        # strictly fewer prefill FLOPs than a cache-less engine would spend
        assert st["prefill_tokens"] < st["prompt_tokens"]
        results["total"] = {
            "requests": st["requests"],
            "prompt_tokens": st["prompt_tokens"],
            "prefill_tokens": st["prefill_tokens"],
            "prefix_hit_tokens": st["prefix_hit_tokens"],
            "hit_rate": st["prefix_hit_tokens"] / max(1, st["prompt_tokens"]),
            "ttft_p50_us": st["ttft_p50_s"] * 1e6,
            "ttft_p99_us": st["ttft_p99_s"] * 1e6,
            "blocks_in_use": st["blocks_in_use"],
            "ticks": st["ticks"],
        }
    out(f"serve_prefix_reuse/total,{results['total']['ttft_p50_us']:.1f},"
        f"hit_rate={results['total']['hit_rate']:.2f} "
        f"prefill_tokens={results['total']['prefill_tokens']} "
        f"of_prompt_tokens={results['total']['prompt_tokens']}")
    out("serve_prefix_reuse/CLAIM warm-turns-skip-prefix-prefill,PASS,exact")
    out("serve_prefix_reuse/CLAIM steady-state-ttft-excludes-compile,PASS,exact")
    _write_results("serve_prefix_reuse", results, out)
    return results


def bench_serve_mixed_tick(out) -> dict:
    from repro.models import init_params
    from repro.models.config import ModelConfig
    from repro.serving.engine import ServeEngine
    from repro.serving.scheduler import Request

    smoke = _smoke()
    cfg = ModelConfig(name="bench-mixed", family="dense", n_layers=2,
                      d_model=64 if smoke else 256, n_heads=4, n_kv_heads=2,
                      d_ff=128 if smoke else 512, vocab_size=256,
                      dtype="float32", q_chunk=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_slots = 8
    long_S = 96 if smoke else 384
    max_len = 160 if smoke else 512
    decode_new = 16 if smoke else 48
    chunk_budget = 32 if smoke else 48
    budgets = {"chunked": chunk_budget, "monolithic": long_S + n_slots}
    results: dict = {}

    for label, budget in budgets.items():
        rng = np.random.default_rng(7)
        eng = ServeEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                          paged=True, block_size=16, token_budget=budget)
        done = []
        eng.on_complete = done.append
        mk = lambda rid, S, n: Request(
            request_id=rid, session_key=rid,
            prompt=rng.integers(0, cfg.vocab_size, (S,)).astype(np.int32),
            max_new_tokens=n)
        # warm the (fixed-shape, compiles-once) step outside the timings
        t0 = time.monotonic()
        eng.submit(mk("warm", 8, 2))
        eng.run_until_drained()
        compile_s = time.monotonic() - t0
        # steady decode pool: six chat sessions mid-generation
        for i in range(6):
            eng.submit(mk(f"chat{i}", 8, decode_new))
        for _ in range(4):
            eng.tick()
        mark = len(eng.stats.tpot_s)
        # inject two long prefills into the busy pool
        for i in range(2):
            eng.submit(mk(f"wall{i}", long_S, 4))
        t0 = time.monotonic()
        eng.run_until_drained()
        wall_s = time.monotonic() - t0
        tpot = eng.stats.tpot_s[mark:]
        assert eng.stats.host_syncs == eng.stats.ticks
        walls = [r for r in done if r.request_id.startswith("wall")]
        assert len(walls) == 2 and all(r.error is None for r in done)
        row = {
            "token_budget": budget,
            "compile_s": compile_s,
            "tpot_p50_us": _pct(tpot, 0.50) * 1e6,
            "tpot_p99_us": _pct(tpot, 0.99) * 1e6,
            "wall_ttft_p99_us": _pct(
                [r.first_token_s - r.arrived_s for r in walls], 0.99) * 1e6,
            "prefill_chunks": eng.stats.prefill_chunks,
            "ticks": eng.stats.ticks,
            "wall_s": wall_s,
        }
        results[label] = row
        out(f"serve_mixed_tick/{label},{row['tpot_p50_us']:.1f},"
            f"tpot_p99_us={row['tpot_p99_us']:.1f} "
            f"prefill_chunks={row['prefill_chunks']} ticks={row['ticks']}")

    ratio = (results["monolithic"]["tpot_p99_us"]
             / max(1e-9, results["chunked"]["tpot_p99_us"]))
    results["stall_ratio_p99"] = ratio
    out(f"serve_mixed_tick/stall_ratio,{ratio:.2f},"
        f"monolithic_p99_over_chunked_p99")
    if not smoke:
        # the tentpole claim: bounding the chunk budget bounds the
        # inter-token stall a concurrent long prefill can inflict
        assert results["chunked"]["tpot_p99_us"] \
            < results["monolithic"]["tpot_p99_us"], \
            "chunked prefill must bound decode TPOT below the monolithic tick"
        out("serve_mixed_tick/CLAIM chunked-tpot-beats-monolithic,PASS,exact")
    _write_results("serve_mixed_tick", results, out)
    return results


def bench_serve_speculative(out) -> dict:
    from repro.models import init_params
    from repro.models.config import ModelConfig
    from repro.serving.engine import ServeEngine
    from repro.serving.scheduler import Request

    smoke = _smoke()
    cfg = ModelConfig(name="bench-spec", family="dense", n_layers=2,
                      d_model=64 if smoke else 256, n_heads=4, n_kv_heads=2,
                      d_ff=128 if smoke else 512, vocab_size=256,
                      dtype="float32", q_chunk=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_slots = 4 if smoke else 8
    S = 16 if smoke else 32
    decode_new = 16 if smoke else 48
    spec_k = 4
    # one budget for every pass: full drafting headroom, fixed packed shape
    # (so baseline and speculative ticks dispatch the same program cost and
    # the TPOT delta is pure accepted-token amortization, not shape luck)
    budget = n_slots * (1 + spec_k) + 8
    max_len = 96 if smoke else 160
    results: dict = {}

    def run(label, spec, drafts=None):
        rng = np.random.default_rng(11)      # same stream ⇒ same prompts
        eng = ServeEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                          paged=True, block_size=16, token_budget=budget,
                          spec_k=spec)
        done = []
        eng.on_complete = done.append
        t0 = time.monotonic()
        eng.submit(Request(
            request_id="warm", session_key="w", max_new_tokens=2,
            prompt=rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)))
        eng.run_until_drained()
        compile_s = time.monotonic() - t0
        mark = len(eng.stats.tpot_s)
        for i in range(n_slots):
            req = Request(
                request_id=f"chat{i}", session_key=f"s{i}",
                prompt=rng.integers(0, cfg.vocab_size,
                                    (S,)).astype(np.int32),
                max_new_tokens=decode_new)
            if drafts is not None:
                req.draft_tokens = np.asarray(drafts[req.request_id],
                                              np.int32)
            eng.submit(req)
        t0 = time.monotonic()
        eng.run_until_drained()
        wall_s = time.monotonic() - t0
        assert eng.stats.host_syncs == eng.stats.ticks, \
            "speculation broke the one-sync-per-tick invariant"
        st = eng.stats
        assert st.spec_accepted <= st.spec_drafted
        assert st.spec_accepted + st.spec_rolled_back == st.spec_drafted
        tpot = st.tpot_s[mark:]
        row = {
            "spec_k": spec, "token_budget": budget, "compile_s": compile_s,
            "tpot_p50_us": _pct(tpot, 0.50) * 1e6,
            "tpot_p99_us": _pct(tpot, 0.99) * 1e6,
            "ticks": st.ticks, "tokens_out": st.tokens_out,
            "drafted": st.spec_drafted, "accepted": st.spec_accepted,
            "rolled_back": st.spec_rolled_back,
            "acceptance_rate": st.spec_acceptance_rate(),
            "wall_s": wall_s,
        }
        results[label] = row
        out(f"serve_speculative/{label},{row['tpot_p50_us']:.1f},"
            f"tpot_p99_us={row['tpot_p99_us']:.1f} ticks={row['ticks']} "
            f"drafted={row['drafted']} accepted={row['accepted']} "
            f"rolled_back={row['rolled_back']} "
            f"acceptance_rate={row['acceptance_rate']:.2f}")
        return {r.request_id: list(r.tokens) for r in done
                if r.request_id.startswith("chat")}

    base_toks = run("baseline", 0)
    # the self-drafting cascade's perfect-drafter limit: requests carry the
    # target's own greedy output as their draft stream (what CascadeRoute
    # plants on escalation when light and heavy agree)
    spec_toks = run("speculative", spec_k, drafts=base_toks)
    ngram_toks = run("self_drafting", spec_k)
    # losslessness: greedy streams identical across all three passes
    assert spec_toks == base_toks, \
        "speculative greedy output diverged from the baseline"
    assert ngram_toks == base_toks, \
        "self-drafting greedy output diverged from the baseline"
    sp = results["speculative"]
    assert sp["drafted"] > 0 and sp["acceptance_rate"] >= 0.5, \
        "perfect drafts must verify at >= 0.5 acceptance"
    speedup = (results["baseline"]["tpot_p50_us"]
               / max(1e-9, sp["tpot_p50_us"]))
    results["tpot_p50_speedup"] = speedup
    out(f"serve_speculative/speedup,{speedup:.2f},"
        f"baseline_p50_over_speculative_p50 "
        f"ngram_acceptance={results['self_drafting']['acceptance_rate']:.2f}")
    if not _smoke():
        assert sp["tpot_p50_us"] < results["baseline"]["tpot_p50_us"], \
            "speculative decode must beat baseline TPOT p50"
        out("serve_speculative/CLAIM spec-tpot-beats-baseline,PASS,exact")
    out("serve_speculative/CLAIM greedy-output-lossless,PASS,exact")
    out("serve_speculative/CLAIM counters-consistent,PASS,exact")
    _write_results("serve_speculative", results, out)
    return results


def bench_serve_multi_model(out) -> dict:
    import statistics

    from repro.core.pools import DispatchPolicy
    from repro.models import init_params
    from repro.models.config import ModelConfig
    from repro.serving.cluster import CascadeGate, CascadeRoute, ServeNode
    from repro.serving.engine import EngineStats

    smoke = _smoke()
    light_cfg = ModelConfig(name="light", family="dense", n_layers=2,
                            d_model=32 if smoke else 64, n_heads=4,
                            n_kv_heads=2, d_ff=64 if smoke else 128,
                            vocab_size=256, dtype="float32", q_chunk=16)
    # dense SSM heavy model: d_inner = 2*d_model must divide ssm_head_dim 64
    heavy_cfg = ModelConfig(name="heavy", family="ssm", n_layers=2,
                            d_model=64 if smoke else 128, n_heads=4,
                            n_kv_heads=2, d_ff=128 if smoke else 256,
                            vocab_size=256, dtype="float32")
    lp = init_params(jax.random.PRNGKey(0), light_cfg)
    hp = init_params(jax.random.PRNGKey(1), heavy_cfg)
    rng = np.random.default_rng(0)

    S = 12 if smoke else 24                  # ONE prompt length: the dense
    max_new = 4 if smoke else 8              # prefill compiles stay bounded
    n_requests = 10 if smoke else 32
    n_sessions = 4
    # depth counts decoding rows too (they gate a new arrival's wait just
    # as queued ones do), so the watermark must leave room above n_slots:
    # 4 in service + 4 waiting per replica, anything beyond redirects/sheds
    watermark = 3 if smoke else 8
    prompt = lambda: rng.integers(0, 256, (S,)).astype(np.int32)
    results: dict = {}

    with ServeNode(n_workers=2) as node:
        light = node.deploy("light", light_cfg, lp, n_replicas=2, n_slots=4,
                            max_len=96, policy=DispatchPolicy.FIFO,
                            watermark=None)      # opened up for calibration
        heavy = node.deploy("heavy", heavy_cfg, hp, n_replicas=2, n_slots=4,
                            max_len=96)          # unbounded: the spillway

        # ---- warm both programs out of the timings (light: the ONE mixed
        # step; heavy: dense prefill for group sizes 1 and 2 + decode step)
        t0 = time.monotonic()
        light.submit("warm", "lw0", prompt(), max_new_tokens=2)
        for i in range(3):
            heavy.submit("warm", f"hw{i}", prompt(), max_new_tokens=2)
        node.run_until_drained()
        results["compile_s"] = time.monotonic() - t0

        # ---- calibrate the gate: median mean-logprob of light generations
        # over probe requests → escalation rate is a property of the GATE
        probe_scores: list[float] = []
        probe = lambda req: probe_scores.append(req.mean_logprob())
        light.on_done.append(probe)
        for i in range(8):
            light.submit(f"cal{i % n_sessions}", f"cal{i}", prompt(),
                         max_new_tokens=max_new)
        node.run_until_drained()
        light.on_done.remove(probe)
        threshold = statistics.median(probe_scores)
        gate = CascadeGate("logprob", threshold=threshold)
        route = CascadeRoute(light, heavy, gate)
        out(f"serve_multi_model/gate,{threshold:.4f},"
            f"median_mean_logprob_over_{len(probe_scores)}_probes")

        # ---- measured overload phase: arrivals outpace service (two
        # requests per driver step, vs a service rate of n_slots/max_new
        # requests per tick per replica), so queues climb to the watermark
        # and stay there — some requests serve and face the gate, the
        # over-watermark tail sheds or redirects (the MultiTASC++ regime,
        # not a one-shot burst that sheds everything)
        for eng in light.engines + heavy.engines:
            eng.stats = EngineStats()
        light.watermark = watermark
        rids = [f"r{i}" for i in range(n_requests)]
        t0 = time.monotonic()
        for i, rid in enumerate(rids):
            route.submit(f"s{i % n_sessions}", rid, prompt(),
                         max_new_tokens=max_new)
            if i % 2 == 1:
                node.step()
        node.run_until_drained()
        wall_s = time.monotonic() - t0

        ls, hs, rs = light.stats(), heavy.stats(), route.stats()
        # each deployment upholds ITS OWN fast-path discipline
        assert ls["host_syncs"] == ls["ticks"], \
            "paged light deployment broke host_syncs == ticks"
        assert hs["host_syncs"] == hs["decode_ticks"] + hs["prefill_batches"], \
            "dense SSM heavy deployment broke the phase-separated discipline"
        # bounded admission really engaged under the burst
        assert ls["shed"] + ls["redirected"] > 0, \
            "overload burst never hit the light tier's watermark"
        assert rs["escalated"] > 0, "nothing escalated under overload"
        # no request vanishes: shed at light fails over to heavy
        for rid in rids:
            res = route.result(rid)
            assert res is not None and len(res) == max_new, \
                f"{rid} unanswered: {route.error(rid)!r}"
        if not smoke:
            assert rs["gate_trips"] > 0, "calibrated gate never tripped"
            assert rs["escalation_rate"] < 1.0, \
                "median-calibrated gate escalated everything"

        def dep_row(st):
            return {
                "requests": st["requests"], "shed": st["shed"],
                "redirected": st["redirected"],
                "tokens_out": st["tokens_out"],
                "ttft_p50_us": st["ttft_p50_s"] * 1e6,
                "ttft_p99_us": st["ttft_p99_s"] * 1e6,
                "tpot_p50_us": st["tpot_p50_s"] * 1e6,
                "tpot_p99_us": st["tpot_p99_s"] * 1e6,
            }

        results["route"] = {
            "requests": rs["requests"], "escalated": rs["escalated"],
            "gate_trips": rs["gate_trips"],
            "error_failovers": rs["error_failovers"],
            "escalation_rate": rs["escalation_rate"],
            "threshold": threshold,
        }
        results["light"] = dep_row(ls)
        results["heavy"] = dep_row(hs)
        results["total"] = {"requests": n_requests, "wall_s": wall_s,
                            "watermark": watermark}
        for name, row in (("light", results["light"]),
                          ("heavy", results["heavy"])):
            out(f"serve_multi_model/{name},{row['ttft_p50_us']:.1f},"
                f"ttft_p99_us={row['ttft_p99_us']:.1f} "
                f"tpot_p50_us={row['tpot_p50_us']:.1f} "
                f"shed={row['shed']} redirected={row['redirected']}")
        out(f"serve_multi_model/route,{rs['escalation_rate']:.2f},"
            f"escalated={rs['escalated']}_of_{rs['requests']} "
            f"gate_trips={rs['gate_trips']} "
            f"error_failovers={rs['error_failovers']}")
    out("serve_multi_model/CLAIM per-deployment-sync-invariants,PASS,exact")
    out("serve_multi_model/CLAIM overload-sheds-or-redirects,PASS,exact")
    out("serve_multi_model/CLAIM shed-fails-over-never-drops,PASS,exact")
    _write_results("serve_multi_model", results, out)
    return results


def bench_serve_chaos(out) -> dict:
    """Chaos smoke: a SEEDED fault schedule (replica crash with KV
    migration, transient submit errors, slow ticks) over a cascade-style
    serve setup.  The claim is availability, not speed: every request
    reaches a terminal state — a served result or a structured error —
    with zero stranded requests, and the drain resolves rather than
    timing out.  Failover counters and post-fault latency land in
    BENCH_serve.json so degraded-mode tails are tracked across PRs."""
    from repro.models import init_params
    from repro.models.config import ModelConfig
    from repro.serving.cluster import ServeNode
    from repro.serving.faults import FaultInjector, FaultKind, FaultSpec

    smoke = _smoke()
    cfg = ModelConfig(name="light", family="dense", n_layers=2,
                      d_model=32 if smoke else 64, n_heads=4, n_kv_heads=2,
                      d_ff=64 if smoke else 128, vocab_size=256,
                      dtype="float32", q_chunk=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    S = 12 if smoke else 24
    max_new = 4 if smoke else 8
    n_requests = 12 if smoke else 48
    prompt = lambda: rng.integers(0, 256, (S,)).astype(np.int32)
    results: dict = {}

    injector = FaultInjector([
        # one replica dies at a seeded tick; its sessions migrate (KV
        # spill/restore) or replay onto the sibling
        FaultSpec(FaultKind.CRASH, deployment="light", at_tick=-8,
                  kv_recoverable=True),
        # a couple of transient submit failures bounce to the retry path
        FaultSpec(FaultKind.SUBMIT_ERROR, deployment="light", count=2),
        # and some slow ticks stretch the tail without tripping the watchdog
        FaultSpec(FaultKind.SLOW_TICK, deployment="light", at_tick=2,
                  count=3, duration_s=0.002),
    ], seed=1234)

    with ServeNode(n_workers=2) as node:
        dep = node.deploy("light", cfg, params, n_replicas=2, n_slots=4,
                          max_len=96, watchdog_s=1.0)
        # warm the mixed program out of the measurement
        t0 = time.monotonic()
        dep.submit("warm", "w0", prompt(), max_new_tokens=2)
        node.run_until_drained()
        results["compile_s"] = time.monotonic() - t0

        node.install_faults(injector)
        rids = [f"r{i}" for i in range(n_requests)]
        t0 = time.monotonic()
        for i, rid in enumerate(rids):
            dep.submit(f"s{i % 4}", rid, prompt(), max_new_tokens=max_new)
            if i % 3 == 2:
                node.step()
        node.run_until_drained()
        wall_s = time.monotonic() - t0

        st = dep.stats()
        stranded = [rid for rid in rids if dep.result(rid) is None]
        assert not stranded, f"stranded requests under chaos: {stranded}"
        errored = sum(1 for rid in rids if dep.error(rid) is not None)
        for rid in rids:
            err = dep.error(rid)
            if err is None:
                assert len(dep.result(rid)) == max_new
            else:
                assert isinstance(err, dict) and "error" in err, \
                    f"unstructured failure for {rid}: {err!r}"
        assert any(e.startswith("crash:") for e in injector.fired_log), \
            "seeded crash never fired"
        assert st["failovers"] >= 1, "crash did not mark the replica down"

        results["faults"] = {
            "failovers": st["failovers"],
            "rehomed": st["rehomed"], "migrated": st["migrated"],
            "replayed": st["replayed"],
            "failover_failed": st["failover_failed"],
            "submit_retries": st["submit_retries"],
            "spill_syncs": st["spill_syncs"],
            "fired": list(injector.fired_log),
        }
        results["total"] = {
            "requests": n_requests, "errored": errored, "wall_s": wall_s,
            "ttft_p99_us": st["ttft_p99_s"] * 1e6,
            "tpot_p99_us": st["tpot_p99_s"] * 1e6,
        }
        out(f"serve_chaos/failover,{st['failovers']},"
            f"rehomed={st['rehomed']} migrated={st['migrated']} "
            f"replayed={st['replayed']} retries={st['submit_retries']}")
        out(f"serve_chaos/total,{wall_s*1e6/n_requests:.1f},"
            f"requests={n_requests} errored={errored} "
            f"ttft_p99_us={results['total']['ttft_p99_us']:.1f}")
    out("serve_chaos/CLAIM zero-stranded-requests-under-chaos,PASS,exact")
    out("serve_chaos/CLAIM structured-errors-only,PASS,exact")
    _write_results("serve_chaos", results, out)
    return results


def bench_serve_overload(out) -> dict:
    """Overload A/B: shed-only FIFO vs SLO classes + KV preemption.

    One replica, watermarked queue, batch flood + interactive trickle.
    The baseline pass submits everything classless (EDF over a uniform
    class IS arrival-order FIFO) with preemption off — the pre-SLO
    behavior: interactive arrivals shed at the watermark or queue behind
    the flood.  The preempt pass tags the trickle ``interactive``: the
    door admits it over the watermark (preempt-before-shed) and the
    engine spills a batch victim to issue it at once."""
    from repro.models import init_params
    from repro.models.config import ModelConfig
    from repro.serving.cluster import ServeNode
    from repro.serving.scheduler import SLO_INTERACTIVE

    smoke = _smoke()
    cfg = ModelConfig(name="ovl", family="dense", n_layers=2,
                      d_model=32 if smoke else 64, n_heads=4, n_kv_heads=2,
                      d_ff=64 if smoke else 128, vocab_size=256,
                      dtype="float32", q_chunk=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_slots = 2
    n_batch = 6
    watermark = n_batch              # the parked flood sits AT the mark
    n_inter = 4 if smoke else 8
    batch_S, batch_new = (8, 8) if smoke else (16, 16)
    inter_S, inter_new = 4, 3
    results: dict = {}

    def run(label, *, preempt):
        rng = np.random.default_rng(3)
        done: dict[str, tuple[float | None, bool]] = {}

        def probe(req):
            ttft = (None if req.first_token_s is None
                    else req.first_token_s - req.arrived_s)
            done[req.request_id] = (ttft, req.error is None)

        with ServeNode(n_workers=1) as node:
            dep = node.deploy("ovl", cfg, params, n_replicas=1,
                              n_slots=n_slots, max_len=96,
                              prefix_cache=False, watermark=None,
                              preempt=preempt)
            # warm the one jitted step outside the measurement
            t0 = time.monotonic()
            dep.submit("warm", "w0",
                       rng.integers(0, 256, (batch_S,)).astype(np.int32),
                       max_new_tokens=2)
            node.run_until_drained()
            compile_s = time.monotonic() - t0
            dep.on_done.append(probe)

            rids = []
            for i in range(n_batch):        # the flood: fills both slots
                rid = f"b{i}"               # and parks the rest in queue
                rids.append(rid)
                dep.submit(f"bs{i}", rid,
                           rng.integers(0, 256,
                                        (batch_S,)).astype(np.int32),
                           max_new_tokens=batch_new)
            # arm the watermark only once the flood is INSIDE the engine
            # (upcall lambdas drained): the flood is accepted work parked
            # at the mark, and the watermark governs what arrives ON TOP —
            # the trickle.  Arming early would race the flood's own
            # admission lambdas and shed the flood at its own door.
            stop = time.monotonic() + 10
            while dep.engines[0].backlog() < n_batch:
                assert time.monotonic() < stop, "flood never reached engine"
                node.step()
                time.sleep(0.001)
            dep.watermark = watermark
            for j in range(n_inter):        # the trickle, on top of it
                rid = f"i{j}"
                rids.append(rid)
                dep.submit(f"is{j}", rid,
                           rng.integers(0, 256,
                                        (inter_S,)).astype(np.int32),
                           max_new_tokens=inter_new,
                           slo=SLO_INTERACTIVE if preempt else None)
                for _ in range(2):
                    node.step()
            node.run_until_drained()

            stranded = [r for r in rids
                        if dep.result(r) is None and dep.error(r) is None]
            assert not stranded, f"stranded under overload: {stranded}"
            # both passes: the accepted flood must complete — in the
            # preempt pass this is the EDF aging bound in action (the
            # preempted flood still finishes, nothing starves)
            berr = {f"b{i}": dep.error(f"b{i}") for i in range(n_batch)
                    if dep.error(f"b{i}") is not None}
            assert not berr, f"batch flood starved/refused: {berr}"
            st = dep.stats()
            if preempt:
                assert st["host_syncs"] == st["ticks"] + st["spill_syncs"]
                # every batch request issued (the classless warm request
                # rides in the batch histogram too, hence >=)
                assert st["queue_wait_s"].get("batch",
                                              {}).get("n", 0) >= n_batch
            else:
                assert st["spill_syncs"] == 0
                assert st["host_syncs"] == st["ticks"]

        inter_ttft = sorted(done[f"i{j}"][0] for j in range(n_inter)
                            if done.get(f"i{j}", (None, False))[1])
        # effective TTFT: a shed request never produced a token — its
        # first-token latency is unbounded, and the A/B claim must charge
        # the shed-only baseline for it rather than sampling the survivors
        eff_ttft = sorted((done[f"i{j}"][0]
                           if done.get(f"i{j}", (None, False))[1]
                           else float("inf")) for j in range(n_inter))
        row = {
            "compile_s": compile_s,
            "preempt": preempt,
            "interactive_served": len(inter_ttft),
            "interactive_shed": n_inter - len(inter_ttft),
            "interactive_ttft_p50_us": _pct(inter_ttft, 0.50) * 1e6,
            "interactive_ttft_p99_us": _pct(inter_ttft, 0.99) * 1e6,
            "batch_served": sum(1 for i in range(n_batch)
                                if done.get(f"b{i}", (None, False))[1]),
            "preemptions": st["preemptions"],
            "resumes": st["resumes"],
            "spilled_blocks": st["spilled_blocks"],
            "preempt_admits": st["preempt_admits"],
            "shed": st["shed"],
            "ticks": st["ticks"],
            "queue_wait_s": st["queue_wait_s"],
        }
        results[label] = row
        out(f"serve_overload/{label},{row['interactive_ttft_p50_us']:.1f},"
            f"ttft_p99_us={row['interactive_ttft_p99_us']:.1f} "
            f"served={row['interactive_served']}_of_{n_inter} "
            f"shed={row['shed']} preemptions={row['preemptions']} "
            f"resumes={row['resumes']}")
        return row, eff_ttft

    base, base_eff = run("baseline", preempt=False)
    pre, pre_eff = run("preempt", preempt=True)

    b_p99 = _pct(base_eff, 0.99) * 1e6       # inf when any shed landed p99
    p_p99 = _pct(pre_eff, 0.99) * 1e6
    assert pre["interactive_served"] == n_inter, \
        "preempt pass shed interactive work it should have admitted"
    assert pre["interactive_served"] >= base["interactive_served"]
    assert base["shed"] >= 1, \
        "the flood never pushed the baseline into its shed-only regime"
    assert pre["preemptions"] >= 1, "overload never triggered a preemption"
    assert p_p99 < b_p99, \
        "preemption failed to beat the shed-only FIFO interactive p99 TTFT"
    # EDF class separation inside the preempt pass: interactive queue wait
    # must sit well below the preempted batch flood's
    pw = pre["queue_wait_s"]
    assert pw["interactive"]["p50_s"] < pw["batch"]["p50_s"], \
        "interactive queue wait did not separate from the batch flood"
    results["total"] = {
        "n_batch": n_batch, "n_interactive": n_inter,
        "watermark": watermark,
        "baseline_eff_p99_finite": math.isfinite(b_p99),
        "preempt_eff_ttft_p99_us": p_p99,
    }
    out(f"serve_overload/effective_p99,{p_p99:.1f},"
        f"baseline_eff_p99_us={b_p99:.1f}_with_shed_as_inf")
    out("serve_overload/CLAIM preempt-beats-shed-only-ttft,PASS,exact")
    out("serve_overload/CLAIM batch-flood-still-completes,PASS,exact")
    out("serve_overload/CLAIM zero-stranded-requests,PASS,exact")
    _write_results("serve_overload", results, out)
    return results


def bench_serve_kv_quant(out) -> dict:
    """A/B: bf16 KV block pool vs int8 (per-(block, slot, kv-head) scales)
    at the SAME fixed token budget, seeds, and prompts — decode is
    bandwidth-bound, so the quantized pool should cut decode TPOT by cutting
    the bytes each decode token streams from the pool.

    Always asserts the machine-independent half of the claim: measured
    ``kv_bytes_per_token`` drops >= 1.8x (int8+f32-scales vs bf16 is
    2D/(D+4) = 1.88x at head_dim 64), both streams complete error-free, and
    ``host_syncs == ticks`` per arm.  Outside smoke mode the wall-clock half
    is asserted too: int8 decode TPOT p50 must beat bf16 (on a CPU host this
    measures the XLA-fallback dequant, so the assert rides the non-smoke
    path exactly like serve_mixed_tick's).  Records per-arm
    ``kv_bytes_per_token`` + shape fields so ``roofline.kv_bytes_table``
    can report achieved vs theoretical bandwidth."""
    from repro.serving.engine import ServeEngine
    from repro.models import init_params
    from repro.models.config import ModelConfig
    from repro.serving.scheduler import Request

    smoke = _smoke()
    # head_dim 64 so the int8 byte ratio (2D/(D+4)) clears the 1.8x bar;
    # long-ish contexts so decode actually streams multiple blocks per token
    cfg = ModelConfig(name="bench-kvq", family="dense", n_layers=2,
                      d_model=128, n_heads=2, n_kv_heads=2, head_dim=64,
                      d_ff=128 if smoke else 256, vocab_size=256,
                      dtype="float32", q_chunk=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_slots = 4
    S = 48 if smoke else 160
    decode_new = 12 if smoke else 48
    max_len = 96 if smoke else 256
    budget = 48
    arms = {"baseline": "bfloat16", "int8": "int8"}
    results: dict = {}

    for label, kv_dtype in arms.items():
        rng = np.random.default_rng(23)      # same stream ⇒ same prompts
        eng = ServeEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                          paged=True, block_size=16, token_budget=budget,
                          kv_dtype=kv_dtype)
        done = []
        eng.on_complete = done.append
        t0 = time.monotonic()
        eng.submit(Request(request_id="warm", session_key="warm",
                           prompt=rng.integers(0, cfg.vocab_size, (8,))
                           .astype(np.int32), max_new_tokens=2))
        eng.run_until_drained()
        compile_s = time.monotonic() - t0
        mark = len(eng.stats.tpot_s)
        for i in range(n_slots):
            eng.submit(Request(
                request_id=f"r{i}", session_key=f"s{i}",
                prompt=rng.integers(0, cfg.vocab_size, (S,))
                .astype(np.int32), max_new_tokens=decode_new))
        t0 = time.monotonic()
        eng.run_until_drained()
        wall_s = time.monotonic() - t0
        tpot = eng.stats.tpot_s[mark:]
        assert eng.stats.host_syncs == eng.stats.ticks
        assert all(r.error is None for r in done)
        row = {
            "kv_dtype": kv_dtype,
            "kv_bytes_per_token": eng.cm.kv_bytes_per_token(),
            "n_layers": cfg.n_layers, "n_kv_heads": cfg.n_kv_heads,
            "head_dim": cfg.head_dim,
            "ctx_tokens": S + decode_new,
            "compile_s": compile_s,
            "tpot_p50_s": _pct(tpot, 0.50),
            "tpot_p50_us": _pct(tpot, 0.50) * 1e6,
            "tpot_p99_us": _pct(tpot, 0.99) * 1e6,
            "ticks": eng.stats.ticks,
            "wall_s": wall_s,
        }
        results[label] = row
        out(f"serve_kv_quant/{label},{row['tpot_p50_us']:.1f},"
            f"kv_bytes_per_token={row['kv_bytes_per_token']:.0f} "
            f"tpot_p99_us={row['tpot_p99_us']:.1f} ticks={row['ticks']}")

    byte_ratio = (results["baseline"]["kv_bytes_per_token"]
                  / results["int8"]["kv_bytes_per_token"])
    tpot_ratio = (results["baseline"]["tpot_p50_us"]
                  / max(1e-9, results["int8"]["tpot_p50_us"]))
    results["total"] = {"kv_byte_ratio": byte_ratio,
                        "tpot_ratio_p50": tpot_ratio}
    out(f"serve_kv_quant/byte_ratio,{byte_ratio:.2f},"
        f"tpot_ratio_p50={tpot_ratio:.2f}")
    assert byte_ratio >= 1.8, \
        f"int8 pool must cut KV bytes/token >= 1.8x vs bf16 (got " \
        f"{byte_ratio:.2f}x)"
    out("serve_kv_quant/CLAIM int8-halves-kv-bytes-per-token,PASS,exact")
    if not smoke:
        assert results["int8"]["tpot_p50_us"] \
            < results["baseline"]["tpot_p50_us"], \
            "int8 KV pool failed to beat bf16 decode TPOT p50"
        out("serve_kv_quant/CLAIM int8-beats-bf16-tpot,PASS,exact")
    _write_results("serve_kv_quant", results, out)
    return results


# ----------------------------------------------------------------------
# Replica scaling on mesh slices
# ----------------------------------------------------------------------
def _replica_scaling_measure() -> dict:
    """Measure 1-slice vs 2-slice deployments (needs >= 4 local devices;
    ``bench_serve_replica_scaling`` re-execs under a forced device count
    when the session has fewer).  Returns the raw per-arm results — all
    asserting happens in the parent."""
    from repro.models import init_params
    from repro.models.config import ModelConfig
    from repro.serving.cluster import ServeCluster
    from repro.serving.engine import EngineStats

    cfg = ModelConfig(name="bench", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype="float32", q_chunk=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n_reqs, max_new = (8, 6) if _smoke() else (24, 16)
    prompts = [rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
               for _ in range(n_reqs)]

    results: dict = {"devices": len(jax.devices())}
    for arm, n_replicas in (("baseline", 1), ("sharded", 2)):
        with ServeCluster(cfg, params, n_replicas=n_replicas, n_slots=4,
                          max_len=128, devices_per_replica=2) as c:
            # sliced replicas compile their OWN programs (per-slice
            # out_shardings): warm every replica before timing
            for w in range(n_replicas):
                c.submit(f"warmup-{w}", f"w{w}", prompts[0],
                         max_new_tokens=2)
            c.run_until_drained()
            for e in c.engines:
                e.stats = EngineStats()
            t0 = time.monotonic()
            for i, p in enumerate(prompts):
                c.submit(f"sess-{i}", f"r{i}", p, max_new_tokens=max_new)
            c.run_until_drained()
            wall_s = time.monotonic() - t0
            served = sum(c.result(f"r{i}") is not None
                         for i in range(n_reqs))
            st = c.stats()
            pool_dev_sets = [
                sorted(d.id for d in
                       jax.tree.leaves(e.cm.pools)[0].sharding.device_set)
                for e in c.engines]
            results[arm] = {
                "n_replicas": n_replicas,
                "requests": n_reqs,
                "served": served,
                "tokens_out": st["tokens_out"],
                "driver_passes": max(e.stats.ticks for e in c.engines),
                "tokens_per_pass": st["tokens_out"]
                / max(1, max(e.stats.ticks for e in c.engines)),
                "wall_s": wall_s,
                "ttft_p50_us": st["ttft_p50_s"] * 1e6,
                "ttft_p99_us": st["ttft_p99_s"] * 1e6,
                "host_syncs_eq_ticks": all(
                    e.stats.host_syncs == e.stats.ticks for e in c.engines),
                "donate_misses": c.kv_store.donate_misses,
                "pool_devices": pool_dev_sets,
            }
    return results


def bench_serve_replica_scaling(out) -> dict:
    """2 replicas on 2 DISJOINT mesh slices vs 1 replica on 1 slice, same
    workload: per-driver-pass token throughput must scale near-linearly
    (each pass ticks every busy engine once; with the work split across two
    slices each engine drains in about half the passes).  Wall-clock 2x
    needs the data-parallel tick drivers tracked in ROADMAP item 1 — the
    single-threaded round-robin driver serializes the two slices' ticks, so
    this benchmark asserts the per-pass ratio plus REAL sharded placement:
    disjoint 2-device slices, zero donate misses (sharded publishes stay
    zero-copy), and host_syncs == ticks per engine."""
    import json as _json
    import subprocess
    import sys

    if len(jax.devices()) >= 4:
        results = _replica_scaling_measure()
    else:
        # jax is already initialized single-device here: re-exec a child
        # with the forced device count (the flag must precede first init).
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=8").strip()
        env.setdefault("JAX_PLATFORMS", "cpu")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(root, "src"), root]
            + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
        code = ("import json\n"
                "from benchmarks.serve import _replica_scaling_measure\n"
                "print('RSJSON:' + json.dumps(_replica_scaling_measure()))\n")
        proc = subprocess.run([sys.executable, "-c", code], cwd=root,
                              env=env, capture_output=True, text=True,
                              timeout=1800)
        if proc.returncode != 0:
            raise RuntimeError(
                f"forced-device child failed:\n{proc.stdout}\n{proc.stderr}")
        line = [ln for ln in proc.stdout.splitlines()
                if ln.startswith("RSJSON:")][-1]
        results = _json.loads(line[len("RSJSON:"):])
        out(f"# measured in forced-8-device child (parent had "
            f"{len(jax.devices())} device(s))")

    base, shard = results["baseline"], results["sharded"]
    for arm in (base, shard):
        assert arm["served"] == arm["requests"], f"stranded requests: {arm}"
        assert arm["host_syncs_eq_ticks"], \
            "a sliced engine broke host_syncs == ticks"
        assert arm["donate_misses"] == 0, \
            "sharded pool publish fell off the zero-copy donate path"
        for devs in arm["pool_devices"]:
            assert len(devs) == 2, f"pool leaf not sharded over 2 devices: {devs}"
    assert not set(shard["pool_devices"][0]) & set(shard["pool_devices"][1]), \
        "replica slices share a device"
    ratio = shard["tokens_per_pass"] / max(1e-9, base["tokens_per_pass"])
    results["total"] = {"tokens_per_pass_ratio": ratio,
                        "ttft_p99_us": shard["ttft_p99_us"]}
    out(f"serve_replica_scaling/baseline,{base['ttft_p99_us']:.1f},"
        f"tokens_per_pass={base['tokens_per_pass']:.2f}")
    out(f"serve_replica_scaling/sharded,{shard['ttft_p99_us']:.1f},"
        f"tokens_per_pass={shard['tokens_per_pass']:.2f} "
        f"ratio={ratio:.2f}")
    out("serve_replica_scaling/CLAIM disjoint-slices-sharded-pool,PASS,exact")
    out("serve_replica_scaling/CLAIM sharded-publish-zero-copy,PASS,exact")
    if not _smoke():
        assert ratio >= 1.8, \
            f"2 slices must deliver ~2x per-pass token throughput " \
            f"(got {ratio:.2f}x)"
        out("serve_replica_scaling/CLAIM two-slices-near-linear,PASS,exact")
    _write_results("serve_replica_scaling", results, out)
    return results

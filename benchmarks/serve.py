"""Serving-path benchmarks beyond the paper's figures: paged KV + prefix
reuse on the multi-replica cluster.

``serve_prefix_reuse``: multi-turn chat sessions over FIFO affinity — every
turn's prompt extends the session's full history, so with the per-replica
prefix trie each warm turn prefills only the suffix past the last cached
block.  Reports TTFT p50/p99 per turn round, the token-level prefix hit
rate, and the skipped-block count; asserts the fast-path invariants (one
device→host sync per tick; warm turns reuse > 0 tokens and prefill strictly
fewer than they carry).  Results land in BENCH_serve.json so the serving
perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                          "BENCH_serve.json")


def bench_serve_prefix_reuse(out) -> dict:
    from repro.core.pools import DispatchPolicy
    from repro.models import init_params
    from repro.models.config import ModelConfig
    from repro.serving.cluster import ServeCluster

    cfg = ModelConfig(name="bench", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype="float32", q_chunk=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    n_sessions, n_turns, block_size = 6, 4, 16
    new_tokens_per_turn, decode_budget = 24, 8
    results: dict = {"turns": []}

    with ServeCluster(cfg, params, n_replicas=2, n_slots=4, max_len=256,
                      policy=DispatchPolicy.FIFO, block_size=block_size) as c:
        history = {f"s{i}": rng.integers(0, cfg.vocab_size,
                                         (new_tokens_per_turn,)).astype(np.int32)
                   for i in range(n_sessions)}
        prev_hits = 0
        for turn in range(n_turns):
            marks = {e: (len(e.stats.ttft_s),
                         e.stats.prefix_hit_tokens, e.stats.prompt_tokens)
                     for e in c.engines}
            t0 = time.monotonic()
            for sess, hist in history.items():
                c.submit(sess, f"{sess}-t{turn}", hist,
                         max_new_tokens=decode_budget)
            c.run_until_drained()
            dt = time.monotonic() - t0
            ttft = sorted(t for e in c.engines
                          for t in e.stats.ttft_s[marks[e][0]:])
            hit = sum(e.stats.prefix_hit_tokens - marks[e][1]
                      for e in c.engines)
            prompt = sum(e.stats.prompt_tokens - marks[e][2]
                         for e in c.engines)
            pct = lambda xs, q: xs[min(len(xs) - 1, int(q * len(xs)))]
            row = {
                "turn": turn,
                "ttft_p50_us": pct(ttft, 0.50) * 1e6,
                "ttft_p99_us": pct(ttft, 0.99) * 1e6,
                "prompt_tokens": prompt,
                "prefix_hit_tokens": hit,
                "hit_rate": hit / max(1, prompt),
                "skipped_blocks": hit // block_size,
                "wall_s": dt,
            }
            results["turns"].append(row)
            out(f"serve_prefix_reuse/turn{turn},{row['ttft_p50_us']:.1f},"
                f"ttft_p99_us={row['ttft_p99_us']:.1f} "
                f"hit_rate={row['hit_rate']:.2f} "
                f"skipped_blocks={row['skipped_blocks']}")
            if turn > 0:
                assert hit > prev_hits or hit > 0, \
                    "warm turns must reuse cached prefix blocks"
            prev_hits = hit
            # next turn: history grows by this turn's output + new user text
            for sess in history:
                turn_out = []
                for rid in (f"{sess}-t{turn}",):
                    res = c.result(rid)
                    assert res is not None
                    turn_out.append(res)
                history[sess] = np.concatenate(
                    [history[sess]] + [np.asarray(t, np.int32) for t in turn_out]
                    + [rng.integers(0, cfg.vocab_size,
                                    (new_tokens_per_turn,)).astype(np.int32)])

        st = c.stats()
        assert st["host_syncs"] == st["decode_ticks"] + st["prefill_batches"], \
            "decode tick made more than one device→host transfer"
        assert st["prefix_hit_tokens"] > 0, "no prefix reuse over warm turns"
        # strictly fewer prefill FLOPs than a cache-less engine would spend
        assert st["prefill_tokens"] < st["prompt_tokens"]
        results["total"] = {
            "requests": st["requests"],
            "prompt_tokens": st["prompt_tokens"],
            "prefill_tokens": st["prefill_tokens"],
            "prefix_hit_tokens": st["prefix_hit_tokens"],
            "hit_rate": st["prefix_hit_tokens"] / max(1, st["prompt_tokens"]),
            "ttft_p50_us": st["ttft_p50_s"] * 1e6,
            "ttft_p99_us": st["ttft_p99_s"] * 1e6,
            "blocks_in_use": st["blocks_in_use"],
        }
    out(f"serve_prefix_reuse/total,{results['total']['ttft_p50_us']:.1f},"
        f"hit_rate={results['total']['hit_rate']:.2f} "
        f"prefill_tokens={results['total']['prefill_tokens']} "
        f"of_prompt_tokens={results['total']['prompt_tokens']}")
    out("serve_prefix_reuse/CLAIM warm-turns-skip-prefix-prefill,PASS,exact")
    with open(BENCH_JSON, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    out(f"# wrote {BENCH_JSON}")
    return results

"""Paper Fig 6 (fast-path latency breakdown), Fig 7 (no-op pipeline), and the
Fig 1 Cascade-vs-broker comparison.

Claims under test: the dispatch overhead (enqueue+dequeue) is small relative
to the put itself; LB ≈ FIFO; pipeline latency grows ~linearly with depth
and trigger < volatile; the broker handoff (serialize + queue + poll +
deserialize) has far higher median and tail latency than the Cascade fast
path running IDENTICAL lambdas.
"""
from __future__ import annotations

import statistics
import tempfile
import time

from repro.core import (BrokerPipeline, CascadeService, DFG, DispatchPolicy,
                        Persistence, PoolSpec, Vertex)

from .common import SIZES, measure, now_us, payload


def bench_fastpath_breakdown(out) -> dict:
    """Fig 6: submit / enqueue / dequeue components, T vs V, L vs F."""
    results = {}
    with tempfile.TemporaryDirectory() as d:
        svc = CascadeService(n_workers=3, log_dir=d)
        for disp, tag in ((DispatchPolicy.ROUND_ROBIN, "L"), (DispatchPolicy.FIFO, "F")):
            svc.store.create_pool(PoolSpec(
                path=f"/trig{tag}", persistence=Persistence.TRANSIENT, dispatch=disp))
            svc.store.create_pool(PoolSpec(path=f"/vola{tag}", replication=3,
                                           dispatch=disp))
            from repro.core.dispatcher import LambdaHandle
            svc.store.register_lambda(LambdaHandle(
                f"noop{tag}", f"/trig{tag}", lambda o, ev: None, dispatch=disp))
            svc.store.register_lambda(LambdaHandle(
                f"noopv{tag}", f"/vola{tag}", lambda o, ev: None, dispatch=disp))
        for size_name, nbytes in (("10KB", SIZES["10KB"]), ("1MB", SIZES["1MB"])):
            data = payload(nbytes)
            n = 150 if nbytes < 100_000 else 40
            for mode in ("trig", "vola"):
                for tag in ("L", "F"):
                    submits, enqueues, dequeues = [], [], []
                    for i in range(n):
                        t0 = now_us()
                        if mode == "trig":
                            r = svc.trigger_put(f"/{mode}{tag}/k", data)
                        else:
                            r = svc.put(f"/{mode}{tag}/k", data)
                        t1 = now_us()
                        r.wait()
                        ev = r.events[0]
                        submits.append(t1 - t0)
                        enqueues.append(max(0.0, (ev.dequeued_ns - ev.enqueued_ns) / 1e3))
                        dequeues.append(max(0.0, (ev.done_ns - ev.dequeued_ns) / 1e3))
                    key = f"{mode[0].upper()}{tag}_{size_name}"
                    med = statistics.median
                    out(f"fig6/{key},{med(submits):.1f},"
                        f"enqueue={med(enqueues):.1f} dequeue={med(dequeues):.1f}")
                    results[key] = (med(submits), med(enqueues), med(dequeues))
        svc.close()
    # claims: dispatch overhead small vs put; LB ≈ FIFO (within 3x)
    for size in ("10KB", "1MB"):
        tl, tf = results[f"T{'L'}_{size}"], results[f"T{'F'}_{size}"]
        assert tl[1] + tl[2] < 20 * max(1.0, tl[0]), "dispatch overhead blew up"
    out("fig6/CLAIM dispatch-overhead-small,PASS,ordinal")
    return results


def _noop_cascade(svc, n_stages: int, mode: str) -> DFG:
    dfg = DFG(name=f"noop{n_stages}{mode}")
    for i in range(n_stages):
        dfg.add_vertex(Vertex(
            f"s{i}", f"/noop{n_stages}{mode}/s{i}",
            persistence=Persistence.TRANSIENT if mode == "trig" else Persistence.VOLATILE,
            replication=1 if mode == "trig" else 3))
        if i:
            dfg.add_edge(f"s{i-1}", f"s{i}")
    lambdas = {}
    done_evt = {"evt": None}

    def relay(ctx, obj):
        if ctx.dfg.successors(ctx.vertex.name):
            ctx.emit(obj.key.rsplit("/", 1)[-1], obj.payload,
                     trigger=(mode == "trig"))
        else:
            done_evt["evt"].set()

    for i in range(n_stages):
        lambdas[f"s{i}"] = relay
    svc.deploy(dfg, lambdas)
    return dfg, done_evt


def bench_noop_pipeline(out) -> dict:
    """Fig 7 + Fig 1: pipeline depth sweep, Cascade (trig/vola) vs broker."""
    import threading

    results = {}
    for size_name in ("10KB", "1MB"):
        data = payload(SIZES[size_name])
        n = 60 if size_name == "10KB" else 25
        for depth in (1, 2, 4):
            with tempfile.TemporaryDirectory() as d:
                svc = CascadeService(n_workers=4, log_dir=d)
                for mode in ("trig", "vola"):
                    dfg, done = _noop_cascade(svc, depth, mode)
                    lat = []
                    for i in range(n):
                        done["evt"] = threading.Event()
                        t0 = now_us()
                        svc.inject(dfg.name, "k", data, trigger=(mode == "trig"))
                        assert done["evt"].wait(10)
                        lat.append(now_us() - t0)
                    med = statistics.median(lat)
                    p99 = sorted(lat)[int(0.99 * len(lat))]
                    out(f"fig7/cascade_{mode}_{size_name}_d{depth},{med:.1f},p99={p99:.1f}")
                    results[f"cascade_{mode}_{size_name}_d{depth}"] = (med, p99)
                svc.close()
            # broker baseline with identical no-op lambdas
            bp = BrokerPipeline([lambda x: x] * depth)
            lat = []
            for i in range(n):
                _, us = bp.roundtrip(data)
                lat.append(us)
            bp.stop()
            med = statistics.median(lat)
            p99 = sorted(lat)[int(0.99 * len(lat))]
            out(f"fig1/broker_{size_name}_d{depth},{med:.1f},p99={p99:.1f}")
            results[f"broker_{size_name}_d{depth}"] = (med, p99)
    # Fig 1 claim, scoped to what an intra-process broker can expose: the
    # handoff COPY cost.  At 1MB the serialize+queue+deserialize path must be
    # far slower than the zero-copy fast path (median AND tail).  At 10KB the
    # paper's gap comes from RDMA-vs-TCP, which has no intra-process analogue
    # — reported above, not asserted (see EXPERIMENTS.md §Paper-claims).
    for depth in (1, 2, 4):
        c = results[f"cascade_trig_1MB_d{depth}"]
        b = results[f"broker_1MB_d{depth}"]
        assert c[0] * 3 < b[0], f"cascade !<< broker median (1MB d{depth})"
        assert c[1] < b[1], f"cascade tail !< broker tail (1MB d{depth})"
    out("fig1/CLAIM cascade<<broker at 1MB,PASS,ordinal")
    return results


def bench_trie(out) -> dict:
    """§3.3: trie matching cost per depth level (paper: ~130ns/level)."""
    from repro.core.trie import PathTrie

    t = PathTrie()
    for i in range(64):
        t.insert(f"/a{i % 8}/b{i % 4}/c{i}/d", i)
    key = "/a1/b1/c9/d/e"
    n = 20000
    t0 = now_us()
    for _ in range(n):
        t.match(key)
    per_call = (now_us() - t0) / n
    per_level = per_call / 5 * 1000  # ns
    out(f"trie/match_per_level,{per_call:.3f},ns_per_level={per_level:.0f}")
    return {"ns_per_level": per_level}

"""§Roofline table generator: reads the dry-run JSONs and emits the
per-(arch × shape) three-term table used by EXPERIMENTS.md.

Also the KV-bytes-per-decode-token accounting mode (``kv_bytes_table``):
decode is bandwidth-bound — every cached K/V byte is read once per decode
token — so the KV-quantization win should be reported as *bytes moved*
(theoretical, from the pool layout) against *achieved bandwidth* (from the
measured ``serve_kv_quant`` A/B), not just wall-clock, which on shared
hosts mostly measures noise."""
from __future__ import annotations

import glob
import json
import os

from repro.configs.registry import ARCH_IDS, SHAPES, all_cells


def load_results(out_dir: str = "experiments/dryrun", tag: str = "") -> dict:
    rows = {}
    for path in glob.glob(os.path.join(out_dir, "*.json")):
        d = json.load(open(path))
        if d.get("tag", "") != tag or d["multi_pod"]:
            continue
        rows[(d["arch"], d["shape"])] = d
    return rows


def table(out, out_dir: str = "experiments/dryrun", tag: str = "") -> None:
    rows = load_results(out_dir, tag)
    out("roofline/arch,shape,compute_s,memory_s,collective_s,dominant,"
        "useful_ratio,temp_GiB")
    for cell in all_cells():
        key = (cell.arch_id, cell.shape.name)
        if cell.skipped:
            out(f"roofline/{cell.arch_id},{cell.shape.name},SKIP,{cell.skip_reason}")
            continue
        d = rows.get(key)
        if d is None:
            out(f"roofline/{cell.arch_id},{cell.shape.name},MISSING")
            continue
        r = d["roofline"]
        out(f"roofline/{cell.arch_id},{cell.shape.name},"
            f"{r['compute_s']:.4f},{r['memory_s']:.4f},{r['collective_s']:.4f},"
            f"{r['dominant'].replace('_s','')},{r['useful_flops_ratio']:.2f},"
            f"{d['memory_analysis']['temp_bytes']/2**30:.1f}")


# ---------------------------------------------------------------- KV bytes
# itemsize of each pool storage dtype; quantized entries add one f32 scale
# per (slot, kv-head), i.e. 4 bytes amortized over head_dim values.
_KV_ITEMSIZE = {"float32": 4, "bfloat16": 2, "int8": 1, "fp8_e4m3": 1}
_KV_SCALED = {"int8", "fp8_e4m3"}


def kv_bytes_per_decode_token(n_layers: int, n_kv_heads: int, head_dim: int,
                              kv_dtype: str) -> float:
    """Theoretical pool bytes per token slot: K+V over every layer, plus
    per-(slot, kv-head) f32 scales when quantized.  A decode token at
    context length L streams L× this per step — THE number the int8-vs-bf16
    ≥1.8x claim is made on (2D/(D+4) at head_dim D)."""
    per_head = 2 * head_dim * _KV_ITEMSIZE[kv_dtype]       # K + V
    if kv_dtype in _KV_SCALED:
        per_head += 2 * 4                                  # k_scale + v_scale
    return float(n_layers * n_kv_heads * per_head)


def kv_bytes_table(out, bench_json: str = "BENCH_serve.json") -> None:
    """Achieved-vs-theoretical KV bandwidth accounting from the
    ``serve_kv_quant`` A/B results (measured ``kv_bytes_per_token`` and
    TPOT at a fixed context): achieved_GBps = ctx × bytes/token / TPOT.
    Emits MISSING rows when the bench hasn't run yet."""
    out("kv_bytes/arm,kv_dtype,meas_B_per_tok,theor_B_per_tok,"
        "ctx_tokens,tpot_p50_us,achieved_MBps,ratio_vs_baseline")
    try:
        data = json.load(open(bench_json)).get("serve_kv_quant")
    except (OSError, json.JSONDecodeError):
        data = None
    if not data:
        out("kv_bytes/baseline,MISSING (run serve_kv_quant first)")
        return
    arms = [(k, v) for k, v in data.items()
            if isinstance(v, dict) and "kv_bytes_per_token" in v]
    base_bytes = next((v["kv_bytes_per_token"] for k, v in arms
                       if k == "baseline"), None)
    for name, arm in arms:
        meas = arm["kv_bytes_per_token"]
        theor = kv_bytes_per_decode_token(
            arm["n_layers"], arm["n_kv_heads"], arm["head_dim"],
            arm["kv_dtype"])
        ctx, tpot = arm["ctx_tokens"], arm["tpot_p50_s"]
        bw = ctx * meas / tpot / 1e6 if tpot > 0 else float("nan")
        ratio = base_bytes / meas if base_bytes else float("nan")
        out(f"kv_bytes/{name},{arm['kv_dtype']},{meas:.0f},{theor:.0f},"
            f"{ctx},{tpot*1e6:.0f},{bw:.1f},{ratio:.2f}")


def markdown_table(out_dir: str = "experiments/dryrun", tag: str = "") -> str:
    rows = load_results(out_dir, tag)
    lines = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
             "dominant | useful FLOPs ratio | temp GiB/chip |",
             "|---|---|---|---|---|---|---|---|"]
    for cell in all_cells():
        if cell.skipped:
            lines.append(f"| {cell.arch_id} | {cell.shape.name} | — | — | — | "
                         f"skipped | — | — |")
            continue
        d = rows.get((cell.arch_id, cell.shape.name))
        if d is None:
            lines.append(f"| {cell.arch_id} | {cell.shape.name} | MISSING |")
            continue
        r = d["roofline"]
        lines.append(
            f"| {cell.arch_id} | {cell.shape.name} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{d['memory_analysis']['temp_bytes']/2**30:.1f} |")
    return "\n".join(lines)

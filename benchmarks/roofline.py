"""§Roofline table generator: reads the dry-run JSONs and emits the
per-(arch × shape) three-term table used by EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os

from repro.configs.registry import ARCH_IDS, SHAPES, all_cells


def load_results(out_dir: str = "experiments/dryrun", tag: str = "") -> dict:
    rows = {}
    for path in glob.glob(os.path.join(out_dir, "*.json")):
        d = json.load(open(path))
        if d.get("tag", "") != tag or d["multi_pod"]:
            continue
        rows[(d["arch"], d["shape"])] = d
    return rows


def table(out, out_dir: str = "experiments/dryrun", tag: str = "") -> None:
    rows = load_results(out_dir, tag)
    out("roofline/arch,shape,compute_s,memory_s,collective_s,dominant,"
        "useful_ratio,temp_GiB")
    for cell in all_cells():
        key = (cell.arch_id, cell.shape.name)
        if cell.skipped:
            out(f"roofline/{cell.arch_id},{cell.shape.name},SKIP,{cell.skip_reason}")
            continue
        d = rows.get(key)
        if d is None:
            out(f"roofline/{cell.arch_id},{cell.shape.name},MISSING")
            continue
        r = d["roofline"]
        out(f"roofline/{cell.arch_id},{cell.shape.name},"
            f"{r['compute_s']:.4f},{r['memory_s']:.4f},{r['collective_s']:.4f},"
            f"{r['dominant'].replace('_s','')},{r['useful_flops_ratio']:.2f},"
            f"{d['memory_analysis']['temp_bytes']/2**30:.1f}")


def markdown_table(out_dir: str = "experiments/dryrun", tag: str = "") -> str:
    rows = load_results(out_dir, tag)
    lines = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
             "dominant | useful FLOPs ratio | temp GiB/chip |",
             "|---|---|---|---|---|---|---|---|"]
    for cell in all_cells():
        if cell.skipped:
            lines.append(f"| {cell.arch_id} | {cell.shape.name} | — | — | — | "
                         f"skipped | — | — |")
            continue
        d = rows.get((cell.arch_id, cell.shape.name))
        if d is None:
            lines.append(f"| {cell.arch_id} | {cell.shape.name} | MISSING |")
            continue
        r = d["roofline"]
        lines.append(
            f"| {cell.arch_id} | {cell.shape.name} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{d['memory_analysis']['temp_bytes']/2**30:.1f} |")
    return "\n".join(lines)

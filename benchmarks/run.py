"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Ordinal claims from the paper
are asserted inline (see each module's docstring for the claim list);
absolute magnitudes are host-scale, not RDMA-scale.

Run: PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filter on benchmark name")
    args = ap.parse_args()

    from . import fastpath, kv_store, pipelines, roofline, serve

    benches = [
        ("table1_kv_latency", kv_store.bench_kv_latency),
        ("fig3_kv_throughput", kv_store.bench_kv_throughput),
        ("fig4_saturation", kv_store.bench_saturation),
        ("fig6_fastpath_breakdown", fastpath.bench_fastpath_breakdown),
        ("fig1_fig7_noop_pipeline", fastpath.bench_noop_pipeline),
        ("trie_ns_per_level", fastpath.bench_trie),
        ("fig10_smart_farming", pipelines.bench_farming),
        ("fig11_collision_detection", pipelines.bench_collision),
        ("serve_cluster_ttft_tpot", pipelines.bench_serve_cluster),
        ("serve_prefix_reuse", serve.bench_serve_prefix_reuse),
        ("serve_mixed_tick", serve.bench_serve_mixed_tick),
        ("serve_speculative", serve.bench_serve_speculative),
        ("serve_multi_model", serve.bench_serve_multi_model),
        ("serve_chaos", serve.bench_serve_chaos),
        ("serve_overload", serve.bench_serve_overload),
        ("serve_kv_quant", serve.bench_serve_kv_quant),
        ("serve_replica_scaling", serve.bench_serve_replica_scaling),
        ("roofline_table", lambda out: roofline.table(out)),
        ("roofline_kv_bytes", lambda out: roofline.kv_bytes_table(out)),
    ]

    def out(line: str) -> None:
        print(line, flush=True)

    failures = []
    only = args.only.split(",") if args.only else None
    for name, fn in benches:
        if only and not any(sub in name for sub in only):
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            fn(out)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # keep going; report at the end
            failures.append((name, repr(e)))
            print(f"# {name} FAILED: {e!r}", flush=True)
    if failures:
        print(f"# {len(failures)} benchmark(s) failed", file=sys.stderr)
        sys.exit(1)
    print("# ALL BENCHMARKS PASS")


if __name__ == "__main__":
    main()
